"""Adaptive model specialization (paper §3.2.3 + 'Road Ahead').

Long-running queries with stable logic but evolving data allow retraining a
smaller model specialized to the *current* stream + preprocessing.  This
example distills the big stream-MLLM into the small backbone on the
optimized preprocessing distribution, then compares accuracy/latency of
big / pruned / distilled-small on the same extraction workload.

  PYTHONPATH=src python examples/distill_specialize.py [--steps 150]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import TollBoothStream
from repro.streaming.pretrain import (CROP, encode_tollbooth_labels,
                                      preprocess_np, stream_models)


def measure(mllm, params, frames, enc):
    t0 = time.perf_counter()
    out = mllm.forward(params, jnp.asarray(frames))
    jax.block_until_ready(out["present"])
    dt = time.perf_counter() - t0
    pred = {k: np.asarray(jnp.argmax(v, -1)) for k, v in out.items()}
    m = enc["mask_car"] > 0
    acc = {
        "present": float((pred["present"] == enc["present"]).mean()),
        "color": float((pred["color"][m] == enc["color"][m]).mean())
        if m.any() else float("nan"),
        "plate_char": float((pred["plate"][m] == enc["plate"][m]).mean())
        if m.any() else float("nan"),
    }
    return acc, dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=96)
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short workload: smoke-run in seconds")
    args = ap.parse_args()

    if args.quick:
        args.frames = min(args.frames, 32)
    ctx = stream_models(quick=args.quick)  # incl. the distilled small

    tb = TollBoothStream(seed=4242, car_rate=0.05)
    frames_raw, labels = tb.batch(args.frames)
    enc = encode_tollbooth_labels(labels)
    x = preprocess_np(frames_raw, CROP, 2)   # the optimized preprocessing

    print(f"\nworkload: {args.frames} frames under Crop+Downscale(2)")
    for name, (model, params) in {
        "big": (ctx.mllm, ctx.mllm_params),
        "pruned-50%": (ctx.mllm, ctx.mllm_pruned_params),
        "distilled-small": (ctx.mllm_small, ctx.mllm_small_params),
    }.items():
        # warmup then measure
        measure(model, params, x[:8], {k: v[:8] for k, v in enc.items()})
        acc, dt = measure(model, params, x, enc)
        print(f"  {name:16s} {dt*1e3/args.frames:6.2f} ms/frame  "
              f"present={acc['present']:.3f} color={acc['color']:.3f} "
              f"plate_char={acc['plate_char']:.3f}")
    print("\nphysical optimization picks the cheapest variant meeting the "
          "accuracy constraint (>=90% of big).")


if __name__ == "__main__":
    main()
