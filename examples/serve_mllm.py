"""Continuous-batching LM serving demo over the assigned-arch pool.

Serves a reduced-config backbone with the slot-based engine: mixed prompt
lengths, bucketed prefill, batched decode, per-slot KV cache lengths.

  PYTHONPATH=src python examples/serve_mllm.py --arch gemma2-2b --requests 6
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, smoke_config
from repro.models import LM, materialize
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--quick", action="store_true",
                    help="fewer, shorter requests: smoke-run in seconds")
    args = ap.parse_args()
    if args.quick:
        args.requests = min(args.requests, 3)
        args.max_new = min(args.max_new, 4)

    cfg = smoke_config(args.arch)
    if cfg.encoder_decoder:
        raise SystemExit("pick a decoder-only arch for this demo")
    lm = LM(cfg, tp=1)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    engine = ServingEngine(cfg, params, max_slots=3, s_max=128, eos_id=-1)

    rs = np.random.RandomState(7)
    reqs = [Request(uid=i,
                    prompt=list(rs.randint(2, cfg.vocab_size,
                                           rs.randint(4, 40))),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s) with 3 slots")
    for r in sorted(done, key=lambda r: r.uid):
        print(f"  req{r.uid} (prompt len {len(r.prompt):2d}): {r.output}")


if __name__ == "__main__":
    main()
