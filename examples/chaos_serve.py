"""Fault-tolerant serving walkthrough: chaos on the 4-feed fleet.

Runs the multi-stream workload (three tollbooth cameras + a volleyball
court, 9 queries, one shared extract server) under *deterministic*
injected faults — a seeded, schedule-driven ``FaultInjector``, never the
wall clock — and demonstrates the serve/degrade/drop contract:

  1. **absorbed faults** — transient forward errors (cleared on retry),
     injected device latency and source stalls on one feed: the run's
     outputs stay **bitwise identical** to the fault-free baseline; the
     cost is visible only in the retry/latency counters.
  2. **an outage** — one feed's transport goes dead past the ingest
     retry budget: its circuit breaker trips and quarantines it while
     the other three feeds keep serving; frames during the outage are
     answered from the semantic gate's last keyframe (marked ``stale``)
     or dropped with exact accounting — ``served + degraded + dropped``
     partitions the feed's frames, nothing is silently wrong.  The
     corruption window is bounded, so the half-open probe eventually
     succeeds and the feed **recovers**: it replays from its last
     snapshot back to the exactly-once frontier and resumes serving.
     The run is observed: fault/retry/quarantine/degraded instants land
     on the feed tracks, and the fault timeline exports to
     ``reports/chaos_trace.json`` (open at https://ui.perfetto.dev).
  3. the per-feed **SLO table** gains the degraded-mode columns — the
     sick feed's availability is exactly its served fraction.

  PYTHONPATH=src python examples/chaos_serve.py [--frames 96] [--quick]
"""
import argparse
import dataclasses
import os

from repro.data import TollBoothStream, VolleyballStream
from repro.faults import FaultInjector, FaultRule
from repro.obs import FAULT_PHASES, Observability
from repro.queries import get_query
from repro.scheduler import Feed, MultiStreamRuntime
from repro.semantic import GateConfig, SemanticGate
from repro.streaming.pretrain import stream_models

FEEDS = (
    ("tb-north", "tollbooth", 1234, ("Q2", "Q6", "Q8")),
    ("tb-south", "tollbooth", 4321, ("Q1", "Q5")),
    ("tb-east", "tollbooth", 2025, ("Q3", "Q9")),
    ("court-1", "volleyball", 1234, ("Q12", "Q13")),
)
SICK = "tb-south"
SEED = 11
TRACE_PATH = os.path.join("reports", "chaos_trace.json")


def _make_stream(dataset: str, seed: int):
    if dataset == "tollbooth":
        return TollBoothStream(seed=seed)
    return VolleyballStream(seed=seed)


def _run(ctx, frames: int, faults=None, gate=None, obs=None, **kw):
    if obs is not None:
        ctx = dataclasses.replace(ctx, obs=obs)
    feeds = [Feed(name, _make_stream(ds, seed),
                  [get_query(qid).naive_plan() for qid in qids])
             for name, ds, seed, qids in FEEDS]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16, faults=faults,
                            gate=gate, **kw)
    return ms.run(frames)


def _absorbed_schedule() -> FaultInjector:
    """Faults the stack absorbs without losing a single frame."""
    return FaultInjector(seed=SEED, rules=[
        # first launch of every 3rd tb-south extract fails; retry clears
        FaultRule(site="forward", kind="error", feed=SICK,
                  start=1, every=3, count=3, param=1),
        # every 4th forward (any feed) completes two polls late
        FaultRule(site="forward", kind="latency", start=0, every=4,
                  count=4, param=2),
        # the volleyball camera hiccups: produces nothing on two rounds
        FaultRule(site="source", kind="stall", feed="court-1",
                  start=1, every=2, count=2),
    ])


def _outage_schedule() -> FaultInjector:
    """A bounded transport outage on the sick feed (plus a stall and a
    transient forward error, so every fault category lands in the
    trace): corrupt deliveries past the ingest retry budget for two
    consecutive pulls, then clean — trips the breaker, recovers."""
    return FaultInjector(seed=SEED, rules=[
        FaultRule(site="source", kind="corrupt", feed=SICK,
                  start=2, every=1, count=2, param=99),
        FaultRule(site="source", kind="stall", feed=SICK,
                  start=1, every=1, count=1),
        FaultRule(site="forward", kind="error", feed=SICK,
                  start=1, every=1, count=1, param=1),
    ])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=96,
                    help="frames per feed")
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short streams: smoke-run in "
                         "seconds")
    args = ap.parse_args()
    if args.quick:
        args.frames = min(args.frames, 48)
    frames = args.frames
    ctx = stream_models(quick=args.quick)

    # ------------------------------------------------------------------
    print(f"\n=== fault-free baseline: {len(FEEDS)} feeds × "
          f"{frames} frames ===")
    base = _run(ctx, frames)
    print(f"fps={base.fps:.1f} forwards={base.server_stats['forwards']}")

    # ------------------------------------------------------------------
    print("\n=== absorbed faults: transient forward errors + injected "
          "latency + source stalls ===")
    inj = _absorbed_schedule()
    res = _run(ctx, frames, faults=inj)
    st = res.server_stats
    print(f"faults fired: {len(inj.log)} "
          f"({', '.join(sorted({e['kind'] for e in inj.log}))}); "
          f"retries={st['retries']} latency_faults={st['latency_faults']}")
    bitwise = all(
        res.feeds[name].per_query[qid].outputs
        == base.feeds[name].per_query[qid].outputs
        for name, _, _, qids in FEEDS for qid in qids)
    assert bitwise, "absorbed faults must keep outputs bitwise identical"
    assert all(r.breaker["trips"] == 0 for r in res.feeds.values())
    print(f"outputs bitwise identical to fault-free: {bitwise}; "
          f"every frame served ({sum(r.served for r in res.feeds.values())}"
          f"/{frames * len(FEEDS)}), zero trips")

    # ------------------------------------------------------------------
    print(f"\n=== outage: {SICK}'s transport goes dead for two pulls "
          "(gated, observed) ===")
    obs = Observability(slo_target_ms=250.0)
    gate = SemanticGate(GateConfig(threshold=0.06))
    inj = _outage_schedule()
    res = _run(ctx, frames, faults=inj, gate=gate, obs=obs,
               pipelined=False, breaker_cooldown=2)
    sick = res.feeds[SICK]
    print(f"{SICK}: served={sick.served} degraded={sick.degraded} "
          f"dropped={sick.dropped} breaker={sick.breaker}")
    assert sick.served + sick.degraded + sick.dropped == frames, \
        "served+degraded+dropped must exactly partition ingested frames"
    assert sick.breaker["trips"] >= 1
    for d in sick.degraded_records:
        assert d["stale"] is True          # degraded answers are marked
    healthy_served = {n: res.feeds[n].served
                      for n, _, _, _ in FEEDS if n != SICK}
    assert all(v == frames for v in healthy_served.values()), \
        healthy_served
    print(f"healthy feeds unaffected: served {healthy_served}")
    if sick.degraded:
        d = sick.degraded_records[0]
        ans = {k: v for k, v in list(d["answer"].items())[:2]}
        print(f"first degraded frame {d['idx']}: stale keyframe answer "
              f"{ans} …")
    if sick.breaker["recoveries"]:
        print(f"recovered after probe: replayed from snapshot, "
              f"{sick.served} frames served exactly once")

    # ------------------------------------------------------------------
    print("\nper-feed SLO accounting with degraded-mode columns:")
    print(obs.slo.table())

    os.makedirs("reports", exist_ok=True)
    n_events = obs.tracer.export_chrome(TRACE_PATH)
    cats = {e["cat"] for e in obs.tracer.events()}
    fault_cats = sorted(cats & set(FAULT_PHASES))
    print(f"\nwrote {TRACE_PATH}: {n_events} events, fault categories = "
          f"{fault_cats}")
    print("open it at https://ui.perfetto.dev — fault/retry instants on "
          "the feed tracks, quarantine/probe/recovered/degraded markers "
          "on the sick feed's")
    assert len(fault_cats) >= 2, \
        f"expected fault-timeline categories in the trace, got {cats}"
    print("\nchaos_serve OK")


if __name__ == "__main__":
    main()
