"""Semantic gating tier walkthrough: the temporal-redundancy extract
cache in front of the shared MLLM.

Serves the 4-feed / 9-query workload three ways over identical streams:

  * ungated   — PR 4's pipelined shared serving (every surviving frame
                pays a forward);
  * gated     — a ``SemanticGate`` consulted inside
                ``SharedExtractServer.submit``: near-duplicates of a
                recent keyframe are answered from its cached extract
                output, every Nth hit is revalidated through the model
                and *compared* (drift detection), and each feed's
                similarity threshold is tuned online against the
                configured accuracy budget;
  * disabled  — the same gate with threshold=0, demonstrating the
                no-regression contract: bitwise identical to ungated.

Prints forwards / model-frame reductions, measured
hit/miss/revalidation/mismatch rates, the per-feed tuned thresholds, and
per-query accuracy deltas against the ungated run.

  PYTHONPATH=src python examples/semantic_serve.py [--frames 256] [--quick]
"""
import argparse

from repro.data import TollBoothStream, VolleyballStream
from repro.queries import get_query
from repro.scheduler import Feed, MultiStreamRuntime, SharedExtractServer
from repro.semantic import GateConfig, SemanticGate
from repro.streaming.pretrain import stream_models

FEEDS = (
    ("tb-north", "tollbooth", 1234, ("Q2", "Q6", "Q8")),
    ("tb-south", "tollbooth", 4321, ("Q1", "Q5")),
    ("tb-east", "tollbooth", 2025, ("Q3", "Q9")),
    ("court-1", "volleyball", 1234, ("Q12", "Q13")),
)


def _make_stream(dataset: str, seed: int):
    if dataset == "tollbooth":
        return TollBoothStream(seed=seed)
    return VolleyballStream(seed=seed)


def _feeds():
    return [Feed(name, _make_stream(ds, seed),
                 [get_query(qid).naive_plan() for qid in qids])
            for name, ds, seed, qids in FEEDS]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=256,
                    help="frames per feed")
    ap.add_argument("--threshold", type=float, default=0.06,
                    help="base signature-distance threshold (0 disables)")
    ap.add_argument("--revalidate-every", type=int, default=8)
    ap.add_argument("--accuracy-budget", type=float, default=0.05)
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short streams: smoke-run in seconds")
    args = ap.parse_args()

    if args.quick:
        args.frames = min(args.frames, 48)
    ctx = stream_models(quick=args.quick)

    print(f"\n=== ungated serving: {len(FEEDS)} feeds × "
          f"{args.frames} frames ===")
    base = MultiStreamRuntime(_feeds(), ctx, micro_batch=16
                              ).run(args.frames)
    bst = base.server_stats

    cfg = GateConfig(threshold=args.threshold,
                     revalidate_every=args.revalidate_every,
                     accuracy_budget=args.accuracy_budget)
    gate = SemanticGate(cfg)
    print(f"=== gated serving (threshold={cfg.threshold}, "
          f"revalidate_every={cfg.revalidate_every}, "
          f"accuracy_budget={cfg.accuracy_budget}) ===")
    gated = MultiStreamRuntime(_feeds(), ctx, micro_batch=16,
                               server=SharedExtractServer(ctx, gate=gate)
                               ).run(args.frames)
    gst = gated.server_stats

    print("=== disabled gate (threshold=0): no-regression check ===")
    off = MultiStreamRuntime(
        _feeds(), ctx, micro_batch=16,
        server=SharedExtractServer(
            ctx, gate=SemanticGate(GateConfig(threshold=0.0)))
    ).run(args.frames)

    print(f"\n{'feed':<10} {'query':<6} {'acc(ungated)':>13} "
          f"{'acc(gated)':>11} {'Δ':>7}  off=ungated")
    worst = 0.0
    identical = True
    for name, _, _, qids in FEEDS:
        for qid in qids:
            bq = base.feeds[name].per_query[qid]
            gq = gated.feeds[name].per_query[qid]
            oq = off.feeds[name].per_query[qid]
            same = oq.outputs == bq.outputs \
                and oq.window_results == bq.window_results
            identical = identical and same
            a, b = get_query(qid).evaluate(bq), get_query(qid).evaluate(gq)
            worst = max(worst, a - b)
            print(f"{name:<10} {qid:<6} {a:>13.3f} {b:>11.3f} "
                  f"{b - a:>+7.3f}  {'yes' if same else 'NO'}")

    served = gst["cache_hits"] + gst["cache_misses"] + gst["revalidations"]
    print(f"\nforwards:      {gst['forwards']} gated vs "
          f"{bst['forwards']} ungated "
          f"({bst['forwards'] / max(gst['forwards'], 1):.2f}x reduction)")
    print(f"model frames:  {gst['frames']} gated vs {bst['frames']} "
          f"ungated "
          f"({bst['frames'] / max(gst['frames'], 1):.2f}x reduction)")
    print(f"cache:         {gst['cache_hits']}/{served} hits "
          f"({gst['cache_hits'] / max(served, 1):.1%}), "
          f"{gst['revalidations']} revalidations, "
          f"{gst['cache_mismatches']} mismatches")
    print("thresholds:    " + "  ".join(
        f"{feed}={st.threshold:.4f}"
        for feed, st in sorted(gate.controller._feeds.items())))
    print(f"throughput:    {gated.fps:.2f} gated vs {base.fps:.2f} "
          f"ungated query-frames/s")
    print(f"accuracy:      worst drop {worst:.3f} "
          f"(budget {cfg.accuracy_budget}) -> "
          f"{'WITHIN' if worst <= cfg.accuracy_budget else 'OVER'} budget")
    print(f"disabled gate: {'bitwise identical' if identical else 'DIVERGED'}"
          " vs ungated serving")


if __name__ == "__main__":
    main()
