"""Fleet optimization: joint, sharing-aware super-optimization + serving.

Optimizes a mixed workload (two tollbooth cameras + a volleyball court,
each with its own queries) *jointly*: every query runs the usual
semantic -> logical -> physical phase pipeline, all timings flow into one
calibrated ``CostCatalog``, and the ``FleetOptimizer`` canonicalizes the
rewritten prefixes (safe-join parameters, joint physical model choice) so
semantically-equivalent chains keep identical ``Op.signature()``s — then
picks per query between its private rewrite and the shareable canonical
plan by *fleet* cost: the sharing-tree cost of the whole workload, with
measured per-op costs and selectivities.  A rewrite that saves a little on
one query but breaks a prefix other queries share is rejected, and the
decision log shows why.

The optimized fleet then serves through the multi-stream tier
(``MultiStreamRuntime.from_fleet``) and is compared against per-query
optimized and naive plan sets — same outputs, fewer model forwards.

  PYTHONPATH=src python examples/fleet_serve.py [--frames 256] [--quick]
"""
import argparse

from repro.core.fleet import FleetOptimizer, FleetQuery
from repro.data import TollBoothStream, VolleyballStream
from repro.queries import get_query
from repro.scheduler import MultiStreamRuntime
from repro.scheduler.sharing_tree import uncalibrated
from repro.streaming.pretrain import stream_models
from repro.streaming.runtime import StreamRuntime

FEEDS = (
    ("tb-north", "tollbooth", 1234, ("Q2", "Q6", "Q8")),
    ("tb-south", "tollbooth", 4321, ("Q1", "Q5")),
    ("court-1", "volleyball", 1234, ("Q12", "Q13")),
)


def _factory(dataset: str):
    if dataset == "tollbooth":
        return lambda seed: TollBoothStream(seed=seed)
    return lambda seed: VolleyballStream(seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=256,
                    help="frames per feed")
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short streams: smoke-run in minutes")
    args = ap.parse_args()

    if args.quick:
        args.frames = min(args.frames, 48)
    val_frames = 48 if args.quick else 128
    ctx = stream_models(quick=args.quick)

    workload = [FleetQuery(get_query(qid), _factory(ds), feed=name)
                for name, ds, _, qids in FEEDS for qid in qids]
    print(f"\n=== jointly optimizing {len(workload)} queries over "
          f"{len(FEEDS)} feeds ===")
    fo = FleetOptimizer(ctx, val_frames=val_frames)
    fleet = fo.optimize(workload)
    print(fleet.describe())
    uncal = [n for p in fleet.plans.values() for n in uncalibrated(p.ops)]
    print(f"\ncalibrated cost entries: {len(fleet.catalog)}  "
          f"(uncalibrated ops in fleet plans: {len(uncal)})")

    print(f"\n=== serving the fleet ({len(FEEDS)} feeds × "
          f"{args.frames} frames) ===")
    streams = {name: _factory(ds)(seed) for name, ds, seed, _ in FEEDS}
    ms = MultiStreamRuntime.from_fleet(fleet, streams, ctx, micro_batch=16)
    shared = ms.run(args.frames)

    print("=== independent execution of the same fleet plans ===")
    exact = True
    indep_wall = 0.0
    indep_forwards = 0
    for name, ds, seed, _ in FEEDS:
        for p in fleet.plans_by_feed[name]:
            plan = p.clone()
            rt = StreamRuntime(plan, ctx, micro_batch=16)
            res = rt.run(_factory(ds)(seed), args.frames)
            indep_wall += res.wall_s
            indep_forwards += sum(op.forwards for op in plan.ops
                                  if hasattr(op, "forwards"))
            sq = shared.feeds[name].per_query[p.query]
            exact = exact and sq.outputs == res.outputs \
                and sq.window_results == res.window_results

    print(f"\nfleet serving: {shared.fps:8.2f} query-frames/s  "
          f"forwards={shared.server_stats['forwards']}  "
          f"(coalesced batches="
          f"{shared.server_stats['coalesced_batches']})")
    print(f"independent:   "
          f"{shared.n_queries * args.frames / indep_wall:8.2f} "
          f"query-frames/s  forwards={indep_forwards}")
    print(f"outputs bitwise identical to solo runs: "
          f"{'yes' if exact else 'NO'}")


if __name__ == "__main__":
    main()
