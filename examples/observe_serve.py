"""Frame-lifecycle observability on the 4-feed / 9-query serving stack.

Runs the multi-stream workload (three tollbooth cameras + a volleyball
court, semantic gating in front of the shared extract server) twice:

  1. **observed** — an ``Observability`` handle threaded through
     ``OpContext.obs``: every frame's lifecycle is traced (ingest →
     prefix ops → gate consult → server queue-wait → staging → dispatch →
     device forward → resume → tail), per-feed latency/staleness land in
     log-binned histograms, and the span ring buffer exports to
     ``reports/trace.json`` — Chrome trace-event JSON.  Open it at
     https://ui.perfetto.dev (or chrome://tracing): one track per feed,
     plus shared ``server`` / ``device`` tracks and the
     ``inflight`` / ``queue_depth`` occupancy counters.
  2. **unobserved** — the default ``NULL_OBS``: instrumented call sites
     degrade to no-op method calls.  The example asserts both runs'
     per-query outputs are bitwise identical, and bounds the tracing
     overhead (measured no-op + span cost × call count vs measured wall)
     at ≤ 1%.

What the trace shows on this CPU-only container: the ``device`` track's
``forward[...]`` spans tile the timeline nearly end-to-end while the
per-feed host spans squeeze between them — XLA's "device" work saturates
the same cores the host loop needs, which is why the pipelined speedup
measured by ``benchmarks/samsara_bench.py fig_pipeline`` sits near 1×
here (overlap is contention-bound); on a real accelerator the forward
spans move off-host and the same trace shows the overlap opening up.

The walkthrough ends with the audit loop: the sharing-tree planner's
per-decision predicted costs joined against what serving measured
(device-probed ``forward_device_ms`` vs the poll-quantized observed
span, per-op walls), drift flags, and a markdown flight report at
``reports/flight_report.md`` that ``scripts/bench_gate.py`` appends its
bench deltas to in CI.

  PYTHONPATH=src python examples/observe_serve.py [--frames 128] [--quick]
"""
import argparse
import os
import time

from repro.data import TollBoothStream, VolleyballStream
from repro.obs import (PHASES, Observability, forward_gap,
                       write_flight_report)
from repro.queries import get_query
from repro.scheduler import Feed, MultiStreamRuntime, SharedExtractServer
from repro.semantic import GateConfig, SemanticGate
from repro.streaming.pretrain import stream_models

FEEDS = (
    ("tb-north", "tollbooth", 1234, ("Q2", "Q6", "Q8")),
    ("tb-south", "tollbooth", 4321, ("Q1", "Q5")),
    ("tb-east", "tollbooth", 2025, ("Q3", "Q9")),
    ("court-1", "volleyball", 1234, ("Q12", "Q13")),
)
TRACE_PATH = os.path.join("reports", "trace.json")


def _make_stream(dataset: str, seed: int):
    if dataset == "tollbooth":
        return TollBoothStream(seed=seed)
    return VolleyballStream(seed=seed)


def _run(ctx, frames: int, obs=None):
    """One gated, pipelined serving run over fresh streams/runtimes;
    returns (runtime, result) so callers can audit the plan."""
    import dataclasses

    from repro.core.costs import CostCatalog
    from repro.scheduler.sharing_tree import SharingTreePlanner

    if obs is not None:
        ctx = dataclasses.replace(ctx, obs=obs)
    feeds = [Feed(name, _make_stream(ds, seed),
                  [get_query(qid).naive_plan() for qid in qids])
             for name, ds, seed, qids in FEEDS]
    gate = SemanticGate(GateConfig(threshold=0.06))
    # a catalog-backed planner closes the audit loop: end-of-run
    # reconcile EMA-feeds measured costs + gate hit rates back into it
    planner = SharingTreePlanner(catalog=CostCatalog(), micro_batch=16)
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16, gate=gate,
                            planner=planner)
    return ms, ms.run(frames)


def _overhead_bound(wall_s: float, frames: int) -> float:
    """Upper-bound the disabled-path tracing cost as a fraction of the
    measured wall: (measured ns per no-op obs call) × (instrumented call
    sites per micro-batch × micro-batches).  The disabled path executes
    only ``obs.enabled`` attribute checks and ``NULL_OBS.now()`` — this
    measures those directly instead of trusting an assumed constant."""
    from repro.obs import NULL_OBS

    reps = 200_000
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        NULL_OBS.now()
    per_call_ns = (time.perf_counter_ns() - t0) / reps
    # ~24 guarded sites touched per micro-batch across the whole
    # lifecycle (ingest, per-prefix-op, gate, submit, launch, retire,
    # resume, tail, SLO) — a deliberate overestimate
    calls = 24 * (frames * len(FEEDS) / 16 + 1)
    return (per_call_ns * calls) / (wall_s * 1e9)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=128,
                    help="frames per feed")
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short streams: smoke-run in seconds")
    args = ap.parse_args()

    if args.quick:
        args.frames = min(args.frames, 48)
    ctx = stream_models(quick=args.quick)

    print(f"\n=== observed serving: {len(FEEDS)} feeds × "
          f"{args.frames} frames (gated, pipelined) ===")
    obs = Observability(slo_target_ms=250.0)
    ms, observed = _run(ctx, args.frames, obs=obs)

    print("\nper-feed SLO accounting "
          f"(target {obs.slo.target_ms:.0f}ms frame latency):")
    print(obs.slo.table())

    st = observed.server_stats
    print(f"\nserver: forwards={st['forwards']} "
          f"coalesced={st['coalesced_batches']} "
          f"peak_inflight={st['max_inflight_seen']} "
          f"cache_hits={st['cache_hits']} "
          f"revalidations={st['revalidations']}")
    qw = obs.metrics.histogram("forward_ms")
    print(f"device forwards: n={qw.count} p50={qw.percentile(50):.1f}ms "
          f"p95={qw.percentile(95):.1f}ms")

    # the audit loop: planner decisions joined against what serving
    # actually measured (device-probed forwards, per-op walls), drift
    # beyond tolerance flagged and EMA-fed back into the cost catalog
    audit = ms.audit()
    print("\nper-decision audit (predicted vs measured, µs/frame):")
    print(audit.table(obs.metrics))
    gap = forward_gap(obs.metrics)
    if gap is not None:
        print(f"\nforward timing: observed {gap['observed_ms']:.1f}ms vs "
              f"device-probed {gap['device_ms']:.1f}ms mean — "
              f"{gap['gap_frac']:.0%} of the observed span is poll "
              f"latency, not device time ({gap['probes']} probes / "
              f"{gap['forwards']} forwards)")
    if ms.drift_flags:
        print(f"cost-model drift flags (catalog EMA-corrected): "
              f"{', '.join(ms.drift_flags)}")

    report_path = write_flight_report(
        os.path.join("reports", "flight_report.md"),
        slo=obs.slo, audit=audit, metrics=obs.metrics,
        flagged=ms.drift_flags,
        notes=[f"{len(FEEDS)} feeds × {args.frames} frames, "
               "gated + pipelined, quick models"
               if args.quick else
               f"{len(FEEDS)} feeds × {args.frames} frames, "
               "gated + pipelined"])
    print(f"\nwrote {report_path} (SLO + audit + drift flags; the CI "
          "bench gate appends its deltas to the same file)")

    os.makedirs("reports", exist_ok=True)
    n_events = obs.tracer.export_chrome(TRACE_PATH)
    cats = {e["cat"] for e in obs.tracer.events()}
    print(f"\nwrote {TRACE_PATH}: {n_events} events, "
          f"span phases = {sorted(cats & set(PHASES))}")
    print("open it at https://ui.perfetto.dev — one track per feed plus "
          "shared server/device tracks and inflight/queue_depth counters")
    assert len(cats & set(PHASES)) >= 6, \
        f"expected >= 6 lifecycle phases in the trace, got {sorted(cats)}"

    print(f"\n=== unobserved rerun (NULL_OBS) — the no-overhead "
          f"contract ===")
    _, baseline = _run(ctx, args.frames)
    same = all(
        observed.feeds[name].per_query[qid].outputs
        == baseline.feeds[name].per_query[qid].outputs
        and observed.feeds[name].per_query[qid].window_results
        == baseline.feeds[name].per_query[qid].window_results
        for name, _, _, qids in FEEDS for qid in qids)
    bound = _overhead_bound(baseline.wall_s, args.frames)
    print(f"outputs bitwise identical observed vs unobserved: "
          f"{'yes' if same else 'NO'}")
    print(f"disabled-path overhead bound: {bound:.3%} of wall "
          f"(limit 1%)")
    assert same, "observability changed serving outputs"
    assert bound <= 0.01, f"disabled-path overhead bound {bound:.3%} > 1%"


if __name__ == "__main__":
    main()
