"""Multi-query shared execution over one stream (the serving story).

Submits every catalog query for one dataset *concurrently*: the planner
factors the plans' longest common operator prefix — including a single
union-task MLLM extract — and one ``MultiQueryRuntime`` serves all of them
in a single pass over the frames.  Compares against N independent
``StreamRuntime``s on the same held-out stream: same per-query answers,
one model invocation per surviving frame instead of N.

  PYTHONPATH=src python examples/multiquery_stream.py \
      [--dataset tollbooth|volleyball] [--frames 512] [--quick]
"""
import argparse

from repro.data import TollBoothStream, VolleyballStream
from repro.queries import QUERIES, get_query
from repro.streaming import MultiQueryRuntime, StreamRuntime
from repro.streaming.pretrain import stream_models


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tollbooth",
                    choices=("tollbooth", "volleyball"))
    ap.add_argument("--frames", type=int, default=512)
    ap.add_argument("--eval-seed", type=int, default=999)
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short streams: smoke-run in seconds")
    args = ap.parse_args()

    if args.quick:
        args.frames = min(args.frames, 64)
    ctx = stream_models(quick=args.quick)

    if args.dataset == "tollbooth":
        make_stream = lambda: TollBoothStream(seed=args.eval_seed)  # noqa
    else:
        make_stream = lambda: VolleyballStream(seed=args.eval_seed)  # noqa
    qids = [qid for qid, q in QUERIES.items() if q.dataset == args.dataset]

    print(f"\n=== factoring {len(qids)} concurrent queries "
          f"({', '.join(qids)}) ===")
    plans = [get_query(qid).naive_plan() for qid in qids]
    mq = MultiQueryRuntime(plans, ctx, micro_batch=16)
    print(mq.shared.describe())
    for note in mq.shared.notes:
        print(f"  [planner] {note}")

    print(f"\n=== shared execution ({args.frames} frames) ===")
    shared = mq.run(make_stream(), args.frames)

    print(f"=== independent execution ({len(qids)} runtimes) ===")
    indep = {}
    indep_wall = 0.0
    for qid in qids:
        rt = StreamRuntime(get_query(qid).naive_plan(), ctx, micro_batch=16)
        res = rt.run(make_stream(), args.frames)
        indep[qid] = res
        indep_wall += res.wall_s

    print(f"\n{'query':<6} {'acc(shared)':>12} {'acc(indep)':>11} exact")
    for qid in qids:
        a = get_query(qid).evaluate(shared.per_query[qid])
        b = get_query(qid).evaluate(indep[qid])
        same = shared.per_query[qid].outputs == indep[qid].outputs
        print(f"{qid:<6} {a:>12.3f} {b:>11.3f} {'yes' if same else 'NO'}")

    indep_mllm = sum(r.mllm_frames for r in indep.values())
    indep_fps = len(qids) * args.frames / indep_wall
    print(f"\nshared:      {shared.fps:8.2f} query-frames/s  "
          f"MLLM frames={shared.mllm_frames}")
    print(f"independent: {indep_fps:8.2f} query-frames/s  "
          f"MLLM frames={indep_mllm}")
    print(f"aggregate speedup: {indep_wall/shared.wall_s:.2f}x   "
          f"model-load reduction: {1 - shared.mllm_frames/indep_mllm:.1%}")


if __name__ == "__main__":
    main()
