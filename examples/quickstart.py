"""Quickstart: train a reduced-config arch for a few steps, checkpoint,
restore, and serve a few tokens with the continuous-batching engine.

  PYTHONPATH=src python examples/quickstart.py [--arch chatglm3-6b]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config, list_archs
from repro.models import LM, materialize
from repro.serving import Request, ServingEngine
from repro.training import (CheckpointManager, OptimizerConfig, TokenStream,
                            TrainConfig, Trainer)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--quick", action="store_true",
                    help="fewer training steps: smoke-run in seconds")
    args = ap.parse_args()
    if args.quick:
        args.steps = min(args.steps, 30)

    cfg = smoke_config(args.arch)
    print(f"arch={args.arch} (reduced config: {cfg.n_layers}L "
          f"d={cfg.d_model})")
    lm = LM(cfg, tp=1)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)

    # --- train ---
    data = TokenStream(cfg.vocab_size, batch=8, seq_len=32)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            lambda p, b: lm.loss(p, b, jnp.float32), params,
            OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
            TrainConfig(steps=args.steps, grad_accum=2, ckpt_every=30,
                        log_every=20),
            data, CheckpointManager(ckpt_dir))
        out = trainer.train()
        print(f"trained {out['step']} steps, "
              f"loss {out['history'][0]:.3f} -> {out['final_loss']:.3f}")

        # --- serve ---
        if not cfg.encoder_decoder:
            engine = ServingEngine(cfg, trainer.params, max_slots=2,
                                   s_max=64, eos_id=-1)
            rs = np.random.RandomState(0)
            reqs = [Request(uid=i,
                            prompt=list(rs.randint(2, cfg.vocab_size, 8)),
                            max_new_tokens=6) for i in range(3)]
            done = engine.run(reqs)
            for r in done:
                print(f"  request {r.uid}: generated {r.output}")
            print(f"engine stats: {engine.stats}")


if __name__ == "__main__":
    main()
