"""The paper's running example, end to end (Figure 1 / Section 3 case study).

Builds the naive Q8 plan ("notify when the stolen red car with plate 'MTT…'
passes the toll booth"), runs the Saṃsāra super-optimizer (semantic ->
logical -> physical, each phase empirically validated), prints the full
optimization report, and compares naive vs optimized FPS + accuracy on a
held-out stream.

  PYTHONPATH=src python examples/tollbooth_stream.py [--frames 512] [--query Q8]
      [--quick]   # tiny un-cached models + short streams (CI smoke)
"""
import argparse

from repro.core.superopt import SuperOptimizer
from repro.data import TollBoothStream, VolleyballStream
from repro.queries import QUERIES, get_query
from repro.streaming.pretrain import stream_models
from repro.streaming.runtime import StreamRuntime


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="Q8", choices=sorted(QUERIES))
    ap.add_argument("--frames", type=int, default=512)
    ap.add_argument("--eval-seed", type=int, default=999)
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short streams: smoke-run in seconds")
    args = ap.parse_args()

    if args.quick:
        args.frames = min(args.frames, 64)
    ctx = stream_models(quick=args.quick)

    query = get_query(args.query)
    if query.dataset == "tollbooth":
        stream_factory = lambda seed: TollBoothStream(seed=seed)  # noqa: E731
    else:
        stream_factory = lambda seed: VolleyballStream(seed=seed)  # noqa: E731

    print(f"\n=== optimizing {query.qid}: {query.description} ===")
    opt = SuperOptimizer(ctx, val_frames=48 if args.quick else 384)
    plan, report = opt.optimize(query, stream_factory)
    print(report.describe())

    print(f"\n=== measuring on a held-out stream ({args.frames} frames) ===")
    naive = StreamRuntime(query.naive_plan(), ctx).run(
        stream_factory(args.eval_seed), args.frames)
    optim = StreamRuntime(plan, ctx).run(
        stream_factory(args.eval_seed), args.frames)
    acc_n = query.evaluate(naive)
    acc_o = query.evaluate(optim)
    print(f"naive:     {naive.fps:7.2f} FPS  accuracy={acc_n:.3f}  "
          f"MLLM frames={naive.mllm_frames}/{naive.n_frames}")
    print(f"optimized: {optim.fps:7.2f} FPS  accuracy={acc_o:.3f}  "
          f"MLLM frames={optim.mllm_frames}/{optim.n_frames}")
    print(f"speedup:   {optim.fps/naive.fps:.2f}x  "
          f"(paper claims ~9-10x on this query class)")


if __name__ == "__main__":
    main()
