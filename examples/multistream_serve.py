"""Cross-stream shared-MLLM serving (the many-queries/many-feeds story).

Stands up K concurrent feeds — three tollbooth cameras with different
traffic seeds plus a volleyball court — each carrying its own query set.
The ``SharingTreePlanner`` factors every feed's plans into sharing groups
(note the global common prefix across the whole workload is *empty*: the
tollbooth and volleyball sources already diverge, yet per-stream subsets
still share), and one ``SharedExtractServer`` serves every group's
union-task extracts via coalesced, shape-bucketed batched forwards.

Compares against one independent ``StreamRuntime`` per (feed, query):
identical per-query answers, strictly fewer jitted model invocations.

  PYTHONPATH=src python examples/multistream_serve.py [--frames 256]
"""
import argparse

from repro.data import TollBoothStream, VolleyballStream
from repro.queries import get_query
from repro.scheduler import Feed, MultiStreamRuntime, SharingTreePlanner
from repro.streaming import MLLMExtractOp, StreamRuntime
from repro.streaming.pretrain import stream_models

FEEDS = (
    ("tb-north", "tollbooth", 1234, ("Q2", "Q6", "Q8")),
    ("tb-south", "tollbooth", 4321, ("Q1", "Q5")),
    ("tb-east", "tollbooth", 2025, ("Q3", "Q9")),
    ("court-1", "volleyball", 1234, ("Q12", "Q13")),
)


def _make_stream(dataset: str, seed: int):
    if dataset == "tollbooth":
        return TollBoothStream(seed=seed)
    return VolleyballStream(seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=256,
                    help="frames per feed")
    ap.add_argument("--quick", action="store_true",
                    help="tiny models + short streams: smoke-run in seconds")
    args = ap.parse_args()

    if args.quick:
        args.frames = min(args.frames, 48)
    ctx = stream_models(quick=args.quick)

    print("\n=== sharing tree over the full workload "
          "(global common prefix: empty) ===")
    all_plans = [get_query(qid).naive_plan()
                 for _, _, _, qids in FEEDS for qid in qids]
    forest = SharingTreePlanner().plan(all_plans)
    print(forest.describe())
    for note in forest.notes:
        print(f"  [planner] {note}")

    feeds = [Feed(name, _make_stream(ds, seed),
                  [get_query(qid).naive_plan() for qid in qids])
             for name, ds, seed, qids in FEEDS]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16)

    print(f"\n=== shared serving: {len(feeds)} feeds × "
          f"{args.frames} frames ===")
    shared = ms.run(args.frames)

    print(f"=== independent execution "
          f"({shared.n_queries} runtimes) ===")
    indep = {}
    indep_wall = 0.0
    indep_forwards = 0
    for name, ds, seed, qids in FEEDS:
        for qid in qids:
            plan = get_query(qid).naive_plan()
            rt = StreamRuntime(plan, ctx, micro_batch=16)
            res = rt.run(_make_stream(ds, seed), args.frames)
            indep[(name, qid)] = res
            indep_wall += res.wall_s
            indep_forwards += sum(op.forwards for op in plan.ops
                                  if isinstance(op, MLLMExtractOp))

    print(f"\n{'feed':<10} {'query':<6} {'acc(shared)':>12} "
          f"{'acc(indep)':>11} exact")
    for name, _, _, qids in FEEDS:
        for qid in qids:
            sq = shared.feeds[name].per_query[qid]
            iq = indep[(name, qid)]
            a, b = get_query(qid).evaluate(sq), get_query(qid).evaluate(iq)
            same = sq.outputs == iq.outputs \
                and sq.window_results == iq.window_results
            print(f"{name:<10} {qid:<6} {a:>12.3f} {b:>11.3f} "
                  f"{'yes' if same else 'NO'}")

    st = shared.server_stats
    indep_fps = shared.n_queries * args.frames / indep_wall
    print(f"\nshared:      {shared.fps:8.2f} query-frames/s  "
          f"forwards={st['forwards']} "
          f"(coalesced batches={st['coalesced_batches']}, "
          f"padding={st['padded_frames']}/{st['frames'] + st['padded_frames']}"
          " frames)")
    print(f"pipelining:  {st['dispatches']} dispatches, "
          f"{st['max_inflight_seen']} forwards in flight at peak, "
          f"staging reuse {st['staging_reused']}/"
          f"{st['staging_reused'] + st['staging_allocated']}"
          f" (+{st['staging_skipped']} exact-fit skips)")
    print(f"independent: {indep_fps:8.2f} query-frames/s  "
          f"forwards={indep_forwards}")
    print(f"forward reduction: {1 - st['forwards'] / indep_forwards:.1%}   "
          f"aggregate speedup: {indep_wall / shared.wall_s:.2f}x")


if __name__ == "__main__":
    main()
