"""Dry-run spec construction tests (no 512-device compile — pure shapes).

The actual lower+compile of all 40 cells × 2 meshes runs via
``python -m repro.launch.dryrun --all --both-meshes`` (reports/dryrun/);
these tests pin the *spec* layer: abstract inputs, shardings, rules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding

from repro.common.config import SHAPE_CELLS, applicable_cells
from repro.common.sharding import mesh_scope, rules_scope
from repro.configs import ASSIGNED, get_config
from repro.launch.specs import cell_rules, cell_spec, quantized_opt


@pytest.fixture(scope="module")
def mesh22():
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def test_cell_rules_long_context():
    cfg = get_config("jamba-1.5-large-398b")
    rules = cell_rules(cfg, SHAPE_CELLS["long_500k"])
    assert rules == {"batch": None, "kv_seq": ("data",)}
    assert cell_rules(cfg, SHAPE_CELLS["train_4k"]) == {}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_cell_specs_build_for_all_cells(mesh22, arch):
    """Every applicable (arch × shape) builds abstract args + shardings."""
    cfg = get_config(arch)
    with mesh_scope(mesh22):
        for cell in applicable_cells(cfg):
            spec = cell_spec(cfg, cell, mesh22)
            assert spec.step_kind == SHAPE_CELLS[cell].kind
            leaves = jax.tree_util.tree_leaves(spec.args)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
            shard_leaves = jax.tree_util.tree_leaves(
                spec.in_shardings,
                is_leaf=lambda x: isinstance(x, NamedSharding))
            assert all(isinstance(s, NamedSharding) for s in shard_leaves)
            # sharding tree must mirror the args tree leaf-for-leaf
            assert len(shard_leaves) == len(leaves)


def test_train_cell_has_optimizer_state_and_donation(mesh22):
    cfg = get_config("gemma2-2b")
    with mesh_scope(mesh22):
        spec = cell_spec(cfg, "train_4k", mesh22)
    params, opt_state, batch = spec.args
    assert "moments" in opt_state and "step" in opt_state
    assert spec.donate == (0, 1)
    assert batch["tokens"].shape == (256, 4096)


def test_decode_cell_shapes(mesh22):
    cfg = get_config("phi3-mini-3.8b")
    with mesh_scope(mesh22):
        spec = cell_spec(cfg, "decode_32k", mesh22)
    params, tokens, cache, cur = spec.args
    assert tokens.shape == (128, 1)
    k_leaf = cache["layers"]["i0"]["k"]
    assert k_leaf.shape == (32, 128, 32768, 32, 96)  # (L, B, S, Hkv, D)
    assert spec.donate == (2,)


def test_quantized_opt_selection():
    assert quantized_opt(get_config("jamba-1.5-large-398b"))
    assert quantized_opt(get_config("qwen3-moe-235b-a22b"))
    assert not quantized_opt(get_config("gemma2-2b"))
    assert not quantized_opt(get_config("mamba2-130m"))


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = f32[8,128]{1,0} all-gather(f32[1,128]{1,0} %p), dimensions={0}
  %ar.1 = bf16[256]{0} all-reduce(bf16[256]{0} %x), to_apply=%sum
  %cp = f32[4,4]{1,0} collective-permute(f32[4,4]{1,0} %y)
  %other = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 1 * 128 * 4
    assert out["all-reduce"] == 256 * 2
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"] + \
        out["collective-permute"]
