"""Fused prefix execution: bitwise identity, planner integration, and the
calibrated physical-phase decision.

The load-bearing claim is the first pair of tests: a ``FusedPrefixOp`` —
one device pass per micro-batch — is *bitwise* interchangeable with the
unfused operator chain it replaces (kept rows, transformed frames, and
the semantic-gate signature), across random chains, shapes, dtypes, and
micro-batch sizes, including Skip's stateful carry across batches.  The
randomized sweep always runs; the hypothesis property (shrinking,
adversarial draws) additionally runs where hypothesis is installed.
"""
import copy

import numpy as np
import pytest

from repro.streaming.fused import FusedPrefixOp, fusable_segment
from repro.streaming.operators import (
    CheapColorFilterOp,
    CropOp,
    DetectOp,
    FusedPreprocessOp,
    MLLMExtractOp,
    SkipOp,
    SourceOp,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

#: frame geometries the random chains draw from
_HWS = [(128, 256), (64, 128)]
_ROIS = {(128, 256): [None, (0, 0, 64, 128), (32, 96, 32, 64)],
         (64, 128): [None, (0, 0, 32, 64)]}
_CROPS = {(128, 256): [(0, 0, 128, 256), (64, 0, 64, 256),
                       (32, 128, 64, 128)],
          (64, 128): [(0, 0, 64, 128), (32, 0, 32, 128), (16, 64, 32, 64)]}


def _draw_chain(pick, hw):
    """A random fusable chain (>= 2 ops) for (3, H, W) frames; ``pick``
    chooses one element of a list (hypothesis draw or seeded rng)."""
    ops = []
    if pick([False, True]):
        ops.append(SkipOp())
    for _ in range(pick([0, 1, 2])):
        ops.append(CheapColorFilterOp(color=pick(["red", "blue"]),
                                      min_frac=pick([0.0, 0.001, 0.01]),
                                      roi=pick(_ROIS[hw])))
    if pick([False, True]):
        ops.append(CropOp(region=pick(_CROPS[hw])))
    if pick([False, True]):
        ch, cw = ops[-1].region[2:] if ops and isinstance(ops[-1], CropOp) \
            else hw
        crop = pick([(0, 0, ch, cw), (ch // 2, 0, ch // 2, cw),
                     (ch // 4, cw // 4, ch // 2, cw // 2)])
        factor = pick([f for f in (1, 2, 4)
                       if crop[2] % f == 0 and crop[3] % f == 0])
        ops.append(FusedPreprocessOp(crop=crop, factor=factor,
                                     grey=pick([False, True])))
    if pick([False, True]):
        ops.append(DetectOp(threshold=pick([0.0, 0.3, 0.5, 0.9])))
    if len(ops) < 2:
        ops = [SkipOp(), CropOp(region=_CROPS[hw][1])] + ops
    assert fusable_segment(ops)
    return ops


def _run_unfused(ops, batches):
    """The runtime's chain walk: stop a batch early once it is empty."""
    outs = []
    for fr in batches:
        b = {"frames": fr, "idx": np.arange(fr.shape[0])}
        for o in ops:
            if b["frames"].shape[0] == 0:
                break
            b = o.process(b)
        outs.append(b)
    return outs


def _check_fused_equals_unfused(stream_ctx, pick, hw, dtype, seed):
    """One example: random chain + 3 stateful micro-batches, fused vs
    unfused bitwise on rows, frames, and the gate signature."""
    from repro.semantic.signature import TemporalSignature

    ops = _draw_chain(pick, hw)
    rng = np.random.RandomState(seed)
    batches = []
    for _ in range(3):                         # skip state carries across
        n = pick(list(range(1, 13)))
        fr = rng.randint(0, 256, (n, 3) + hw, np.uint8)
        for i in range(1, n):                  # repeated frames: Skip drops
            if pick([False, True]):
                fr[i] = fr[i - 1]
        batches.append(fr.astype(dtype))

    unfused = [copy.deepcopy(o) for o in ops]
    for o in unfused:
        o.open(stream_ctx)
        o.reset()
    fused = FusedPrefixOp(stage_ops=tuple(copy.deepcopy(o) for o in ops),
                          sig=True)
    fused.open(stream_ctx)
    fused.reset()

    sigfn = TemporalSignature()
    for bu, fr in zip(_run_unfused(unfused, batches), batches):
        bf = fused.process({"frames": fr, "idx": np.arange(fr.shape[0])})
        feats, emb = bf.pop("_sig")
        assert np.array_equal(bf["idx"], bu["idx"])
        if bu["idx"].shape[0] == 0:
            # the runtime stops an emptied batch mid-chain, so the
            # unfused frames may still be untransformed; nothing
            # downstream ever observes them — only emptiness matters
            assert feats.shape[0] == 0 and emb.shape[0] == 0
            continue
        assert bf["frames"].dtype == bu["frames"].dtype
        assert np.array_equal(bf["frames"], bu["frames"])
        # the fused signature (computed on the full batch, then masked)
        # is bitwise the gate's own signature of the surviving frames
        ref_feats, ref_emb = sigfn.features(bu["frames"])
        assert np.array_equal(feats, np.asarray(ref_feats))
        assert np.array_equal(emb, np.asarray(ref_emb))
        # per-stage attribution covers every member op, monotone rows
        assert [s[0] for s in fused.last_stage_counts] == \
            [o.name for o in ops]
        rows = [fr.shape[0]] + [s[2] for s in fused.last_stage_counts]
        assert all(a >= b for a, b in zip(rows, rows[1:]))


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(6))
def test_fused_prefix_bitwise_equals_unfused_chain(stream_ctx, seed):
    rng = np.random.RandomState(1000 + seed)
    pick = lambda opts: opts[rng.randint(len(opts))]  # noqa: E731
    hw = _HWS[seed % len(_HWS)]
    dtype = [np.uint8, np.float32][seed % 2]
    _check_fused_equals_unfused(stream_ctx, pick, hw, dtype, seed)


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @given(data=st.data())
    @settings(max_examples=10, deadline=None)
    def test_fused_prefix_bitwise_property(stream_ctx, data):
        pick = lambda opts: data.draw(st.sampled_from(opts))  # noqa: E731
        hw = data.draw(st.sampled_from(_HWS))
        dtype = data.draw(st.sampled_from([np.uint8, np.float32]))
        seed = data.draw(st.integers(0, 2**31 - 1))
        _check_fused_equals_unfused(stream_ctx, pick, hw, dtype, seed)


@pytest.mark.slow
def test_fused_runtime_matches_unfused_bitwise_with_spans(stream_ctx):
    """MultiStreamRuntime drives a fused plan to the same outputs as the
    unfused plan, emitting ``prefix:fused`` spans + per-stage gauges."""
    import dataclasses

    from repro.data import TollBoothStream
    from repro.obs import Observability
    from repro.queries import get_query
    from repro.scheduler import Feed, MultiStreamRuntime

    def prefix_ops():
        return [SkipOp(), CheapColorFilterOp(color="red", min_frac=0.0),
                FusedPreprocessOp(crop=(64, 0, 64, 256), factor=2),
                DetectOp(threshold=0.1)]

    def plan(fuse):
        p = get_query("Q2").naive_plan()
        ops = prefix_ops()
        if fuse:
            ops = [FusedPrefixOp(stage_ops=tuple(ops), sig=True)]
        for op in ops:          # each lands immediately before the extract
            p.insert_before(MLLMExtractOp, op)
        return p

    def run(fuse, obs=None):
        ctx = stream_ctx if obs is None \
            else dataclasses.replace(stream_ctx, obs=obs)
        ms = MultiStreamRuntime(
            [Feed("tb", TollBoothStream(seed=3, car_rate=0.2),
                  [plan(fuse)])],
            ctx, micro_batch=16)
        return ms.run(48)

    obs = Observability(slo_target_ms=10_000.0)
    base = run(False)
    fused = run(True, obs=obs)
    q = "Q2"
    assert fused.feeds["tb"].per_query[q].outputs == \
        base.feeds["tb"].per_query[q].outputs
    assert fused.feeds["tb"].per_query[q].window_results == \
        base.feeds["tb"].per_query[q].window_results
    # one prefix:fused span per micro-batch instead of one per member op
    names = [e["name"] for e in obs.tracer.events() if e["cat"] == "prefix"]
    assert "prefix:fused" in names
    member = {f"prefix:{o.name}" for o in prefix_ops()}
    assert not member & set(names)
    # per-stage attribution gauges cover all four member stages (op
    # names may themselves contain '/', so strip the fixed ends)
    stages = {k[len("prefix_fused/tb/"):].rsplit("/", 1)[0]
              for k in obs.metrics.snapshot()["gauges"]
              if k.startswith("prefix_fused/tb/")}
    assert stages == {o.name for o in prefix_ops()}


@pytest.mark.slow
def test_physical_refuses_fusion_when_calibration_loses(stream_ctx):
    """On a sparse stream Skip kills nearly every row up front, so the
    unfused chain is far cheaper than one full-batch fused pass — the
    physical phase must measure that and keep the plan unfused."""
    from repro.core.costs import CostCatalog
    from repro.core.physical import PhysicalOptimizer
    from repro.data import TollBoothStream
    from repro.queries import get_query

    plan = get_query("Q2").naive_plan()
    for op in [SkipOp(), CheapColorFilterOp(color="red"),
               FusedPreprocessOp(crop=(64, 0, 64, 256), factor=2),
               DetectOp(threshold=0.5)]:
        plan.insert_before(MLLMExtractOp, op)
    before = [o.name for o in plan.ops]
    # default car_rate=0.009: almost every frame is static background
    sample = TollBoothStream(seed=404).batch(64)[0]
    opt = PhysicalOptimizer(stream_ctx)
    report = {"decisions": []}
    opt._fuse_prefix(plan, report, CostCatalog(), None, sample)
    info = report["fused_prefix"]
    assert info["fused"] is False
    assert info["fused_us"] > info["unfused_us"]
    assert [o.name for o in plan.ops] == before
    assert not any(isinstance(o, FusedPrefixOp) for o in plan.ops)


def test_fusable_segment_rules():
    ok = [SkipOp(), CheapColorFilterOp(color="red"),
          FusedPreprocessOp(crop=(0, 0, 128, 256), factor=2),
          DetectOp()]
    assert fusable_segment(ok)
    assert not fusable_segment([])
    assert not fusable_segment([CropOp(region=(0, 0, 64, 256)), SkipOp()])
    assert not fusable_segment([DetectOp(), CropOp(region=(0, 0, 64, 256))])
    assert not fusable_segment([SkipOp(), SourceOp()])


def test_unfuse_roundtrip_and_bucket_expansion():
    from repro.scheduler.sharing_tree import extract_bucket

    ops = [SkipOp(), CropOp(region=(64, 0, 64, 256)),
           FusedPreprocessOp(crop=(0, 0, 64, 256), factor=2), DetectOp()]
    fop = FusedPrefixOp(stage_ops=tuple(ops), sig=True)
    # unfuse() rebuilds equivalent fresh descriptors
    assert [o.signature() for o in fop.unfuse()] == \
        [o.signature() for o in ops]
    # the op's own signature is hashable (planner share keys, dicts)
    hash(fop.signature())
    # the server coalescing bucket sees through the fusion
    ex = MLLMExtractOp(tasks=("color",), model="small")
    assert extract_bucket([fop, ex]) == extract_bucket(list(ops) + [ex])
    assert extract_bucket([fop, ex]) == ("small", (3, 32, 128))
