"""End-to-end behaviour tests for the Saṃsāra system.

Uses tiny (non-cached) models so the suite stays CPU-fast; the full-quality
numbers live in benchmarks/ (reports/samsara_bench.log).
"""
import numpy as np
import pytest

from repro.core.superopt import SuperOptimizer
from repro.data import TollBoothStream, VolleyballStream
from repro.queries import get_query
from repro.streaming.operators import MLLMExtractOp, SkipOp
from repro.streaming.pretrain import train_stream_models
from repro.streaming.runtime import StreamRuntime


@pytest.fixture(scope="module")
def ctx():
    # tiny training: enough for the plumbing; accuracy is benchmarks' job
    return train_stream_models(steps_mllm=40, steps_small=20, steps_det=30,
                               cache_dir=None, verbose=False)


def test_naive_plan_runs_and_extracts(ctx):
    q = get_query("Q2")
    rt = StreamRuntime(q.naive_plan(), ctx, micro_batch=8)
    res = rt.run(TollBoothStream(seed=11), 64)
    assert res.n_frames == 64
    assert res.mllm_frames == 64          # naive: every frame through MLLM
    assert res.fps > 0
    assert all("color" in o for o in res.outputs)


def test_superoptimizer_reduces_mllm_load(ctx):
    q = get_query("Q8")
    sf = lambda seed: TollBoothStream(seed=seed)  # noqa: E731
    opt = SuperOptimizer(ctx, val_frames=64)
    plan, report = opt.optimize(q, sf, phases=("semantic",))
    naive = StreamRuntime(q.naive_plan(), ctx).run(sf(99), 128)
    optim = StreamRuntime(plan, ctx).run(sf(99), 128)
    # The invariant is MLLM-load reduction; wall FPS only wins when the
    # extractor is expensive (this fixture's 40-step model is toy-cheap —
    # the real comparison lives in benchmarks/samsara_bench).
    assert optim.mllm_frames < naive.mllm_frames
    # report artifacts exist
    assert report.phases[0]["knowledge"]
    assert any("SELECT Skip" in l for l in report.phases[0]["selection_log"])


def test_all_phases_produce_valid_plans(ctx):
    q = get_query("Q6")
    sf = lambda seed: TollBoothStream(seed=seed)  # noqa: E731
    opt = SuperOptimizer(ctx, val_frames=64)
    plan, report = opt.optimize(q, sf)
    assert plan.index_of(MLLMExtractOp) is not None
    # Q6 needs color -> greyscale must NOT appear
    assert "greyscale" not in plan.describe()
    res = StreamRuntime(plan, ctx).run(sf(5), 128)
    acc = q.evaluate(res)
    assert 0.0 <= acc <= 1.0


def test_volleyball_query_runs(ctx):
    q = get_query("Q13")
    rt = StreamRuntime(q.naive_plan(), ctx, micro_batch=8)
    res = rt.run(VolleyballStream(seed=3), 300)
    assert res.window_results, "tumbling windows must close"
    assert all(w["kind"] == "top3_actions" for w in res.window_results)


def test_streaming_snapshot_restore(ctx):
    """Aligned checkpoint: snapshot mid-stream, restore, results identical."""
    q = get_query("Q2")
    sf = lambda: TollBoothStream(seed=21)  # noqa: E731
    opt_plan = q.naive_plan()
    opt_plan.insert_after_source(SkipOp(amount=3))
    rt = StreamRuntime(opt_plan, ctx, micro_batch=8)
    stream = sf()
    r1 = rt.run(stream, 64, warmup=0)
    snap = rt.snapshot()
    r2 = rt.run(stream, 64, warmup=0)

    # recover: fresh runtime, restore snapshot, replay from source offset
    plan2 = q.naive_plan()
    plan2.insert_after_source(SkipOp(amount=3))
    rt2 = StreamRuntime(plan2, ctx, micro_batch=8)
    rt2.restore(snap)
    stream2 = sf()
    stream2.batch(64)                      # replay source to offset 64
    r3 = rt2.run(stream2, 64, warmup=0)
    assert [o["idx"] for o in r2.outputs] == [o["idx"] for o in r3.outputs]


def test_adaptive_model_switching(ctx):
    op = MLLMExtractOp(tasks=("present", "color"), model="adaptive")
    op.open(ctx)
    frames = TollBoothStream(seed=2).batch(16)[0]
    batch = {"frames": frames.astype(np.float32) / 255.0 - 0.5,
             "idx": np.arange(16)}
    out = op.process(batch)
    assert "color" in out["attrs"]
    # low density -> pruned branch taken without error
    small = {"frames": batch["frames"][:2], "idx": np.arange(2)}
    for _ in range(6):
        op.process(small)
    assert op._density_ema < 0.35
