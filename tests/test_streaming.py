"""Streaming engine + super-optimizer unit tests (model-free where possible)."""
import numpy as np
import pytest

from repro.core.semantic import SemanticReasoner, extract_knowledge
from repro.data import TollBoothStream, VolleyballStream
from repro.queries import QUERIES, get_query
from repro.queries.catalog import car_passes
from repro.streaming.operators import (
    CheapColorFilterOp,
    CropOp,
    DownscaleOp,
    FilterOp,
    GreyscaleOp,
    MLLMExtractOp,
    OpContext,
    SinkOp,
    SkipOp,
    SourceOp,
    WindowAggOp,
)
from repro.streaming.plan import Plan


def batch_of(frames, start=0):
    return {"frames": frames, "idx": np.arange(start, start + len(frames))}


# ---------------------------------------------------------------------------
# data generators
# ---------------------------------------------------------------------------

def test_tollbooth_labels_consistent():
    tb = TollBoothStream(seed=1)
    frames, labels = tb.batch(400)
    assert frames.shape == (400, 3, 128, 256) and frames.dtype == np.uint8
    present = np.mean([l["car_present"] for l in labels])
    assert 0.2 < present < 0.8  # skip opportunity exists
    for l in labels:
        if l["car_readable"]:
            assert l["plate"] is not None and len(l["plate"]) == 6
        if l["stolen"]:
            assert l["color"] == "red" and l["plate"].startswith("MTT")


def test_tollbooth_deterministic_reset():
    tb = TollBoothStream(seed=5)
    f1, l1 = tb.batch(50)
    tb.reset()
    f2, l2 = tb.batch(50)
    np.testing.assert_array_equal(f1, f2)


def test_car_passes_grouping():
    tb = TollBoothStream(seed=2)
    _, labels = tb.batch(600)
    passes = car_passes(labels)
    assert len(passes) >= 1
    for p in passes:
        assert p["last"] >= p["first"]
        assert len(p["plate"]) == 6


def test_volleyball_actions():
    vb = VolleyballStream(seed=0)
    frames, labels = vb.batch(200)
    acts = set(l["action"] for l in labels)
    assert "spike" in acts and "idle" in acts


# ---------------------------------------------------------------------------
# operators
# ---------------------------------------------------------------------------

def test_crop_downscale_greyscale_shapes():
    f = np.random.randint(0, 255, (4, 3, 128, 256), np.uint8)
    b = CropOp(region=(64, 0, 64, 256)).process(batch_of(f))
    assert b["frames"].shape == (4, 3, 64, 256)
    b = DownscaleOp(factor=2).process(b)
    assert b["frames"].shape == (4, 3, 32, 128)
    b = GreyscaleOp().process(b)
    assert b["frames"].shape == (4, 3, 32, 128)
    # greyscale collapses channels to equal values
    np.testing.assert_allclose(b["frames"][:, 0], b["frames"][:, 1])


def test_skip_op_drops_static_frames():
    tb = TollBoothStream(seed=3, car_rate=0.0)  # never any car
    frames, _ = tb.batch(32)
    op = SkipOp(amount=3, threshold=0.02)
    op.open(OpContext())
    out = op.process(batch_of(frames))
    # static stream: all but the first few frames drop
    assert len(out["idx"]) <= 10


def test_skip_op_keeps_activity():
    tb = TollBoothStream(seed=4, car_rate=0.3)  # dense traffic
    frames, labels = tb.batch(64)
    op = SkipOp(amount=3, threshold=0.02)
    op.open(OpContext())
    out = op.process(batch_of(frames))
    assert len(out["idx"]) >= 16  # most activity kept


def test_cheap_color_filter():
    tb = TollBoothStream(seed=6, car_rate=0.05)
    frames, labels = tb.batch(300)
    op = CheapColorFilterOp(color="red", min_frac=0.008)
    op.open(OpContext())
    out = op.process(batch_of(frames))
    kept = set(int(i) for i in out["idx"])
    # every frame with a fully-visible red car must survive
    for i, l in enumerate(labels):
        if l["car_readable"] and l["color"] == "red":
            assert i in kept


def test_filter_predicates():
    attrs = {"color": np.array([0, 1, 0]),          # red, blue, red
             "plate": np.array([[12, 19, 19, 0, 0, 0],
                                [12, 19, 19, 0, 0, 0],
                                [0, 1, 2, 3, 4, 5]]),
             "present": np.array([1, 1, 1])}
    b = {"frames": np.zeros((3, 3, 8, 8), np.uint8), "idx": np.arange(3),
         "attrs": attrs}
    out = FilterOp(("and", ("eq", "color", "red"),
                    ("prefix", "plate", "MTT"))).process(b)
    assert list(out["idx"]) == [0]


def test_window_agg_tumbling():
    op = WindowAggOp(kind="top_color", window=10)
    colors = np.array([0] * 6 + [1] * 3)
    b = {"frames": np.zeros((9, 1, 1, 1)), "idx": np.arange(9),
         "attrs": {"color": colors}}
    out = op.process(b)
    assert "window_results" not in out  # window not closed yet
    b2 = {"frames": np.zeros((3, 1, 1, 1)), "idx": np.arange(10, 13),
          "attrs": {"color": np.array([1, 1, 1])}}
    out2 = op.process(b2)
    res = out2["window_results"][0]
    assert res["top_color"] == "red" and res["window"] == (0, 10)


def test_plan_validation_and_rewrites():
    plan = Plan([SourceOp(), MLLMExtractOp(tasks=("present",)), SinkOp()])
    plan.insert_after_source(SkipOp(amount=2))
    plan.insert_before(MLLMExtractOp, CropOp(region=(64, 0, 64, 256)))
    assert plan.index_of(SkipOp) == 1
    assert "skip" in plan.describe()
    with pytest.raises(AssertionError):
        Plan([SinkOp(), SourceOp()])


# ---------------------------------------------------------------------------
# semantic knowledge extraction (model-free)
# ---------------------------------------------------------------------------

def test_knowledge_extraction_tollbooth():
    tb = TollBoothStream(seed=7)
    frames, _ = tb.batch(256)
    know = extract_knowledge(frames, tb.metadata)
    assert 0.1 < know.empty_fraction < 0.9
    assert know.active_bbox is not None
    y0, x0, h, w = know.active_bbox
    assert y0 >= 32  # activity is in the road half, not the sky
    assert know.min_dwell >= 2
    assert any("empty" in f for f in know.facts)


def test_semantic_reasoner_rejects_greyscale_for_color_queries():
    # sparse-but-nonempty stream => clear skip/crop opportunity (an
    # all-empty sample makes the reasoner conservatively reject Skip:
    # min_dwell is unmeasurable without any observed object)
    tb = TollBoothStream(seed=8, car_rate=0.02)
    frames, _ = tb.batch(384)
    know = extract_knowledge(frames, tb.metadata)
    q8 = get_query("Q8")
    chosen, log = SemanticReasoner().select(know, q8)
    assert any("REJECT Greyscale" in l for l in log)
    kinds = {type(op).__name__ for op in chosen}
    assert "SkipOp" in kinds or "CropOp" in kinds
    assert "GreyscaleOp" not in kinds


def test_volleyball_knowledge_weaker_skip():
    vb = VolleyballStream(seed=0)
    frames, _ = vb.batch(256)
    know = extract_knowledge(frames, vb.metadata)
    # moving camera: most frames are active -> little skip opportunity
    assert know.empty_fraction < 0.3


def test_all_13_queries_defined():
    assert set(QUERIES) == {f"Q{i}" for i in range(1, 14)}
    for q in QUERIES.values():
        plan = q.naive_plan()
        assert plan.index_of(MLLMExtractOp) is not None
        assert q.dataset in ("tollbooth", "volleyball")
