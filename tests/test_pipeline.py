"""GPipe pipeline-parallelism test (multi-device CPU).

Needs >1 host device; running this file spawns a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 so the main pytest
process keeps its single-device view (per the dry-run brief).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.distribution import gpipe, PipelineConfig
devs = np.asarray(jax.devices()).reshape(4)
mesh = Mesh(devs, ('pod',))
W = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.3
def stage_fn(w, x):
    return jnp.tanh(x @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
pipe = gpipe(stage_fn, mesh, PipelineConfig(axis='pod', microbatches=4))
y = pipe(W, x)
ref = x
for i in range(4):
    ref = jnp.tanh(ref @ W[i])
err = float(jnp.max(jnp.abs(y - ref)))
assert err < 1e-6, err
print('PIPE_OK', err)
"""


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "PIPE_OK" in out.stdout, out.stderr[-2000:]


def test_bubble_fraction():
    from repro.distribution import PipelineConfig

    assert PipelineConfig(microbatches=4).bubble_fraction(2) == pytest.approx(
        1 / 5)
    assert PipelineConfig(microbatches=8).bubble_fraction(2) == pytest.approx(
        1 / 9)
