"""Fault-tolerant serving contract tests.

The chaos tier's promises, each pinned here:

* the fault schedule is **deterministic** — a pure function of
  (seed, site, feed, event, attempt), independent of interleaving;
* ``NULL_FAULTS`` is **inert** — a run with it is bitwise identical to a
  run without the faults package in the loop at all;
* faults the stack absorbs (transient forward errors, injected device
  latency, source stalls, corrupt deliveries cleared within the retry
  budget) leave every served answer **bitwise identical** to the
  fault-free run;
* faults it cannot absorb trip the feed's **circuit breaker**: the feed
  is quarantined (stale-served or dropped with exact accounting — served
  + degraded + dropped partitions the ingested frames, no frame served
  twice), the healthy fleet keeps its bitwise outputs, and a recovered
  feed replays from its last snapshot back to the exactly-once frontier;
* a genuinely stuck server **names the stuck work** instead of spinning
  (the ``ExtractStallError`` watchdog).
"""
import numpy as np
import pytest

from repro.data import TollBoothStream, VolleyballStream
from repro.faults import (
    CLOSED,
    HALF_OPEN,
    NULL_FAULTS,
    OPEN,
    CircuitBreaker,
    ExtractFaultError,
    ExtractStallError,
    FaultInjector,
    FaultRule,
    RetryPolicy,
    resolve_faults,
)
from repro.queries import get_query
from repro.scheduler import Feed, MultiStreamRuntime, SharedExtractServer
from repro.semantic import GateConfig, SemanticGate
from repro.streaming.operators import OpContext


@pytest.fixture(scope="module")
def ctx(stream_ctx):
    return stream_ctx


# ---------------------------------------------------------------------------
# schedule / injector unit tests (model-free)
# ---------------------------------------------------------------------------

def test_fault_rule_schedule_arithmetic():
    r = FaultRule(site="forward", kind="error", feed="a",
                  start=2, every=3, count=2)
    hits = [e for e in range(20) if r.matches("forward", "a", "big", e)]
    assert hits == [2, 5]                 # start, start+every, count-capped
    assert not r.matches("forward", "b", "big", 2)       # feed filter
    assert not r.matches("source", "a", "", 2)           # site filter
    rv = FaultRule(site="forward", kind="error", variant="small")
    assert rv.matches("forward", "x", "small", 0)
    assert not rv.matches("forward", "x", "big", 0)
    with pytest.raises(AssertionError):
        FaultRule(site="source", kind="error")           # kind/site mismatch


def test_injector_pure_and_deterministic():
    rules = [FaultRule(site="forward", kind="error", p=0.5, param=2)]
    a, b = FaultInjector(rules, seed=9), FaultInjector(rules, seed=9)
    # fault_at is pure: same (event, attempt) -> same answer, any order
    pattern = [a.fault_at("forward", "f", "big", e) for e in range(32)]
    assert pattern == [b.fault_at("forward", "f", "big", e)
                       for e in reversed(range(32))][::-1]
    assert any(p is not None for p in pattern)
    assert any(p is None for p in pattern)
    # a different seed draws a different p<1 pattern
    c = FaultInjector(rules, seed=10)
    assert pattern != [c.fault_at("forward", "f", "big", e)
                       for e in range(32)]
    # event counters are per (site, feed); peek does not consume
    assert a.peek_event("source", "f") == 0
    assert a.next_event("source", "f") == 0
    assert a.next_event("source", "f") == 1
    assert a.next_event("source", "g") == 0
    assert a.peek_event("source", "f") == 2
    # firing logs; fault_at never does
    a.fire("forward", "f", "big",
           next(e for e, p in enumerate(pattern) if p is not None))
    assert len(a.log) == 1 and a.log[0]["site"] == "forward"


def test_attempt_clearing_models_transient_faults():
    inj = FaultInjector([FaultRule(site="forward", kind="error",
                                   param=2)], seed=0)
    assert inj.fault_at("forward", "f", "big", 0, attempt=0) is not None
    assert inj.fault_at("forward", "f", "big", 0, attempt=1) is not None
    assert inj.fault_at("forward", "f", "big", 0, attempt=2) is None


def test_transport_corruption_detectable_and_reversible():
    inj = FaultInjector([FaultRule(site="source", kind="corrupt",
                                   param=1)], seed=0)
    frames = np.arange(2 * 3 * 4 * 4, dtype=np.uint8).reshape(2, 3, 4, 4)
    pristine = frames.copy()
    bad = inj.transport("f", frames, event=0, attempt=0)
    assert not inj.delivered_ok(bad)            # always detectable
    assert np.array_equal(frames, pristine)     # stream data untouched
    ok = inj.transport("f", frames, event=0, attempt=1)   # fault cleared
    assert inj.delivered_ok(ok)
    assert ok is frames                         # pristine, bitwise, free
    # float frames are poisoned in-dtype
    ff = np.ones((1, 3, 4, 4), np.float32)
    assert not inj.delivered_ok(inj.transport("f", ff, event=0))


def test_null_faults_inert_and_resolution_order():
    assert not NULL_FAULTS.enabled
    assert NULL_FAULTS.fault_at("forward", "f", "big", 0) is None
    assert NULL_FAULTS.next_event("source", "f") == 0
    assert NULL_FAULTS.next_event("source", "f") == 0    # stateless
    inj = FaultInjector(seed=1)
    assert resolve_faults(None, inj) is inj
    assert resolve_faults(inj, FaultInjector(seed=2)) is inj
    assert resolve_faults(None, None) is NULL_FAULTS


def test_retry_policy_backoff_is_exponential():
    rp = RetryPolicy(max_attempts=4, backoff_base=2)
    assert [rp.backoff_rounds(a) for a in (1, 2, 3)] == [2, 4, 8]


def test_circuit_breaker_lifecycle():
    br = CircuitBreaker(cooldown=2, max_cooldown=8)
    assert br.closed and br.state == CLOSED
    br.trip("ingest dead")
    br.trip("ingest dead")                       # idempotent while open
    assert br.state == OPEN and br.counters["trips"] == 1
    assert br.last_reason == "ingest dead"
    br.tick()
    assert br.state == OPEN                      # cooldown not elapsed
    br.tick()
    assert br.state == HALF_OPEN and br.should_probe
    br.probe_failed()
    assert br.state == OPEN and br.cooldown == 4     # doubled
    br.probe_failed()
    br.probe_failed()
    assert br.cooldown == 8                      # capped at max_cooldown
    for _ in range(br.cooldown):
        br.tick()
    assert br.state == HALF_OPEN
    br.close()
    assert br.closed and br.cooldown == 2        # reset on recovery
    assert br.counters["recoveries"] == 1


# ---------------------------------------------------------------------------
# server-level fault handling (models required)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.chaos
def test_server_retries_transient_forward_fault_bitwise(ctx):
    frames = TollBoothStream(seed=3).batch(4)[0].astype(np.float32)
    clean = SharedExtractServer(ctx, max_batch=8)
    want = clean.submit("big", frames, feed="a")
    clean.drain()

    inj = FaultInjector([FaultRule(site="forward", kind="error",
                                   param=1)], seed=0)
    srv = SharedExtractServer(ctx, max_batch=8, faults=inj)
    req = srv.submit("big", frames, feed="a")
    srv.drain()
    assert req.done and not req.failed
    assert srv.stats["forward_faults"] == 1
    assert srv.stats["retries"] == 1
    for task in ("present", "color", "plate"):
        assert np.array_equal(req.result[task], want.result[task])


@pytest.mark.slow
@pytest.mark.chaos
def test_server_exhausts_retry_budget_and_fails_request(ctx):
    inj = FaultInjector([FaultRule(site="forward", kind="error",
                                   feed="sick", param=99)], seed=0)
    srv = SharedExtractServer(ctx, max_batch=8, faults=inj,
                              retry=RetryPolicy(max_attempts=2))
    frames = TollBoothStream(seed=3).batch(2)[0].astype(np.float32)
    sick = srv.submit("big", frames, feed="sick")
    well = srv.submit("big", frames, feed="well")
    srv.drain()                       # terminates: the request goes terminal
    assert sick.failed and not sick.done
    with pytest.raises(ExtractFaultError):
        sick.result
    assert well.done and not well.failed
    assert srv.stats["retry_exhausted"] == 1
    assert srv.stats["forward_faults"] == 2          # both attempts
    assert srv.pending_requests() == 0               # counters settled


@pytest.mark.slow
@pytest.mark.chaos
def test_server_injected_latency_is_bitwise_and_clock_free(ctx):
    frames = TollBoothStream(seed=3).batch(3)[0].astype(np.float32)
    clean = SharedExtractServer(ctx, max_batch=8)
    want = clean.submit("big", frames, feed="a")
    clean.drain()

    inj = FaultInjector([FaultRule(site="forward", kind="latency",
                                   param=3)], seed=0)
    srv = SharedExtractServer(ctx, max_batch=8, faults=inj)
    req = srv.submit("big", frames, feed="a")
    srv.dispatch()
    # the completion is observed exactly param polls late
    assert srv.poll() == 0 and srv.poll() == 0 and srv.poll() == 0
    srv._inflight[0].block()
    assert srv.poll() == 1
    assert srv.stats["latency_faults"] == 1
    for task in ("present", "color", "plate"):
        assert np.array_equal(req.result[task], want.result[task])


def test_watchdog_names_stuck_work():
    # model-free: a queued request that can never launch (backoff pinned
    # into the far future) must be *named*, not spun on forever
    srv = SharedExtractServer(OpContext(), max_batch=8,
                              drain_timeout_s=0.0)
    req = srv.submit("big", np.zeros((2, 3, 8, 8), np.float32), feed="a")
    req.not_before = 10 ** 9
    with pytest.raises(ExtractStallError, match="feed='a'"):
        srv.drain()
    with pytest.raises(ExtractStallError, match="drain\\(\\)"):
        srv.drain()


# ---------------------------------------------------------------------------
# runtime-level chaos contracts (models required)
# ---------------------------------------------------------------------------

def _feeds():
    return [
        Feed("tb0", TollBoothStream(seed=42),
             [get_query("Q2").naive_plan()]),
        Feed("vb0", VolleyballStream(seed=5),
             [get_query("Q12").naive_plan()]),
    ]


def _outputs(res, feed):
    return {q: r.outputs for q, r in res.feeds[feed].per_query.items()}


@pytest.fixture(scope="module")
def plain48(ctx):
    """The fault-free reference run every chaos contract diffs against."""
    return MultiStreamRuntime(_feeds(), ctx, micro_batch=8).run(48)


@pytest.mark.slow
@pytest.mark.chaos
def test_null_faults_run_bitwise_identical(ctx, plain48):
    res = MultiStreamRuntime(_feeds(), ctx, micro_batch=8,
                             faults=NULL_FAULTS).run(48)
    for f in ("tb0", "vb0"):
        assert _outputs(res, f) == _outputs(plain48, f)
        for q, r in res.feeds[f].per_query.items():
            assert r.window_results == \
                plain48.feeds[f].per_query[q].window_results
        assert res.feeds[f].degraded == 0 and res.feeds[f].dropped == 0


@pytest.mark.slow
@pytest.mark.chaos
def test_absorbed_faults_keep_outputs_bitwise(ctx, plain48):
    # transient forward errors (cleared on retry), injected device
    # latency, source stalls and recoverable corrupt deliveries — all
    # absorbed, all bitwise
    inj = FaultInjector(seed=3, rules=[
        FaultRule(site="forward", kind="error", feed="tb0",
                  start=1, every=3, count=2, param=1),
        FaultRule(site="forward", kind="latency", start=0, every=4,
                  count=3, param=2),
        FaultRule(site="source", kind="stall", feed="vb0",
                  start=1, every=2, count=3),
        FaultRule(site="source", kind="corrupt", feed="vb0",
                  start=4, every=3, count=2, param=1),
    ])
    res = MultiStreamRuntime(_feeds(), ctx, micro_batch=8,
                             faults=inj).run(48)
    for f in ("tb0", "vb0"):
        assert _outputs(res, f) == _outputs(plain48, f)
        assert res.feeds[f].served == 48
        assert res.feeds[f].breaker["trips"] == 0
    assert res.server_stats["retries"] >= 1
    assert res.server_stats["latency_faults"] >= 1
    assert len(inj.log) >= 4
    # rerunning the same schedule reproduces the same fault log
    inj2 = FaultInjector(seed=3, rules=list(inj.rules))
    res2 = MultiStreamRuntime(_feeds(), ctx, micro_batch=8,
                              faults=inj2).run(48)
    assert inj2.log == inj.log
    for f in ("tb0", "vb0"):
        assert _outputs(res2, f) == _outputs(res, f)


@pytest.mark.slow
@pytest.mark.chaos
def test_dead_source_trips_breaker_with_exact_accounting(ctx, plain48):
    inj = FaultInjector(seed=11, rules=[
        FaultRule(site="source", kind="corrupt", feed="tb0",
                  start=1, every=1, param=99)])
    res = MultiStreamRuntime(_feeds(), ctx, micro_batch=8,
                             faults=inj).run(48)
    tb = res.feeds["tb0"]
    # quarantined: the breaker tripped, the run still terminated
    assert tb.breaker["trips"] == 1
    # exact partition, nothing served twice, nothing silently lost
    assert tb.served + tb.degraded + tb.dropped == 48
    assert tb.served > 0                  # the pre-fault prefix was served
    served_idx = sorted(r["idx"] for r in
                        res.feeds["tb0"].per_query["Q2"].outputs)
    assert len(served_idx) == len(set(served_idx)) == tb.served
    # the served prefix is bitwise the fault-free prefix
    want = _outputs(plain48, "tb0")
    got = _outputs(res, "tb0")
    for q in want:
        assert got[q] == want[q][:len(got[q])]
    # the healthy feed never noticed
    assert _outputs(res, "vb0") == _outputs(plain48, "vb0")
    assert res.feeds["vb0"].served == 48
    assert res.feeds["vb0"].breaker["trips"] == 0


@pytest.mark.slow
@pytest.mark.chaos
def test_bounded_outage_probes_replays_and_recovers(ctx, plain48):
    # corruption spans two source events, then clears: the breaker must
    # probe after cooldown, replay from the snapshot and serve the rest
    # of the stream bitwise
    inj = FaultInjector(seed=11, rules=[
        FaultRule(site="source", kind="corrupt", feed="tb0",
                  start=1, every=1, count=2, param=99)])
    res = MultiStreamRuntime(_feeds(), ctx, micro_batch=8, faults=inj,
                             breaker_cooldown=1).run(48)
    tb = res.feeds["tb0"]
    assert tb.breaker["trips"] == 1
    assert tb.breaker["recoveries"] >= 1
    assert tb.served + tb.degraded + tb.dropped == 48
    assert tb.dropped + tb.degraded <= 24      # outage, not the whole run
    # every served answer (before and after the outage) matches the
    # fault-free run at the same frame index; no frame appears twice
    want = {(q, r["idx"]): r for q, outs in _outputs(plain48,
                                                     "tb0").items()
            for r in outs}
    seen = set()
    for q, outs in _outputs(res, "tb0").items():
        for r in outs:
            assert want[(q, r["idx"])] == r
            assert (q, r["idx"]) not in seen
            seen.add((q, r["idx"]))
    assert _outputs(res, "vb0") == _outputs(plain48, "vb0")


@pytest.mark.slow
@pytest.mark.chaos
def test_gated_outage_serves_stale_keyframe_answers(ctx):
    # with the semantic gate live, a quarantined feed degrades to its
    # newest keyframe answer — marked stale, never silently wrong
    gate = SemanticGate(GateConfig(threshold=0.12,
                                   revalidate_every=1000))
    inj = FaultInjector(seed=7, rules=[
        FaultRule(site="source", kind="corrupt", feed="tb0",
                  start=2, every=1, param=99)])
    res = MultiStreamRuntime(_feeds(), ctx, micro_batch=8, faults=inj,
                             gate=gate, pipelined=False).run(48)
    tb = res.feeds["tb0"]
    assert tb.served + tb.degraded + tb.dropped == 48
    assert tb.degraded > 0
    assert len(tb.degraded_records) == tb.degraded
    for d in tb.degraded_records:
        assert d["stale"] is True and d["answer"]
    # degraded frames never leak into the served outputs
    served_idx = {r["idx"] for r in
                  res.feeds["tb0"].per_query["Q2"].outputs}
    assert served_idx.isdisjoint(d["idx"] for d in tb.degraded_records)
