"""Fleet optimizer + cost catalog tests.

Covers the joint-optimization contract: (a) the common phase interface —
every phase optimizer drives through ``run(plan, pctx)`` and the
orchestrator reports per-phase wall clocks and calibrated op timings;
(b) the ``CostCatalog`` — chain calibration stamps measured
``cost_us``/``pass_rate`` (zero is a legitimate measurement: the
``< 0`` sentinel), persistence round-trips exactly; (c) canonicalization —
safe-join yields the least aggressive parameterization and identical
signatures; (d) the fleet result — sharing survives joint optimization
and execution through the shared runtimes stays bitwise identical to solo
runs of each query's own fleet plan.
"""
import pytest

from repro.core.costs import CostCatalog
from repro.core.fleet import (FleetOptimizer, FleetQuery, joined_prefix,
                              safe_join)
from repro.core.superopt import SuperOptimizer
from repro.data import TollBoothStream, VolleyballStream
from repro.queries import get_query
from repro.scheduler.sharing_tree import (EXTRACT_DISPATCH_US,
                                          SharingTreePlanner, chain_cost_us,
                                          chain_reach, coalescing_saving_us,
                                          extract_bucket, op_cost_us,
                                          uncalibrated)
from repro.streaming.operators import (
    CheapColorFilterOp,
    CropOp,
    DownscaleOp,
    FusedPreprocessOp,
    MLLMExtractOp,
    SkipOp,
    SourceOp,
)
from repro.streaming.plan import Plan
from repro.streaming.runtime import StreamRuntime


@pytest.fixture(scope="module")
def ctx(stream_ctx):
    return stream_ctx


# ---------------------------------------------------------------------------
# (a) cost sentinel + selectivity-aware chain cost (model-free)
# ---------------------------------------------------------------------------

def test_zero_cost_is_a_measurement_not_a_fallback():
    op = SkipOp()
    assert op.cost_us < 0                     # uncalibrated sentinel
    assert op_cost_us(op) == 30.0             # static default
    op.cost_us = 0.0                          # measured free op
    assert op_cost_us(op) == 0.0              # NOT replaced by the default


def test_catalog_backs_unstamped_ops_before_static_defaults():
    cat = CostCatalog()
    cat.record("SkipOp", 7.5, direct=True)
    cat.record("mllm[small]", 99.0, direct=True)
    assert op_cost_us(SkipOp(), cat) == 7.5
    assert op_cost_us(MLLMExtractOp(model="small"), cat) == 99.0
    assert op_cost_us(MLLMExtractOp(model="big"), cat) == 1200.0  # static


def test_chain_cost_discounts_through_measured_pass_rates():
    skip, mllm = SkipOp(), MLLMExtractOp()
    skip.cost_us, skip.pass_rate = 10.0, 0.25
    mllm.cost_us = 1000.0
    # the extract is only reached by the 25% of frames skip lets through
    assert chain_cost_us([skip, mllm]) == pytest.approx(10.0 + 250.0)
    assert uncalibrated([skip, mllm]) == []
    fresh = MLLMExtractOp()
    assert uncalibrated([skip, fresh]) == [fresh.name]


def test_chain_cost_tail_seeded_by_prefix_reach():
    # a tail behind a selective shared prefix is discounted exactly like
    # the same ops inside one independent chain — no boundary asymmetry
    skip, mllm = SkipOp(), MLLMExtractOp()
    skip.cost_us, skip.pass_rate = 10.0, 0.1
    mllm.cost_us = 1000.0
    whole = chain_cost_us([skip, mllm])
    split = chain_cost_us([skip]) + chain_cost_us(
        [mllm], reach=chain_reach([skip]))
    assert split == pytest.approx(whole)
    # planner level: sharing a selective prefix must report the saving
    stamps = {"SourceOp": (0.0, 1.0), "SkipOp": (10.0, 0.1),
              "MLLMExtractOp": (1000.0, 1.0), "FilterOp": (5.0, 0.5),
              "WindowAggOp": (1.0, 1.0), "SinkOp": (1.0, 1.0)}
    p1, p2 = get_query("Q2").naive_plan(), get_query("Q6").naive_plan()
    for p in (p1, p2):
        p.insert_after_source(SkipOp(amount=3))
        for op in p.ops:
            op.cost_us, op.pass_rate = stamps[type(op).__name__]
    (group,) = SharingTreePlanner().plan([p1, p2]).streams["tollbooth"]
    assert group.is_shared
    # prefix Source->Skip->MLLM->Filter costs 110.5 and is saved once;
    # post-prefix sinks/windows run at reach 0.05 either way
    assert group.saving_us == pytest.approx(110.5, rel=1e-6)


def test_unstamped_ops_read_selectivity_from_catalog():
    cat = CostCatalog()
    cat.record("SkipOp", 10.0, pass_rate=0.25, direct=True)
    cost = chain_cost_us([SkipOp(), MLLMExtractOp()], cat)
    assert cost == pytest.approx(10.0 + 0.25 * 1200.0)  # static mllm big


# ---------------------------------------------------------------------------
# (b) cost catalog: recording semantics + persistence
# ---------------------------------------------------------------------------

def test_direct_measurements_outrank_run_estimates():
    cat = CostCatalog()
    cat.record("mllm[big]", 5000.0, direct=False)   # run-derived bracket
    cat.record("mllm[big]", 1000.0, direct=True)    # micro-benchmark
    assert cat.lookup("mllm[big]") == 1000.0
    cat.record("mllm[big]", 9000.0, direct=False)   # later run estimate
    assert cat.lookup("mllm[big]") == 1000.0        # never clobbered
    cat.record("mllm[big]", 2000.0, direct=True)    # fresh direct sample
    assert cat.lookup("mllm[big]") == pytest.approx(1500.0)  # EMA merge


def test_catalog_roundtrip(tmp_path):
    cat = CostCatalog()
    cat.record("SkipOp", 12.25, pass_rate=0.5, direct=True)
    cat.record("mllm[big]@64x128", 4321.5, direct=True)
    cat.record("DetectOp", 400.0, pass_rate=0.125, direct=False)
    path = str(tmp_path / "catalog.json")
    cat.save(path)
    back = CostCatalog.load(path)
    assert back.to_dict() == cat.to_dict()
    assert len(back) == 3 and back.lookup("SkipOp") == 12.25


# ---------------------------------------------------------------------------
# (c) safe-join canonicalization (model-free)
# ---------------------------------------------------------------------------

def test_safe_join_takes_least_aggressive_params():
    j = safe_join([SkipOp(amount=6, roi=(0, 0, 32, 64)),
                   SkipOp(amount=2, roi=(32, 32, 32, 64))])
    assert j.amount == 2 and j.roi == (0, 0, 64, 96)   # min amount, ∪ roi
    j = safe_join([DownscaleOp(factor=4), DownscaleOp(factor=2)])
    assert j.factor == 2
    j = safe_join([FusedPreprocessOp(crop=(0, 0, 64, 128), factor=4),
                   FusedPreprocessOp(crop=(64, 0, 64, 128), factor=2)])
    assert j.crop == (0, 0, 128, 128) and j.factor == 2 and not j.grey
    # different predicates never join
    assert safe_join([CheapColorFilterOp(color="red"),
                      CheapColorFilterOp(color="blue")]) is None


def test_joined_prefix_drops_private_and_order_violating_ops():
    src = SourceOp(stream_name="tollbooth")
    a = [src, SkipOp(amount=4), CropOp(region=(0, 0, 64, 256)),
         CheapColorFilterOp(color="red")]
    b = [src, SkipOp(amount=2), CropOp(region=(64, 0, 64, 256))]
    joined = joined_prefix([a, b])
    names = [type(o).__name__ for o in joined]
    assert names == ["SourceOp", "SkipOp", "CropOp"]   # private op dropped
    assert joined[1].amount == 2
    assert joined[2].region == (0, 0, 128, 256)
    # identical chains join to identical signatures
    j2 = joined_prefix([a, a])
    assert [o.signature() for o in j2] == [o.signature() for o in a]


# ---------------------------------------------------------------------------
# (d) phase interface + calibration (models required)
# ---------------------------------------------------------------------------

def test_calibrate_chain_stamps_measured_costs(ctx):
    q = get_query("Q2")
    plan = q.naive_plan()
    frames, _ = TollBoothStream(seed=404).batch(32)
    cat = CostCatalog()
    cat.calibrate_chain(plan.ops, frames, ctx)
    assert uncalibrated(plan.ops) == []
    for op in plan.ops:
        assert op.cost_us >= 0 and 0.0 <= op.pass_rate <= 1.0
    mi = plan.index_of(MLLMExtractOp)
    assert plan.ops[mi].cost_us > plan.ops[0].cost_us   # extract dominates
    assert cat.lookup("mllm[big]") is not None          # variant fallback
    # stamped plans drive the planner without static defaults
    cost = chain_cost_us(plan.ops)
    assert cost > 0
    # calibration leaves runtime state pristine: the plan still runs
    res = StreamRuntime(plan, ctx, micro_batch=8).run(
        TollBoothStream(seed=11), 16)
    assert res.n_frames == 16


def test_superopt_drives_phases_through_common_interface(ctx):
    q = get_query("Q2")
    sf = lambda seed: TollBoothStream(seed=seed)  # noqa: E731
    opt = SuperOptimizer(ctx, val_frames=48)
    assert set(opt.phase_registry) == {"semantic", "logical", "physical"}
    plan, report = opt.optimize(q, sf, phases=("semantic",))
    assert set(report.phase_wall_s) == {"semantic", "calibration"}
    assert all(w > 0 for w in report.phase_wall_s.values())
    assert report.op_timings, "calibrated op timings must be reported"
    keys = {r["key"] for r in report.op_timings}
    assert any(k.startswith("mllm[") for k in keys)
    rows = report.to_rows()
    assert {r["kind"] for r in rows} == {"phase_wall", "op_timing"}
    assert uncalibrated(plan.ops) == []
    assert "semantic" in report.describe()


def test_merged_extract_inherits_column_calibration(ctx):
    p1, p2 = get_query("Q2").naive_plan(), get_query("Q6").naive_plan()
    frames, _ = TollBoothStream(seed=404).batch(16)
    cat = CostCatalog()
    for p in (p1, p2):
        cat.calibrate_chain(p.ops, frames, ctx)
    forest = SharingTreePlanner(catalog=cat).plan([p1, p2])
    (group,) = forest.streams["tollbooth"]
    assert group.is_shared
    merged = [op for op in group.execution.prefix
              if isinstance(op, MLLMExtractOp)]
    assert merged and merged[0].cost_us >= 0   # union op keeps measurement


# ---------------------------------------------------------------------------
# (e) the fleet contract (slow: full joint optimization)
# ---------------------------------------------------------------------------

def _sink_plan(ops, query):
    from repro.streaming.operators import SinkOp

    return Plan(list(ops) + [SinkOp()], query=query)


def test_extract_bucket_tracks_prefix_shape_transforms():
    src = SourceOp(stream_name="tollbooth")
    ex = MLLMExtractOp(tasks=("present",), model="big")
    assert extract_bucket([src, ex]) == ("big", (3, 128, 256))
    assert extract_bucket(
        [src, CropOp(region=(64, 0, 64, 256)), DownscaleOp(factor=2), ex]
    ) == ("big", (3, 32, 128))
    assert extract_bucket(
        [src, FusedPreprocessOp(crop=(0, 0, 128, 256), factor=2), ex]
    ) == ("big", (3, 64, 128))
    assert extract_bucket([src]) is None            # no extract: no bucket
    # adaptive resolves per batch at runtime: statically unknowable bucket
    assert extract_bucket(
        [src, MLLMExtractOp(tasks=("present",), model="adaptive")]) is None


def test_coalescing_saving_rewards_cross_feed_bucket_alignment():
    # two feeds whose groups land in the same (variant, shape) bucket save
    # k-1 of k extract dispatches; misaligned buckets save nothing
    planner = SharingTreePlanner()

    def forest(crop=None, model="big", stream="tollbooth"):
        ops = [SourceOp(stream_name=stream)]
        if crop is not None:
            ops.append(CropOp(region=crop))
        ops.append(MLLMExtractOp(tasks=("present",), model=model))
        return planner.plan([_sink_plan(ops, "q")])

    aligned = [forest(), forest(stream="volleyball")]
    mb = 16
    saving = coalescing_saving_us(aligned, micro_batch=mb)
    # uncalibrated extracts fall back to the static dispatch cost; of two
    # aligned groups exactly one stops paying it (sum - max)
    assert saving == pytest.approx(EXTRACT_DISPATCH_US / mb)
    three = aligned + [forest(stream="volleyball")]
    # factor_plans disambiguates duplicate queries; three aligned groups
    # save two dispatches
    assert coalescing_saving_us(three, micro_batch=mb) == \
        pytest.approx(2 * EXTRACT_DISPATCH_US / mb)
    # a cropped prefix lands in a different bucket: nothing to coalesce
    misaligned = [forest(), forest(crop=(64, 0, 64, 256))]
    assert coalescing_saving_us(misaligned, micro_batch=mb) == 0.0
    # different physical variants never share a forward either
    mixed_model = [forest(), forest(model="small")]
    assert coalescing_saving_us(mixed_model, micro_batch=mb) == 0.0


def _fleet_workload():
    tb = lambda seed: TollBoothStream(seed=seed)      # noqa: E731
    vb = lambda seed: VolleyballStream(seed=seed)     # noqa: E731
    return ([FleetQuery(get_query(q), tb, feed="tb")
             for q in ("Q2", "Q6", "Q8")] +
            [FleetQuery(get_query(q), vb, feed="vb")
             for q in ("Q12", "Q13")])


@pytest.mark.slow
def test_fleet_sharing_survives_and_costs_calibrated(ctx):
    fo = FleetOptimizer(ctx, val_frames=48)
    res = fo.optimize(_fleet_workload())
    assert sorted(res.plans) == ["Q12", "Q13", "Q2", "Q6", "Q8"]
    # every plan fully calibrated — the planner never falls back
    for p in res.plans.values():
        assert uncalibrated(p.ops) == []
    # sharing survives joint optimization: at least as many queries sit in
    # shared groups as under naive sharing
    naive_forests = [SharingTreePlanner().plan(
        [res.naive_plans[k] for k in keys])
        for keys in res.feed_keys.values()]
    n_shared_naive = sum(g.n_queries for f in naive_forests
                         for g in f.groups() if g.is_shared)
    n_shared_fleet = sum(g.n_queries for f in res.forests.values()
                         for g in f.groups() if g.is_shared)
    assert n_shared_fleet >= n_shared_naive
    # the joint estimate crushes naive and stays within the defection
    # margin of the per-query assignment (the margin keeps structure when
    # the estimated difference is noise-level)
    assert res.fleet_cost_us["fleet"] < res.fleet_cost_us["naive"]
    assert res.fleet_cost_us["fleet"] <= \
        res.fleet_cost_us["solo"] * (1.0 + 5 * fo.rel_margin)
    assert res.decisions


@pytest.mark.slow
def test_fleet_execution_bitwise_identical_to_solo(ctx):
    from repro.scheduler import MultiStreamRuntime
    from repro.streaming.multiquery import MultiQueryRuntime

    fo = FleetOptimizer(ctx, val_frames=48)
    res = fo.optimize(_fleet_workload(), phases=("semantic", "logical"))
    makers = {"tb": lambda: TollBoothStream(seed=555),
              "vb": lambda: VolleyballStream(seed=555)}
    ms = MultiStreamRuntime.from_fleet(
        res, {f: makers[f]() for f in res.plans_by_feed}, ctx,
        micro_batch=16)
    out = ms.run(48)
    for feed, plans in res.plans_by_feed.items():
        for p in plans:
            ind = StreamRuntime(p.clone(), ctx, micro_batch=16).run(
                makers[feed](), 48)
            sq = out.feeds[feed].per_query[p.query]
            assert sq.outputs == ind.outputs
            assert sq.window_results == ind.window_results
    # the single-stream shared runtime accepts the same fleet plans
    mq = MultiQueryRuntime.from_fleet(res, "tb", ctx, micro_batch=16)
    shared = mq.run(makers["tb"](), 48)
    for p in res.plans_by_feed["tb"]:
        ind = StreamRuntime(p.clone(), ctx, micro_batch=16).run(
            makers["tb"](), 48)
        assert shared.per_query[p.query].outputs == ind.outputs
