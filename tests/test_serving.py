"""Serving engine + quantization tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import LM, materialize
from repro.serving import Request, ServingEngine
from repro.serving.quantize import dequantize_params, quantize_params_int8
from repro.serving.sampler import sample_logits


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("chatglm3-6b")
    lm = LM(cfg, tp=1)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    return cfg, lm, params


def test_engine_serves_more_requests_than_slots(small_model):
    cfg, lm, params = small_model
    eng = ServingEngine(cfg, params, max_slots=2, s_max=64, eos_id=-1)
    reqs = [Request(uid=i, prompt=list(range(3 + i, 13 + i)),
                    max_new_tokens=5) for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.output) == 5 for r in done)
    assert eng.stats["finished"] == 5


def test_engine_matches_unbatched_greedy(small_model):
    """Continuous-batched greedy decode == single-sequence greedy decode."""
    cfg, lm, params = small_model
    prompt = list(range(5, 17))
    eng = ServingEngine(cfg, params, max_slots=3, s_max=64, eos_id=-1)
    # fill other slots with decoys to force real batching
    reqs = [Request(uid=0, prompt=prompt, max_new_tokens=6),
            Request(uid=1, prompt=list(range(40, 49)), max_new_tokens=6),
            Request(uid=2, prompt=list(range(60, 80)), max_new_tokens=6)]
    done = {r.uid: r for r in eng.run(reqs)}

    # reference: manual prefill+decode at fp32
    cache = lm.init_cache(1, 64, dtype=jnp.float32)
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, cache = lm.prefill(params, {"tokens": tokens}, cache,
                               dtype=jnp.float32)
    out = []
    tok = int(jnp.argmax(logits[0, -1]))
    out.append(tok)
    cur = len(prompt)
    for _ in range(5):
        logits, cache = lm.decode(params, jnp.asarray([[tok]], jnp.int32),
                                  cache, jnp.int32(cur), dtype=jnp.float32)
        tok = int(jnp.argmax(logits[0, 0]))
        out.append(tok)
        cur += 1
    assert done[0].output == out


def test_sampler_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample_logits(logits)[0]) == 1
    key = jax.random.PRNGKey(0)
    t = sample_logits(jnp.tile(logits, (64, 1)), key, temperature=1.0,
                      top_k=2)
    assert set(np.asarray(t).tolist()) <= {1, 2}


def test_quantize_roundtrip_and_size(small_model):
    cfg, lm, params = small_model
    qp, stats = quantize_params_int8(params)
    assert stats["ratio"] < 0.35            # ~4x smaller + scales
    dq = dequantize_params(qp)
    tokens = jnp.arange(64).reshape(2, 32) % cfg.vocab_size
    l1, _ = lm.logits_causal(params, {"tokens": tokens}, jnp.float32)
    l2, _ = lm.logits_causal(dq, {"tokens": tokens}, jnp.float32)
    # int8 weight quantization keeps top-1 prediction mostly stable
    agree = float(np.mean(np.argmax(np.asarray(l1), -1)
                          == np.argmax(np.asarray(l2), -1)))
    assert agree > 0.7
