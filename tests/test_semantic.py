"""Semantic gating tier tests.

Covers the subsystem's contract at three levels: (a) model-free —
temporal signatures, cache hits/misses, the revalidation budget, the
accuracy-budgeted admission controller, LRU bounds, snapshot/restore of
gating state; (b) with models — the solo ``MLLMExtractOp`` cache-consult
path and the ``SharedExtractServer`` cache-consult stage (including hits
on keyframes whose forwards are still in flight — the pipelined donor
path), with the no-regression guarantee that a disabled gate
(threshold=0) is bitwise identical to the ungated tier; (c) the
``MultiStreamRuntime`` snapshot/restore round-trip (per-feed source
offsets + drain barrier + gating/cache state, bitwise vs an uninterrupted
run) and the hit-rate-aware cost model.
"""
import dataclasses

import numpy as np
import pytest

from repro.semantic import GateConfig, SemanticGate, TemporalSignature


@pytest.fixture(scope="module")
def ctx(stream_ctx):
    return stream_ctx


def _scene(value: float, shape=(3, 32, 64)) -> np.ndarray:
    """One deterministic already-normalized frame (max <= 8)."""
    f = np.full(shape, value, np.float32)
    f[:, ::4, ::4] = -value
    return f


def _frames(*values) -> np.ndarray:
    return np.stack([_scene(v) for v in values])


def _fake_preds(n: int, tag: int = 0):
    return {"present": np.full(n, tag, np.int32),
            "plate": np.full((n, 6), tag, np.int32)}


def _pump(gate, feed, frames, tag=0):
    """Admit one batch and answer its model rows with fake predictions."""
    adm = gate.admit(feed, "big", frames)
    adm.bind(_fake_preds(adm.n_model, tag) if adm.n_model else None)
    return adm, adm.assemble()


# ---------------------------------------------------------------------------
# (a) model-free: signature, cache, budget, controller
# ---------------------------------------------------------------------------

def test_signature_distance_and_buckets():
    sig = TemporalSignature()
    a = _frames(0.5)
    b = _frames(0.5)
    c = _frames(-1.5)
    fa, ea = sig.features(a)
    fb, eb = sig.features(b)
    fc, ec = sig.features(c)
    assert TemporalSignature.distance(fa[0], ea[0], fb[0], eb[0]) == 0.0
    far = TemporalSignature.distance(fa[0], ea[0], fc[0], ec[0])
    assert far > 0.1
    # a tiny perturbation stays near; buckets are stable for equal frames
    noisy = a + 0.001
    fn, en = sig.features(noisy)
    assert TemporalSignature.distance(fa[0], ea[0], fn[0], en[0]) < 0.01
    assert TemporalSignature.bucket(ea[0], 0.5) == \
        TemporalSignature.bucket(eb[0], 0.5)
    # raw (uint8-range) and normalized views of one frame agree per frame
    raw = ((a * 0.25 + 0.5) * 255.0).astype(np.float32)
    fr, er = sig.features(raw)
    assert TemporalSignature.distance(fa[0], ea[0], fr[0], er[0]) < 1e-4


def test_gate_hits_misses_and_revalidation_budget():
    gate = SemanticGate(GateConfig(threshold=0.05, revalidate_every=4))
    frames = _frames(0.5, 0.5, 0.5, 0.5)
    adm, out = _pump(gate, "f", frames, tag=7)
    # row 0 is novel; rows 1-3 are intra-batch near-duplicates of it
    assert gate.counters["cache_misses"] == 1
    assert gate.counters["cache_hits"] == 3
    assert np.array_equal(out["present"], np.full(4, 7, np.int32))
    # 4th hit on the keyframe revalidates (within-budget drift detection)
    adm2, out2 = _pump(gate, "f", frames, tag=7)
    assert gate.counters["revalidations"] == 1
    assert gate.counters["cache_mismatches"] == 0
    assert np.array_equal(out2["present"], np.full(4, 7, np.int32))
    # the budget invariant: no keyframe ever serves `revalidate_every`
    # consecutive answers without a model check
    for entries in gate.cache._feeds.values():
        for e in entries.values():
            assert e.since_reval < gate.config.revalidate_every


def test_gate_mismatch_tightens_threshold_and_repairs_keyframe():
    gate = SemanticGate(GateConfig(threshold=0.05, revalidate_every=2,
                                   accuracy_budget=0.05))
    frames = _frames(0.5, 0.5)
    _pump(gate, "f", frames, tag=1)          # novel + 1 hit
    # next hit revalidates; the model now answers differently -> mismatch
    adm, out = _pump(gate, "f", frames, tag=2)
    assert gate.counters["revalidations"] >= 1
    assert gate.counters["cache_mismatches"] >= 1
    thr = gate.controller.threshold("f")
    assert thr < gate.config.threshold       # tightened
    assert thr > 0.0                         # never fully closes
    # the drifted keyframe was refreshed with the fresh answer
    adm3, out3 = _pump(gate, "f", frames, tag=2)
    assert out3["present"][0] == 2
    # clean revalidations recover the threshold, never past the base
    for _ in range(200):
        gate.controller.observe("f", False)
    assert gate.controller.threshold("f") == \
        pytest.approx(gate.config.threshold)


def test_gate_cache_is_bounded_lru():
    gate = SemanticGate(GateConfig(threshold=0.05, max_entries=4))
    for i in range(10):
        _pump(gate, "f", _frames(-2.0 + i * 0.45), tag=i)
    assert len(gate.cache._feeds["f"]) <= 4
    assert gate.counters["cache_misses"] == 10


def test_gate_snapshot_restore_roundtrip_model_free():
    gate = SemanticGate(GateConfig(threshold=0.05, revalidate_every=4))
    frames = _frames(0.5, 0.5, -1.5)
    _pump(gate, "f", frames, tag=3)
    gate.controller.observe("f", True)
    st = gate.snapshot()

    g2 = SemanticGate(GateConfig(threshold=0.05, revalidate_every=4))
    g2.restore(st)
    assert g2.counters == gate.counters
    assert g2.controller.threshold("f") == gate.controller.threshold("f")
    # the restored keyframes answer exactly like the originals
    a1, o1 = _pump(gate, "f", frames, tag=9)
    a2, o2 = _pump(g2, "f", frames, tag=9)
    assert a1.n_model == a2.n_model
    for k in o1:
        assert np.array_equal(o1[k], o2[k])


def test_gate_reset_scopes_to_feed():
    gate = SemanticGate(GateConfig(threshold=0.05))
    _pump(gate, "a", _frames(0.5))
    _pump(gate, "b", _frames(0.5))
    gate.reset("a")
    assert "a" not in gate.cache._feeds and "b" in gate.cache._feeds
    gate.reset()
    assert not gate.cache._feeds


# ---------------------------------------------------------------------------
# (a') hit-rate-aware cost model
# ---------------------------------------------------------------------------

def test_chain_cost_discounts_extract_by_gate_hit_rate():
    from repro.queries import get_query
    from repro.scheduler.sharing_tree import SharingTreePlanner, chain_cost_us

    ops = get_query("Q2").naive_plan().ops
    full = chain_cost_us(ops, micro_batch=16)
    half = chain_cost_us(ops, micro_batch=16, gate_hit_rate=0.5)
    none = chain_cost_us(ops, micro_batch=16, gate_hit_rate=1.0)
    assert none < half < full
    # only the extract term is discounted: the cheap tail survives intact
    assert full - half == pytest.approx((full - none) / 2)
    # the planner prices shares with the discount: savings shrink with h
    plans = [get_query(q).naive_plan() for q in ("Q2", "Q6")]
    s0 = SharingTreePlanner().plan(plans).groups()[0].saving_us
    s9 = SharingTreePlanner(gate_hit_rate=0.9).plan(plans)
    s9 = s9.groups()[0].saving_us
    assert 0 < s9 < s0


def test_cost_catalog_gate_hit_rates_roundtrip(tmp_path):
    from repro.core.costs import CostCatalog

    cat = CostCatalog()
    assert cat.mean_gate_hit_rate() == 0.0
    cat.record_gate_hit_rate("tb0", 0.8)
    cat.record_gate_hit_rate("vb0", 0.2)
    cat.record_gate_hit_rate("tb0", 0.4)        # EMA-merged
    assert 0.4 < cat.gate_hit_rates["tb0"] < 0.8
    path = str(tmp_path / "cat.json")
    cat.save(path)
    back = CostCatalog.load(path)
    assert back.gate_hit_rates == cat.gate_hit_rates
    assert back.mean_gate_hit_rate() == pytest.approx(
        cat.mean_gate_hit_rate())


# ---------------------------------------------------------------------------
# (b) with models: solo op path + server cache-consult stage
# ---------------------------------------------------------------------------

def test_server_stats_is_cached_view(ctx):
    from repro.scheduler import SharedExtractServer

    srv = SharedExtractServer(ctx, gate=SemanticGate(GateConfig()))
    view = srv.stats
    assert srv.stats is view                  # one dict, not rebuilt
    for k in ("cache_hits", "cache_misses", "revalidations",
              "cache_mismatches"):
        assert view[k] == 0
    srv.reset_stats()
    assert srv.stats is view                  # reset updates in place


def test_solo_op_disabled_gate_is_bitwise_identical(ctx):
    from repro.data import TollBoothStream
    from repro.queries import get_query
    from repro.streaming.runtime import StreamRuntime

    plain = StreamRuntime(get_query("Q2").naive_plan(), ctx,
                          micro_batch=16).run(TollBoothStream(seed=3), 48)
    gctx = dataclasses.replace(
        ctx, gate=SemanticGate(GateConfig(threshold=0.0)))
    gated = StreamRuntime(get_query("Q2").naive_plan(), gctx,
                          micro_batch=16).run(TollBoothStream(seed=3), 48)
    assert gated.outputs == plain.outputs
    assert gated.window_results == plain.window_results
    assert gctx.gate.counters["cache_misses"] == 0    # never consulted


def test_solo_op_gated_skips_redundant_forwards(ctx):
    from repro.data import TollBoothStream
    from repro.queries import get_query
    from repro.streaming.plan import Plan
    from repro.streaming.runtime import StreamRuntime

    gate = SemanticGate(GateConfig(threshold=0.06, revalidate_every=8))
    gctx = dataclasses.replace(ctx, gate=gate)
    plan = get_query("Q2").naive_plan()
    rt = StreamRuntime(plan, gctx, micro_batch=16)
    res = rt.run(TollBoothStream(seed=3), 64)
    assert gate.counters["cache_hits"] > 0
    served = sum(gate.counters[k] for k in
                 ("cache_hits", "cache_misses", "revalidations"))
    # every frame classified exactly once: 64 measured + the untimed
    # 16-frame warmup batch (op.reset drops keyframes, not accounting)
    assert served == 64 + 16
    # model load accounting is unchanged (frames reaching the extract);
    # the *cache* is what absorbed the redundant fraction
    assert res.mllm_frames == 64
    assert 0.0 <= get_query("Q2").evaluate(res) <= 1.0


def test_server_gated_submit_short_circuits_dispatch(ctx):
    from repro.data import TollBoothStream
    from repro.scheduler import SharedExtractServer

    gate = SemanticGate(GateConfig(threshold=0.06, revalidate_every=100))
    srv = SharedExtractServer(ctx, gate=gate)
    f1 = TollBoothStream(seed=3).batch(1)[0].astype(np.float32)
    frames = np.repeat(f1, 6, axis=0)         # 6 identical rows
    r1 = srv.submit("big", frames, feed="a")
    assert srv.pending_frames("a") == 1       # only the novel row queued
    assert srv.drain() == 1
    assert r1.done
    base = r1.result
    # every row equals the keyframe's answer
    for task in base:
        assert all(np.array_equal(base[task][i], base[task][0])
                   for i in range(6))
    # a fully-cached batch never touches the dispatch queue
    forwards = srv.stats["forwards"]
    r2 = srv.submit("big", frames, feed="a")
    assert srv.pending_frames("a") == 0
    assert r2.done                            # short-circuited: no drain
    assert srv.stats["forwards"] == forwards
    for task in base:
        assert np.array_equal(r2.result[task], base[task])
    assert srv.stats["cache_hits"] == 5 + 6
    assert srv.stats["requests"] == 2


def test_server_gated_hits_on_inflight_keyframes(ctx):
    # the pipelined donor path: batch 2 hits keyframes whose forward
    # (from batch 1) has not retired yet — batch 2 reports done only once
    # the donor completes, then serves the donor's rows
    from repro.data import TollBoothStream
    from repro.scheduler import SharedExtractServer

    gate = SemanticGate(GateConfig(threshold=0.06, revalidate_every=100))
    srv = SharedExtractServer(ctx, gate=gate, max_inflight=2)
    f1 = TollBoothStream(seed=5).batch(1)[0].astype(np.float32)
    frames = np.repeat(f1, 4, axis=0)
    r1 = srv.submit("big", frames, feed="a")
    r2 = srv.submit("big", frames, feed="a")  # hits r1's pending keyframe
    assert not r1.done and not r2.done
    assert srv.pending_frames("a") == 1       # r2 queued nothing
    srv.drain()
    assert r1.done and r2.done
    for task in r1.result:
        assert np.array_equal(r2.result[task], r1.result[task])


def test_multistream_disabled_gate_identity_and_revalidation(ctx):
    from repro.data import TollBoothStream
    from repro.queries import get_query
    from repro.scheduler import Feed, MultiStreamRuntime, SharedExtractServer

    def feeds():
        return [Feed("tb", TollBoothStream(seed=11),
                     [get_query(q).naive_plan() for q in ("Q2", "Q6")])]

    base = MultiStreamRuntime(feeds(), ctx, micro_batch=16).run(48)
    off = MultiStreamRuntime(
        feeds(), ctx, micro_batch=16,
        server=SharedExtractServer(
            ctx, gate=SemanticGate(GateConfig(threshold=0.0)))).run(48)
    for q in ("Q2", "Q6"):
        assert off.feeds["tb"].per_query[q].outputs == \
            base.feeds["tb"].per_query[q].outputs
        assert off.feeds["tb"].per_query[q].window_results == \
            base.feeds["tb"].per_query[q].window_results
    assert off.server_stats["cache_hits"] == 0

    gate = SemanticGate(GateConfig(threshold=0.06, revalidate_every=4))
    on = MultiStreamRuntime(
        feeds(), ctx, micro_batch=16,
        server=SharedExtractServer(ctx, gate=gate)).run(48)
    st = on.server_stats
    assert st["cache_hits"] > 0
    assert st["frames"] < base.server_stats["frames"]
    # revalidation actually fired within its budget on a real stream
    assert st["revalidations"] >= st["cache_hits"] // 4
    assert on.mllm_frames == base.mllm_frames     # load metric unchanged


def test_gated_run_records_hit_rates_in_catalog(ctx):
    # the cost-model loop: a gated serving run lands its measured
    # per-feed hit rates in the planner's catalog, so the next planning
    # pass prices extracts at observed model load
    from repro.core.costs import CostCatalog
    from repro.data import TollBoothStream
    from repro.queries import get_query
    from repro.scheduler import (Feed, MultiStreamRuntime,
                                 SharedExtractServer, SharingTreePlanner)

    cat = CostCatalog()
    planner = SharingTreePlanner(catalog=cat)
    assert planner.gate_hit_rate == 0.0       # nothing measured yet
    gate = SemanticGate(GateConfig(threshold=0.06))
    ms = MultiStreamRuntime(
        [Feed("tb", TollBoothStream(seed=11),
              [get_query("Q2").naive_plan()])],
        ctx, micro_batch=16, planner=planner,
        server=SharedExtractServer(ctx, gate=gate))
    ms.run(48)
    assert cat.gate_hit_rates["tb"] == pytest.approx(gate.hit_rate("tb"))
    assert planner.gate_hit_rate > 0.0        # the planner now discounts


def test_multiquery_server_gated_path(ctx):
    from repro.data import TollBoothStream
    from repro.queries import get_query
    from repro.scheduler import SharedExtractServer
    from repro.streaming.multiquery import MultiQueryRuntime

    def plans():
        return [get_query(q).naive_plan() for q in ("Q2", "Q6")]

    plain = MultiQueryRuntime(plans(), ctx, micro_batch=16).run(
        TollBoothStream(seed=9), 48)
    off = MultiQueryRuntime(
        plans(), ctx, micro_batch=16,
        server=SharedExtractServer(
            ctx, gate=SemanticGate(GateConfig(threshold=0.0)))
    ).run(TollBoothStream(seed=9), 48)
    for q in ("Q2", "Q6"):
        assert off.per_query[q].outputs == plain.per_query[q].outputs
        assert off.per_query[q].window_results == \
            plain.per_query[q].window_results

    gate = SemanticGate(GateConfig(threshold=0.06, revalidate_every=4))
    mq = MultiQueryRuntime(plans(), ctx, micro_batch=16,
                           server=SharedExtractServer(ctx, gate=gate))
    on = mq.run(TollBoothStream(seed=9), 48)
    assert gate.counters["cache_hits"] > 0
    assert on.mllm_frames == plain.mllm_frames
    st = mq.snapshot()                    # gating state rides the snapshot
    assert st["gate"] is not None


# ---------------------------------------------------------------------------
# (c) MultiStreamRuntime snapshot/restore (drain barrier + gating state)
# ---------------------------------------------------------------------------

def _ms_snapshot_feeds():
    from repro.data import TollBoothStream, VolleyballStream
    from repro.queries import get_query
    from repro.scheduler import Feed
    from repro.streaming.operators import (FilterOp, MLLMExtractOp, SinkOp,
                                           SourceOp, WindowAggOp)
    from repro.streaming.plan import Plan

    # a short tumbling window so both segments close windows — the
    # sharpest state to round-trip
    win = Plan([SourceOp(stream_name="tollbooth"),
                MLLMExtractOp(tasks=("present", "color"), model="big"),
                FilterOp(("eq", "present", 1)),
                WindowAggOp("top_color", 32), SinkOp()], query="Qwin")
    return [
        Feed("tb", TollBoothStream(seed=17),
             [win, get_query("Q2").naive_plan()]),
        Feed("vb", VolleyballStream(seed=17),
             [get_query("Q12").naive_plan()]),
    ]


@pytest.mark.parametrize("gated", [False, True])
def test_multistream_snapshot_restore_bitwise(ctx, gated):
    from repro.scheduler import MultiStreamRuntime, SharedExtractServer

    def runtime():
        kw = {}
        if gated:
            # pipelined=False keeps gated classification deterministic
            # (assemble order is data- not timing-dependent)
            kw = {"server": SharedExtractServer(
                ctx, gate=SemanticGate(GateConfig(threshold=0.06,
                                                  revalidate_every=4))),
                "pipelined": False}
        return MultiStreamRuntime(_ms_snapshot_feeds(), ctx,
                                  micro_batch=16, **kw)

    full = None if gated else runtime().run(96)

    seg = runtime()
    seg.run(48)                                   # segment 1 (fresh)
    snap = seg.snapshot()
    assert snap["feeds"]["tb"]["source_index"] == 48
    if gated:
        assert snap.get("gate") is not None
    cont = seg.run(48, warmup=0)                  # uninterrupted tail

    rt2 = runtime()
    rt2.restore(snap)
    for fs in rt2._feeds:                         # replay to the offset
        fs.feed.stream.batch(48)
    resumed = rt2.run(48)                         # warmup suppressed

    for feed in ("tb", "vb"):
        for qid, cq in cont.feeds[feed].per_query.items():
            rq = resumed.feeds[feed].per_query[qid]
            # the round trip: restored == uninterrupted continuation,
            # bitwise (outputs, windows, gating decisions and all)
            assert rq.outputs == cq.outputs
            assert rq.window_results == cq.window_results
            if gated:
                # segment boundaries change *when* revalidations
                # assemble (a run-end drain is an extra barrier), so a
                # gated segmented run is bitwise vs its own
                # continuation, not vs a differently-segmented run
                continue
            # ungated, segmentation is invisible: the continuation is
            # exactly the uninterrupted 96-frame run's tail
            fq = full.feeds[feed].per_query[qid]
            k = len(rq.window_results)
            if k:
                assert rq.window_results == fq.window_results[-k:]
            assert [o for o in rq.outputs if "window" not in o] == \
                [o for o in fq.outputs
                 if "window" not in o and o["idx"] >= 48]


def test_multistream_snapshot_mid_pipelined_flight_bitwise(ctx):
    """snapshot() taken while forwards are genuinely outstanding: the
    drain barrier must run the in-flight continuations to completion and
    fold them into the checkpoint, so a restore continues bitwise — the
    aligned-checkpoint claim under pipelined serving, not just at the
    quiescent end-of-run boundary the other round-trip tests use."""
    from repro.scheduler import MultiStreamRuntime

    def runtime():
        return MultiStreamRuntime(_ms_snapshot_feeds(), ctx,
                                  micro_batch=16)

    full = runtime().run(96)

    seg = runtime()
    seg.run(32)
    # hand-inject the next micro-batch on every feed and dispatch, so the
    # snapshot lands with suspended continuations parked at the server
    # and forwards on the device — mid-flight, not drained
    for fs in seg._feeds:
        frames, _ = fs.feed.stream.batch(16)
        batch = {"frames": frames,
                 "idx": np.arange(fs.source_index, fs.source_index + 16)}
        for g in fs.groups:
            p = g.start(batch)
            if p is not None:
                fs.pendings.append((g, p))
        fs.source_index += 16
    assert any(fs.pendings for fs in seg._feeds)
    seg.server.dispatch()
    assert seg.server.inflight > 0 or seg.server.pending_requests() > 0

    snap = seg.snapshot()                      # the drain barrier
    assert not any(fs.pendings for fs in seg._feeds)
    assert snap["feeds"]["tb"]["source_index"] == 48
    assert snap["feeds"]["vb"]["source_index"] == 48

    rt2 = runtime()
    rt2.restore(snap)
    for fs in rt2._feeds:                      # replay to the offset
        fs.feed.stream.batch(48)
    resumed = rt2.run(48)                      # warmup suppressed

    # the restored continuation is exactly the uninterrupted run's tail:
    # outputs and every window spanning the mid-flight batch included
    for feed in ("tb", "vb"):
        for qid, rq in resumed.feeds[feed].per_query.items():
            fq = full.feeds[feed].per_query[qid]
            k = len(rq.window_results)
            if k:
                assert rq.window_results == fq.window_results[-k:]
            assert [o for o in rq.outputs if "window" not in o] == \
                [o for o in fq.outputs
                 if "window" not in o and o["idx"] >= 48]
