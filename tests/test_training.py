"""Training substrate tests: optimizer, checkpoint/restore, elastic restore,
data resumability, int8 moments, fault-tolerance paths."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import LM, materialize
from repro.training import (
    CheckpointManager,
    OptimizerConfig,
    TokenStream,
    TrainConfig,
    Trainer,
)
from repro.training.optimizer import (_dq8, _dq8_v, _q8, _q8_v, adamw_init,
                                      adamw_update, lr_schedule)


def small_setup(quant=False):
    cfg = smoke_config("chatglm3-6b")
    lm = LM(cfg, tp=1)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    data = TokenStream(cfg.vocab_size, batch=4, seq_len=16, seed=0)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=50,
                          quantized_state=quant)
    return cfg, lm, params, data, opt


def test_int8_moment_roundtrip_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    q, s = _q8(x)
    err = jnp.max(jnp.abs(_dq8(q, s) - x) / (jnp.max(jnp.abs(x), -1,
                                                     keepdims=True) + 1e-9))
    assert float(err) < 1.0 / 127 + 1e-3
    # v-path: small values in a row with a big max survive the 4th-root map
    # (1e-4 -> u=0.1, 13 quant steps; linear quant would floor it to 0)
    v = jnp.concatenate([jnp.full((1, 255), 1e-4), jnp.ones((1, 1))], -1)
    vq, vs = _q8_v(v)
    back = _dq8_v(vq, vs)
    assert float(back[0, 0]) > 1e-6  # not crushed to zero
    lin_q, lin_s = _q8(v)
    assert float(_dq8(lin_q, lin_s)[0, 0]) == 0.0  # linear int8 would be


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr_schedule(cfg, jnp.asarray(100))) <= 0.11


@pytest.mark.parametrize("quant", [False, True])
def test_train_decreases_or_stays_stable(quant):
    cfg, lm, params, data, opt = small_setup(quant)
    tr = Trainer(lambda p, b: lm.loss(p, b, jnp.float32), params, opt,
                 TrainConfig(steps=20, grad_accum=2, log_every=0), data)
    out = tr.train()
    assert np.isfinite(out["final_loss"])
    # no explosion
    assert out["final_loss"] < out["history"][0] * 1.2 + 1.0


def test_checkpoint_restore_exact_resume():
    cfg, lm, params, data, opt = small_setup()
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2)
        tr = Trainer(lambda p, b: lm.loss(p, b, jnp.float32), params, opt,
                     TrainConfig(steps=10, grad_accum=1, ckpt_every=5,
                                 log_every=0), data, ck)
        tr.train()
        assert ck.latest_step() == 10
        # continue 5 more; record losses
        more = tr.train(5)
        # fresh trainer restores at 10 and must replay identical batches
        params2 = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
        tr2 = Trainer(lambda p, b: lm.loss(p, b, jnp.float32), params2, opt,
                      TrainConfig(steps=5, grad_accum=1, log_every=0),
                      TokenStream(cfg.vocab_size, 4, 16, seed=0), ck)
        assert tr2.restore(step=10)
        assert tr2.step == 10 and tr2.data.index == 10
        out2 = tr2.train(5)
        # history is cumulative on the original trainer: the continuation's
        # losses are its LAST five entries
        np.testing.assert_allclose(out2["history"], more["history"][-5:],
                                   rtol=1e-4, atol=1e-5)


def test_checkpoint_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2)
        for s in (1, 2, 3):
            ck.save(s, {"a": jnp.ones((4,)) * s, "n": {"b": jnp.zeros((2, 2))}})
        assert ck.list_steps() == [2, 3]  # GC keeps last 2
        tree = ck.restore(3)
        np.testing.assert_allclose(tree["a"], 3 * np.ones(4))
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_elastic_restore_onto_mesh():
    """Checkpoint saved unsharded restores onto a sharded mesh layout."""
    from repro.common.sharding import mesh_scope, param_sharding_tree
    from repro.models.param import axes_tree
    from repro.launch.mesh import make_test_mesh

    cfg, lm, params, data, opt = small_setup()
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d)
        ck.save(1, {"params": params})
        mesh = make_test_mesh(1, 1)
        with mesh_scope(mesh):
            sh = param_sharding_tree(axes_tree(lm.spec()), mesh)
            tree = ck.restore(1, shardings={"params": sh})
        l1 = jax.tree_util.tree_leaves(tree["params"])
        l0 = jax.tree_util.tree_leaves(params)
        for a, b in zip(l0, l1):
            np.testing.assert_allclose(a, b)


def test_tokenstream_deterministic_and_resumable():
    s1 = TokenStream(512, 4, 16, seed=3)
    b1 = [s1.next_batch() for _ in range(3)]
    s2 = TokenStream(512, 4, 16, seed=3)
    s2.set_state({"index": np.asarray(2), "seed": np.asarray(3)})
    b2 = s2.next_batch()
    np.testing.assert_array_equal(b1[2]["tokens"], b2["tokens"])
