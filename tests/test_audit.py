"""Optimizer audit loop & bench gate tests.

Three levels, mirroring ``tests/test_obs.py``: (a) model-free —
``CostCatalog.reconcile`` convergence and drift flagging, ``PlanAudit``
exactly reproducing the planner's predicted forest costs, measured-cost
extraction from a synthetic metrics registry, flight-report rendering;
(b) the bench gate — ``scripts/bench_gate.py`` passes on an unmodified
copy of the committed baseline and exits nonzero on an injected 2×
slowdown; (c) with models — sampled completion-probe device timing
leaves un-probed serving bitwise identical (the ``test_obs.py``
no-overhead contract extends to the probe: it only ever runs behind
``obs.enabled``) while recording ``forward_device_ms`` measurements the
reconcile pass feeds back into the planner's catalog.
"""
import dataclasses
import json
import os
import shutil
import subprocess
import sys
import time

import pytest

from repro.core.costs import CostCatalog
from repro.obs import (
    NULL_OBS,
    NULL_TRACER,
    Metrics,
    Observability,
    PlanAudit,
    forward_gap,
    write_flight_report,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def ctx(stream_ctx):
    return stream_ctx


# ---------------------------------------------------------------------------
# (a) CostCatalog.reconcile: convergence, drift flags, entry creation
# ---------------------------------------------------------------------------

def test_reconcile_converges_miscalibrated_catalog():
    # deliberately mis-calibrated: direct entry 8x below reality
    cat = CostCatalog()
    cat.record("mllm[big]", 50.0, direct=True, overhead_us=10.0)
    truth = {"mllm[big]": {"us": 400.0, "overhead_us": 80.0, "frames": 64}}
    flags = cat.reconcile(truth)
    assert flags == ["mllm[big]"]        # 8x off: flagged on first pass
    for _ in range(11):
        cat.reconcile(truth)
    e = cat.entries["mllm[big]"]
    # EMA halves the error each pass: within 5% after 12 reconciles
    assert e.us == pytest.approx(400.0, rel=0.05)
    assert e.overhead_us == pytest.approx(80.0, rel=0.05)
    # within-tolerance measurements stop flagging once converged
    assert cat.reconcile(truth) == []


def test_reconcile_bypasses_direct_protection_and_creates_entries():
    cat = CostCatalog()
    cat.record("FilterOp", 10.0, direct=True)
    # record() with direct=False cannot move a direct entry...
    cat.record("FilterOp", 1000.0, direct=False)
    assert cat.entries["FilterOp"].us == 10.0
    # ...but reconcile (serving-time ground truth) can
    cat.reconcile({"FilterOp": {"us": 30.0, "frames": 8}})
    assert cat.entries["FilterOp"].us == pytest.approx(20.0)
    # unseen keys are created outright, never flagged
    flags = cat.reconcile({"DetectOp": {"us": 77.0, "frames": 4,
                                        "pass_rate": 0.5}})
    assert flags == []
    assert cat.entries["DetectOp"].us == 77.0
    assert cat.entries["DetectOp"].pass_rate == 0.5


def test_reconcile_ignores_garbage_measurements():
    cat = CostCatalog()
    cat.record("SkipOp", 30.0)
    cat.reconcile({"SkipOp": {"us": float("nan")},
                   "CropOp": {"us": -5.0}})
    assert cat.entries["SkipOp"].us == 30.0
    assert "CropOp" not in cat.entries


# ---------------------------------------------------------------------------
# (a) PlanAudit: exact prediction reproduction + measured join
# ---------------------------------------------------------------------------

def _plans(qids):
    from repro.queries import get_query
    return [get_query(q).naive_plan() for q in qids]


def _forest(qids, catalog=None, micro_batch=16):
    from repro.scheduler.sharing_tree import SharingTreePlanner
    planner = SharingTreePlanner(catalog=catalog, micro_batch=micro_batch)
    return planner.plan(_plans(qids)), planner


def test_audit_reproduces_planner_predictions_exactly():
    # every decision in the forest re-derives to the stored cost: the
    # audit prices plans with the planner's own model and parameters
    cat = CostCatalog()
    cat.record("mllm[big]", 900.0, overhead_us=120.0, direct=True)
    for qids in (("Q2", "Q6", "Q8"), ("Q1",), ("Q1", "Q5", "Q12")):
        forest, planner = _forest(qids, catalog=cat)
        audit = PlanAudit(forest, catalog=planner.catalog,
                          micro_batch=planner.micro_batch,
                          gate_hit_rate=planner.gate_hit_rate)
        assert audit.verify_predictions() == pytest.approx(0.0, abs=1e-9)
        for row in audit.rows():
            assert row["predicted_saving_us"] == pytest.approx(
                row["predicted_indep_us"] - row["predicted_shared_us"])


def test_audit_verify_detects_stale_predictions():
    cat = CostCatalog()
    forest, planner = _forest(("Q2", "Q6"), catalog=cat)
    audit = PlanAudit(forest, catalog=cat,
                      gate_hit_rate=planner.gate_hit_rate)
    assert audit.verify_predictions() == pytest.approx(0.0, abs=1e-9)
    # mutate the catalog after planning: stored predictions are stale now
    cat.record("mllm[big]", 50_000.0, direct=True)
    assert audit.verify_predictions() > 0.1


def test_audit_measured_costs_and_drift_flagging():
    forest, planner = _forest(("Q2", "Q6", "Q8"))
    audit = PlanAudit(forest, micro_batch=16, tolerance=0.5)
    m = Metrics()
    # synthetic serving surfaces: 4 prefix-op invocations of 16 frames
    # at 2ms each, and a probed forward of 32 frames at 64ms
    for _ in range(4):
        m.observe("op_wall_us/SkipOp", 2000.0)
    m.inc("op_frames/SkipOp", 64)
    m.inc("op_rows_out/SkipOp", 32)
    m.observe("forward_device_ms/big", 64.0)
    m.inc("forward_device_frames/big", 32)
    measured = audit.measured_costs(m)
    assert measured["SkipOp"]["us"] == pytest.approx(125.0)   # 8000/64
    assert measured["SkipOp"]["pass_rate"] == pytest.approx(0.5)
    assert measured["mllm[big]"]["us"] == pytest.approx(2000.0)
    rows = audit.rows(m)
    assert all("measured_shared_us" in r for r in rows)
    # static defaults price the extract at 1200µs; measured 2000µs is
    # 1.67x — beyond the 0.5 tolerance, so shared rows flag
    flagged = [r for r in rows if r["flagged"]]
    assert flagged, rows
    # reconcile moves a catalog toward those measurements
    cat = CostCatalog()
    cat.record("mllm[big]", 500.0, direct=True)
    flags = audit.reconcile(m, cat)
    assert "mllm[big]" in flags
    assert cat.entries["mllm[big]"].us == pytest.approx(1250.0)
    assert "SkipOp" in cat.entries


def test_audit_table_and_flight_report_render(tmp_path):
    forest, planner = _forest(("Q2", "Q6"))
    audit = PlanAudit(forest, gate_hit_rate=planner.gate_hit_rate)
    table = audit.table()
    assert "Q2+Q6" in table and "pred save" in table
    m = Metrics()
    m.observe("forward_ms", 10.0)
    m.observe("forward_device_ms", 8.0)
    path = write_flight_report(
        str(tmp_path / "flight_report.md"), audit=audit, metrics=m,
        flagged=["mllm[big]"], notes=["test run"])
    body = open(path).read()
    assert "# Serving flight report" in body
    assert "Optimizer audit" in body
    assert "mllm[big]" in body
    assert "poll latency" in body        # the forward-gap section
    gap = forward_gap(m)
    assert gap["gap_ms"] == pytest.approx(2.0)
    assert gap["gap_frac"] == pytest.approx(0.2)


def test_forward_gap_none_without_probes():
    m = Metrics()
    assert forward_gap(m) is None
    m.observe("forward_ms", 10.0)
    assert forward_gap(m) is None        # observed but never probed


# ---------------------------------------------------------------------------
# (b) the bench gate against the committed baseline
# ---------------------------------------------------------------------------

BASELINE = os.path.join(REPO, "reports", "benchmarks", "baseline")


def _run_gate(baseline, current, *extra):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "bench_gate.py"),
         "--baseline", str(baseline), "--current", str(current), *extra],
        capture_output=True, text=True)


@pytest.mark.skipif(not os.path.isdir(BASELINE),
                    reason="committed baseline missing")
def test_bench_gate_passes_unmodified_and_flags_2x_slowdown(tmp_path):
    current = tmp_path / "current"
    shutil.copytree(BASELINE, current)
    # unmodified rerun: identical rows, nothing regresses
    r = _run_gate(BASELINE, current)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "REGRESSED" not in r.stdout
    # inject a 2x slowdown into every lower-is-better ms metric
    injected = 0
    for fn in os.listdir(current):
        p = current / fn
        data = json.loads(p.read_text())
        for row in data["rows"]:
            if isinstance(row["metric"], (int, float)) and \
                    row["name"].endswith("_ms"):
                row["metric"] *= 2.0
                injected += 1
        p.write_text(json.dumps(data))
    assert injected, "baseline carries no *_ms metrics to slow down"
    r = _run_gate(BASELINE, current)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSED" in r.stdout
    # warn-only mode reports but does not fail (the CI default this PR)
    r = _run_gate(BASELINE, current, "--warn-only")
    assert r.returncode == 0
    assert "REGRESSED" in r.stdout


@pytest.mark.skipif(not os.path.isdir(BASELINE),
                    reason="committed baseline missing")
def test_bench_gate_appends_report_section(tmp_path):
    current = tmp_path / "current"
    shutil.copytree(BASELINE, current)
    report = tmp_path / "flight_report.md"
    report.write_text("# Serving flight report\n")
    r = _run_gate(BASELINE, current, "--warn-only",
                  "--report", str(report))
    assert r.returncode == 0, r.stdout + r.stderr
    body = report.read_text()
    assert body.startswith("# Serving flight report")
    assert "## Bench deltas" in body


def test_bench_gate_missing_baseline_is_usage_error(tmp_path):
    r = _run_gate(tmp_path / "nope", tmp_path / "nope2")
    assert r.returncode == 2


def test_history_direction_and_compare():
    from benchmarks.history import append_history, compare, direction
    assert direction("fig_pipeline.fps") == +1
    assert direction("fig_ms.latency_p95_ms") == -1
    assert direction("fig_ms.serving") == -1
    assert direction("fig_ms.forwards") == -1
    assert direction("fig_pipeline.inflight") is None      # no guess
    base = [{"name": "a_ms", "metric": 10.0},
            {"name": "a_ms", "metric": 12.0},       # trial noise
            {"name": "fps", "metric": 100.0},
            {"name": "only_base_ms", "metric": 1.0}]
    cur = [{"name": "a_ms", "metric": 11.0},
           {"name": "fps", "metric": 40.0},
           {"name": "new_metric_ms", "metric": 5.0}]
    deltas = {d["name"]: d for d in compare(base, cur, tolerance=0.5)}
    # min-of-trials: baseline a_ms is 10, current 11 -> 1.1x, ok
    assert not deltas["a_ms"]["regressed"]
    assert deltas["a_ms"]["ratio"] == pytest.approx(1.1)
    # fps higher-is-better: 100 -> 40 is 2.5x worse, regressed
    assert deltas["fps"]["regressed"]
    # one-sided metrics never gate
    assert "only_base_ms" not in deltas
    assert "new_metric_ms" not in deltas


def test_history_append_roundtrip(tmp_path):
    from benchmarks.history import append_history, host_key
    bench = tmp_path / "bench"
    bench.mkdir()
    rows = [{"name": "x_ms", "metric": 3.0, "host_cpus": 1,
             "host_platform": "test", "host_python": "3.10",
             "jax_backend": "cpu", "jax_version": "0"}]
    (bench / "BENCH_t.json").write_text(json.dumps(
        {"section": "t", "ok": True, "rows": rows}))
    (bench / "BENCH_bad.json").write_text(json.dumps(
        {"section": "bad", "ok": False,
         "rows": [{"name": "y_ms", "metric": 1.0}]}))
    hist = tmp_path / "history.jsonl"
    assert append_history(str(bench), str(hist)) == 1
    assert append_history(str(bench), str(hist)) == 1     # appends
    lines = [json.loads(l) for l in hist.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["host_key"] == host_key(rows[0])
    assert lines[0]["rows"] == [
        {"section": "t", "name": "x_ms", "metric": 3.0}]


# ---------------------------------------------------------------------------
# (c) with models: probe keeps serving bitwise identical, reconcile flows
# ---------------------------------------------------------------------------

_FEEDS = (("tb0", 3, ("Q2", "Q6", "Q8")), ("tb1", 11, ("Q1", "Q5")))


def _run_ms(ctx, obs=None, frames=32, planner=None, probe_every=1):
    from repro.data import TollBoothStream
    from repro.queries import get_query
    from repro.scheduler import Feed, MultiStreamRuntime
    from repro.semantic import GateConfig, SemanticGate

    if obs is not None:
        ctx = dataclasses.replace(ctx, obs=obs)
    feeds = [Feed(name, TollBoothStream(seed=seed),
                  [get_query(q).naive_plan() for q in qids])
             for name, seed, qids in _FEEDS]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16, planner=planner,
                            gate=SemanticGate(GateConfig(threshold=0.06)))
    # probe aggressively in tests: every forward (default samples 1-in-8)
    ms.server.device_probe_every = probe_every
    return ms, ms.run(frames)


def test_probed_serving_bitwise_identical_with_device_timing(ctx):
    from repro.core.costs import CostCatalog
    from repro.scheduler.sharing_tree import SharingTreePlanner

    _, base = _run_ms(ctx)               # NULL_OBS default: never probes
    cat = CostCatalog()
    obs = Observability(tracer=NULL_TRACER, slo_target_ms=10_000.0)
    ms, probed = _run_ms(ctx, obs=obs,
                         planner=SharingTreePlanner(catalog=cat,
                                                    micro_batch=16))
    for name, _, qids in _FEEDS:
        for q in qids:
            assert probed.feeds[name].per_query[q].outputs == \
                base.feeds[name].per_query[q].outputs
            assert probed.feeds[name].per_query[q].window_results == \
                base.feeds[name].per_query[q].window_results
    # the probe measured real device completions, distinct from the
    # poll-quantized observed span — device time never exceeds it
    dev = obs.metrics.histogram("forward_device_ms")
    assert dev.count > 0
    gap = forward_gap(obs.metrics)
    assert gap is not None and gap["gap_ms"] >= 0
    # the reconcile pass fed serving measurements into the catalog: the
    # chosen variant's device-probed cost is now a catalog entry
    assert any(k.startswith("mllm[") for k in cat.entries), \
        sorted(cat.entries)
    # and the runtime's audit joins predictions with those measurements
    rows = ms.audit().rows(obs.metrics)
    assert rows and all("drift" in r for r in rows)


def test_unprobed_overhead_bounded_under_one_percent():
    # the probe only exists behind `obs.enabled` + a sampling check; the
    # un-probed path (NULL_OBS, or the 7-of-8 unsampled forwards) pays
    # at most the test_obs.py no-op budget plus one modulo test per
    # forward — bound it the same analytic way
    reps = 100_000
    seq = 0
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        NULL_OBS.now()
        NULL_TRACER.span("x", "forward", 0, 0)
        if seq % 8 == 0:
            pass
        seq += 1
    per_site_ns = (time.perf_counter_ns() - t0) / reps
    assert per_site_ns < 10_000
    # pessimistic: 40 instrumented sites per 5ms frame (as test_obs.py)
    assert (40 * per_site_ns) / 5e6 < 0.01
