"""Model zoo tests: smoke configs for all 10 assigned archs, decode parity,
sharded-vs-single numerical parity, gradient flow."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, smoke_config
from repro.models import LM, materialize
from repro.models.param import axes_tree
from repro.common.config import applicable_cells, SHAPE_CELLS

B, S = 2, 32


def make_batch(cfg, key=1):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, 16, cfg.d_model))
    if cfg.frontend == "patch":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, 4, cfg.d_model))
        batch["patch_pos"] = jnp.arange(4)[None, :].repeat(B, 0)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_loss_shapes_no_nans(arch):
    """Per-arch smoke test: reduced config, one forward/train step on CPU."""
    cfg = smoke_config(arch)
    lm = LM(cfg, tp=1, q_block=16)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    batch = make_batch(cfg)
    logits, aux = lm.logits_causal(params, batch, jnp.float32)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = jax.jit(lm.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    # one train (grad) step must produce finite grads
    grads = jax.grad(lambda p: lm.loss(p, batch, jnp.float32))(params)
    gn = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g)), grads, 0.0)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ["chatglm3-6b", "gemma2-2b", "mamba2-130m",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-medium", "pixtral-12b"])
def test_decode_matches_causal(arch):
    """Prefill+decode continuation == full causal forward (fp32 exact)."""
    cfg = smoke_config(arch)
    if cfg.has_moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    lm = LM(cfg, tp=1, q_block=16)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    batch = make_batch(cfg)
    tokens = batch["tokens"]
    logits_full, _ = lm.logits_causal(params, batch, jnp.float32)
    P = S - 4
    pb = dict(batch)
    pb["tokens"] = tokens[:, :P]
    cache = lm.init_cache(B, S, t_src=16, dtype=jnp.float32)
    lg, cache = lm.prefill(params, pb, cache, dtype=jnp.float32)
    np.testing.assert_allclose(lg[:, 0], logits_full[:, P - 1], atol=2e-3,
                               rtol=1e-3)
    for t in range(3):
        lg, cache = lm.decode(params, tokens[:, P + t:P + t + 1], cache,
                              jnp.int32(P + t), dtype=jnp.float32)
        np.testing.assert_allclose(lg[:, 0], logits_full[:, P + t],
                                   atol=2e-3, rtol=1e-3)


def test_moe_capacity_drops_are_only_divergence():
    """With huge capacity, MoE prefill/decode is exact vs causal."""
    cfg = smoke_config("qwen3-moe-235b-a22b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    lm = LM(cfg, tp=1, q_block=16)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    batch = make_batch(cfg)
    logits_full, _ = lm.logits_causal(params, batch, jnp.float32)
    cache = lm.init_cache(B, S, dtype=jnp.float32)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :S - 1]
    lg, cache = lm.prefill(params, pb, cache, dtype=jnp.float32)
    np.testing.assert_allclose(lg[:, 0], logits_full[:, S - 2], atol=2e-3,
                               rtol=1e-3)


def test_applicable_cells_long_context_rule():
    """long_500k only for sub-quadratic archs; decode cells for all."""
    subq = {a for a in ASSIGNED
            if "long_500k" in applicable_cells(get_config(a))}
    assert subq == {"jamba-1.5-large-398b", "mamba2-130m"}
    for a in ASSIGNED:
        cells = applicable_cells(get_config(a))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)


def test_full_configs_match_assignment():
    """Exact config numbers from the assignment sheet."""
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads, c.vocab_size) == (94, 4096, 64, 4, 151936)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (128, 8, 1536)
    c = get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (
        72, 8192, 24576, 65536)
    assert (c.moe.n_experts, c.moe.top_k) == (16, 2)
    assert c.block_pattern.count("attn+moe") == 1 and len(c.block_pattern) == 8
    c = get_config("gemma2-2b")
    assert (c.n_layers, c.d_model, c.d_ff, c.attention.window,
            c.attention.softcap, c.final_softcap) == (26, 2304, 9216, 4096,
                                                      50.0, 30.0)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (24, 768, 128)
    c = get_config("seamless-m4t-medium")
    assert c.encoder_decoder and c.n_encoder_layers == 12
    c = get_config("phi3-mini-3.8b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (32, 3072, 8192,
                                                             32064)
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (64, 6, 1408)
    c = get_config("chatglm3-6b")
    assert (c.attention.n_kv_heads, c.attention.rotary_pct) == (2, 0.5)
    c = get_config("glm4-9b")
    assert (c.n_layers, c.vocab_size) == (40, 151552)
    c = get_config("pixtral-12b")
    assert (c.n_layers, c.d_model, c.d_ff) == (40, 5120, 14336)


def test_vocab_padding_divisible_by_model_axis():
    for a in ASSIGNED:
        assert get_config(a).padded_vocab % 16 == 0


def test_param_counts_in_expected_range():
    """Config param totals should land near the advertised sizes."""
    import repro.models.model as mm

    expect = {
        "qwen3-moe-235b-a22b": (200e9, 280e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
        # NOTE: the assignment sheet's numbers (48L x 64e x d_ff 1408, all
        # layers MoE) arithmetically give ~28.5B total / ~3.3B active; the
        # family name says 16B (the HF model interleaves dense layers /
        # fewer routed experts). We implement the sheet's numbers exactly.
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "gemma2-2b": (2e9, 3.5e9),
        "phi3-mini-3.8b": (3e9, 4.5e9),
        "glm4-9b": (8e9, 11e9),
        "chatglm3-6b": (5.5e9, 7.5e9),
        "pixtral-12b": (11e9, 14e9),
        "mamba2-130m": (0.1e9, 0.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = mm.param_count_estimate(get_config(arch))
        assert lo <= n <= hi, (arch, n)
