"""Cross-stream shared-MLLM serving tests.

Covers the scheduler subsystem's contract: (a) the SharingTreePlanner
groups plans by signature-prefix subsets — including workloads whose
*global* common prefix is empty — under a cost model that can also refuse
to share; (b) the SharedExtractServer coalesces cross-stream requests into
shape-bucketed batched forwards whose per-row results match the op's solo
path bitwise; (c) the MultiStreamRuntime serves K feeds with strictly
fewer forwards than independent execution while every query's outputs stay
bitwise identical — plus a hypothesis property test over random catalog
subsets (the conventions of ``test_properties.py``).
"""
import numpy as np
import pytest

from repro.core.multiquery import share_key
from repro.data import TollBoothStream, VolleyballStream
from repro.queries import QUERIES, get_query
from repro.scheduler import (
    Feed,
    MultiStreamRuntime,
    SharedExtractServer,
    SharingTreePlanner,
)
from repro.streaming.operators import (
    MLLMExtractOp,
    OpContext,
    SinkOp,
    SkipOp,
    SourceOp,
)
from repro.streaming.plan import Plan
from repro.streaming.runtime import StreamRuntime


@pytest.fixture(scope="module")
def ctx(stream_ctx):
    # the session-scoped model stack from conftest.py (trained once)
    return stream_ctx


def _skip_plan(qid, amount=3):
    """A catalog plan with a Skip in front — a divergent signature prefix."""
    q = get_query(qid)
    ops = [SourceOp(stream_name=q.dataset), SkipOp(amount=amount),
           MLLMExtractOp(tasks=q.tasks, model="big")]
    ops += q.tail()
    ops.append(SinkOp())
    return Plan(ops, query=f"{qid}s")


def _indep(qid, ctx, stream, n):
    rt = StreamRuntime(get_query(qid).naive_plan(), ctx, micro_batch=16)
    return rt.run(stream, n)


# ---------------------------------------------------------------------------
# (a) sharing-tree planner (model-free)
# ---------------------------------------------------------------------------

def test_share_key_groups_by_prefix_and_merge_identity():
    assert share_key(get_query("Q2").naive_plan()) == \
        share_key(get_query("Q8").naive_plan())          # same model, mergeable
    assert share_key(get_query("Q2").naive_plan()) != \
        share_key(_skip_plan("Q2"))                      # Skip diverges
    assert share_key(get_query("Q2").naive_plan()) != \
        share_key(get_query("Q12").naive_plan())         # different stream


def test_planner_shares_subsets_when_global_prefix_empty():
    # tollbooth + volleyball sources: no op is common to all four plans,
    # yet each per-stream pair still factors into a shared group
    plans = [get_query(q).naive_plan() for q in ("Q2", "Q6", "Q12", "Q13")]
    assert plans[0].common_prefix(plans[2]) == 0         # truly empty
    forest = SharingTreePlanner().plan(plans)
    assert set(forest.streams) == {"tollbooth", "volleyball"}
    by_stream = {s: sorted(g.execution.queries for g in gs)
                 for s, gs in forest.streams.items()}
    assert by_stream["tollbooth"] == [["Q2", "Q6"]]
    assert by_stream["volleyball"] == [["Q12", "Q13"]]
    assert all(g.is_shared and g.saving_us > 0 for g in forest.groups())
    assert forest.n_queries == 4
    assert "global common prefix is empty" in " ".join(forest.notes)


def test_planner_splits_divergent_prefixes_within_one_stream():
    # Q2/Q6 share a plain extract; Q5s/Q9s share a Skip-prefixed one; the
    # global prefix within the stream is just the source (worthless), so
    # the tree holds two separately-shared subsets
    plans = [get_query("Q2").naive_plan(), get_query("Q6").naive_plan(),
             _skip_plan("Q5"), _skip_plan("Q9")]
    forest = SharingTreePlanner().plan(plans)
    groups = forest.streams["tollbooth"]
    assert sorted(g.execution.queries for g in groups) == \
        [["Q2", "Q6"], ["Q5s", "Q9s"]]
    skip_group = next(g for g in groups if g.execution.queries[0] == "Q5s")
    assert any(isinstance(op, SkipOp) for op in skip_group.execution.prefix)
    assert forest.describe().count("shared") == 2


def test_planner_cost_model_can_refuse_to_share():
    plans = [get_query("Q2").naive_plan(), get_query("Q6").naive_plan()]
    forest = SharingTreePlanner(min_saving_us=1e9).plan(plans)
    groups = forest.streams["tollbooth"]
    assert [g.n_queries for g in groups] == [1, 1]
    assert not any(g.is_shared for g in groups)
    assert any("-> independent" in n for n in forest.notes)


def test_planner_singleton_and_mixed_models():
    # different physical models never share an extract: separate groups
    p_big = get_query("Q2").naive_plan()
    p_small = get_query("Q6").naive_plan()
    p_small.ops[1] = MLLMExtractOp(tasks=("present", "color"), model="small")
    forest = SharingTreePlanner().plan([p_big, p_small])
    assert [g.n_queries for g in forest.streams["tollbooth"]] == [1, 1]


# ---------------------------------------------------------------------------
# (b) shared extract server
# ---------------------------------------------------------------------------

def test_server_backpressure_accounting_model_free():
    # submit/pending bookkeeping needs no models — drain is never called
    srv = SharedExtractServer(OpContext(), max_batch=32)
    f = np.zeros((5, 3, 8, 8), np.float32)
    srv.submit("big", f, feed="a")
    srv.submit("big", f, feed="a")
    srv.submit("small", f, feed="b")
    assert srv.pending_requests() == 3
    assert srv.pending_requests("a") == 2
    assert srv.pending_frames() == 15 and srv.pending_frames("b") == 5
    with pytest.raises(AssertionError):
        srv.submit("adaptive", f)        # caller must resolve the variant
    with pytest.raises(AssertionError):
        srv.submit("big", np.zeros((0, 3, 8, 8), np.float32))


def test_server_coalesces_and_matches_solo_path(ctx):
    srv = SharedExtractServer(ctx, max_batch=64)
    s1, s2 = TollBoothStream(seed=3), TollBoothStream(seed=11)
    f1, _ = s1.batch(5)
    f2, _ = s2.batch(9)
    r1 = srv.submit("big", f1.astype(np.float32), feed="a")
    r2 = srv.submit("big", f2.astype(np.float32), feed="b")
    assert not r1.done
    assert srv.drain() == 1              # one coalesced forward for both
    assert r1.done and r2.done
    assert srv.stats["coalesced_batches"] == 1
    assert srv.stats["frames"] == 14 and srv.stats["padded_frames"] == 2

    # solo path: the op's own jitted program on each stream separately
    for frames, req in ((f1, r1), (f2, r2)):
        op = MLLMExtractOp(tasks=("present", "color", "plate"), model="big")
        op.open(ctx)
        out = op.process({"frames": frames.astype(np.float32),
                          "idx": np.arange(frames.shape[0])})
        for task in ("present", "color", "plate"):
            assert np.array_equal(out["attrs"][task], req.result[task])


def test_server_dispatch_poll_protocol_and_inflight_accounting(ctx):
    # dispatch() launches async forwards up to max_inflight and returns
    # immediately; poll()/wait() retire them; the running pending counters
    # drop at dispatch (they track queued-not-dispatched work)
    srv = SharedExtractServer(ctx, max_batch=4, max_inflight=2)
    frames = TollBoothStream(seed=3).batch(4)[0].astype(np.float32)
    reqs = [srv.submit("big", frames, feed="a") for _ in range(3)]
    assert srv.pending_requests() == 3 and srv.pending_frames() == 12
    launched = srv.dispatch()
    assert launched == 2                 # max_inflight caps dispatch-ahead
    assert srv.inflight == 2
    assert srv.pending_requests() == 1 and srv.pending_frames() == 4
    assert reqs[2].result is None        # still queued
    assert srv.wait() >= 1               # blocks for the oldest forward
    assert reqs[0].done
    assert srv.drain() >= 1              # runs the remaining request
    assert all(r.done for r in reqs)
    assert srv.inflight == 0 and srv.pending_requests() == 0
    assert srv.stats["forwards"] == 3
    assert srv.stats["dispatches"] >= 2
    assert srv.stats["max_inflight_seen"] == 2
    # exact-fit single requests skip the staging copy entirely
    assert srv.stats["staging_skipped"] == 3
    # lazy materialization: all three requests saw identical frames
    for task in ("present", "color", "plate"):
        assert np.array_equal(reqs[0].result[task], reqs[1].result[task])
        assert np.array_equal(reqs[0].result[task], reqs[2].result[task])


def test_server_staging_buffers_reused_without_stale_leakage(ctx):
    srv = SharedExtractServer(ctx, max_batch=8, max_inflight=1)
    s = TollBoothStream(seed=5)
    f1 = s.batch(6)[0].astype(np.float32)     # bucket 8 -> staged + padded
    f2 = s.batch(6)[0].astype(np.float32)
    srv.submit("big", f1)
    srv.drain()
    assert srv.stats["staging_allocated"] == 1
    assert srv.stats["staging_reused"] == 0
    r2 = srv.submit("big", f2)                # same bucket: reuses buffer
    srv.drain()
    assert srv.stats["staging_allocated"] == 1
    assert srv.stats["staging_reused"] == 1
    # a reused (stale) staging buffer must not perturb results: rows match
    # the op's solo path bitwise (padding rows re-zeroed on reuse)
    op = MLLMExtractOp(tasks=("present", "color", "plate"), model="big")
    op.open(ctx)
    out = op.process({"frames": f2, "idx": np.arange(6)})
    for task in ("present", "color", "plate"):
        assert np.array_equal(out["attrs"][task], r2.result[task])
    # an exactly-full request bypasses staging
    f8 = s.batch(8)[0].astype(np.float32)
    srv.submit("big", f8)
    srv.drain()
    assert srv.stats["staging_skipped"] == 1
    assert srv.stats["staging_allocated"] == 1


def test_server_dispatch_defers_partial_buckets_while_device_fed(ctx):
    # a padded partial chunk is deferred while a forward is in flight (it
    # usually grows into a full bucket by the next dispatch), but launches
    # when the device would otherwise idle
    srv = SharedExtractServer(ctx, max_batch=8, max_inflight=2)
    s = TollBoothStream(seed=7)
    full = s.batch(8)[0].astype(np.float32)   # bucket 8: full
    part = s.batch(6)[0].astype(np.float32)   # bucket 8: padded partial
    srv.submit("big", full)
    srv.submit("big", part)
    assert srv.dispatch() == 1                # full launches, partial waits
    assert srv.pending_requests() == 1
    srv.drain()                               # barrier flushes the partial
    assert srv.stats["forwards"] == 2
    # with nothing in flight, a lone partial launches immediately
    srv.submit("big", part)
    assert srv.dispatch() == 1
    srv.drain()
    # budget bounds a single dispatch call
    srv.submit("big", full)
    srv.submit("big", full)
    assert srv.dispatch(budget=1) == 1
    assert srv.pending_requests() == 1
    srv.drain()
    # the deferral is bounded: a partial whose bucket never fills launches
    # after MAX_PARTIAL_DEFERS dispatch calls even while the device is fed
    srv.submit("big", full)
    srv.submit("big", part)
    assert srv.dispatch() == 1                # full in flight, partial deferred
    for _ in range(srv.MAX_PARTIAL_DEFERS - 1):
        assert srv.dispatch() == 0            # still deferred, counted
    assert srv.dispatch() == 1                # overdue: launches despite inflight
    srv.drain()


def test_server_buckets_by_shape_and_respects_max_batch(ctx):
    srv = SharedExtractServer(ctx, max_batch=8)
    full, _ = TollBoothStream(seed=1).batch(6)
    crop = full[:, :, 64:128, :]         # different (C,H,W): its own bucket
    srv.submit("big", full.astype(np.float32))
    srv.submit("big", crop.astype(np.float32))
    assert srv.drain() == 2              # shape buckets never mix
    # max_batch splits one variant+shape group into several forwards
    srv.reset_stats()
    for _ in range(3):
        srv.submit("big", full.astype(np.float32))
    srv.drain()
    assert srv.stats["forwards"] == 3    # 6+6 > 8 -> no 2-request chunk fits
    assert srv.stats["frames"] == 18


def test_cheap_color_and_detect_normalize_per_frame(ctx):
    # raw-vs-normalized is a per-frame decision (the make_extract_fn
    # convention): a mixed-stage batch must score each row exactly as a
    # uniform batch of that row's stage would
    from repro.streaming.operators import CheapColorFilterOp, DetectOp

    raw = TollBoothStream(seed=1).batch(4)[0].astype(np.float32)
    normed = (raw / 255.0 - 0.5) / 0.25
    mixed = np.concatenate([raw[:2], normed[2:]], axis=0)

    color = CheapColorFilterOp(color="red")
    color.open(ctx)
    import jax.numpy as jnp
    got = np.asarray(color._frac(jnp.asarray(mixed)))
    assert np.array_equal(got[:2],
                          np.asarray(color._frac(jnp.asarray(raw)))[:2])
    assert np.array_equal(got[2:],
                          np.asarray(color._frac(jnp.asarray(normed)))[2:])

    det = DetectOp()
    det.open(ctx)
    got = np.asarray(det._run(jnp.asarray(mixed)))
    assert np.array_equal(got[:2],
                          np.asarray(det._run(jnp.asarray(raw)))[:2])
    assert np.array_equal(got[2:],
                          np.asarray(det._run(jnp.asarray(normed)))[2:])


# ---------------------------------------------------------------------------
# (c) multi-stream runtime: bitwise equivalence + fewer forwards
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multistream_matches_independent_bitwise(ctx):
    feeds = [
        Feed("tb0", TollBoothStream(seed=42),
             [get_query(q).naive_plan() for q in ("Q2", "Q6")]),
        Feed("tb1", TollBoothStream(seed=7),
             [get_query("Q8").naive_plan()]),
        Feed("vb0", VolleyballStream(seed=5),
             [get_query(q).naive_plan() for q in ("Q12", "Q13")]),
    ]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16)
    res = ms.run(64)
    assert res.n_feeds == 3 and res.n_queries == 5

    makers = {"tb0": lambda: TollBoothStream(seed=42),
              "tb1": lambda: TollBoothStream(seed=7),
              "vb0": lambda: VolleyballStream(seed=5)}
    indep_forwards = 0
    for fname, qids in (("tb0", ("Q2", "Q6")), ("tb1", ("Q8",)),
                        ("vb0", ("Q12", "Q13"))):
        for qid in qids:
            plan = get_query(qid).naive_plan()
            rt = StreamRuntime(plan, ctx, micro_batch=16)
            ind = rt.run(makers[fname](), 64)
            indep_forwards += sum(op.forwards for op in plan.ops
                                  if isinstance(op, MLLMExtractOp))
            shared_q = res.feeds[fname].per_query[qid]
            assert shared_q.outputs == ind.outputs
            assert shared_q.window_results == ind.window_results
            assert get_query(qid).evaluate(shared_q) == \
                get_query(qid).evaluate(ind)
    # the serving claim: coalescing makes forwards strictly cheaper than
    # the sum of independent runs (and even than one forward per feed
    # micro-batch: 3 feeds * 4 micro-batches = 12)
    assert res.server_stats["forwards"] < indep_forwards
    assert res.server_stats["forwards"] < 12
    assert res.server_stats["coalesced_batches"] >= 1
    # model load counts union extracts once per feed frame
    assert res.mllm_frames == 3 * 64


@pytest.mark.slow
def test_pipelined_serving_matches_synchronous_drain(ctx):
    # the pipelined dispatch-ahead loop (default) and the lock-step
    # barrier drain produce bitwise-identical per-query results; the
    # pipelined run actually overlaps (>= 2 in-flight forwards seen)
    def feeds():
        return [
            Feed("tb0", TollBoothStream(seed=42),
                 [get_query(q).naive_plan() for q in ("Q2", "Q6")]),
            Feed("tb1", TollBoothStream(seed=7),
                 [get_query("Q8").naive_plan()]),
            Feed("tb2", TollBoothStream(seed=11),
                 [get_query("Q1").naive_plan()]),
            Feed("vb0", VolleyballStream(seed=5),
                 [get_query(q).naive_plan() for q in ("Q12", "Q13")]),
        ]

    sync = MultiStreamRuntime(feeds(), ctx, micro_batch=16,
                              pipelined=False).run(48)
    pipe = MultiStreamRuntime(feeds(), ctx, micro_batch=16).run(48)
    for fname in ("tb0", "tb1", "tb2", "vb0"):
        for qid, sq in sync.feeds[fname].per_query.items():
            pq = pipe.feeds[fname].per_query[qid]
            assert pq.outputs == sq.outputs
            assert pq.window_results == sq.window_results
    assert pipe.mllm_frames == sync.mllm_frames
    assert pipe.server_stats["max_inflight_seen"] >= 2
    assert pipe.server_stats["dispatches"] >= 1


@pytest.mark.slow
def test_multistream_run_is_repeatable(ctx):
    # warmup=1 rewinds streams and resets ops/sinks/accumulators: a second
    # run() is a fresh measurement, not an accumulation over the first
    feeds = [Feed("a", TollBoothStream(seed=2),
                  [get_query(q).naive_plan() for q in ("Q2", "Q6")])]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16)
    r1 = ms.run(32)
    r2 = ms.run(32)
    for q in ("Q2", "Q6"):
        assert r2.feeds["a"].per_query[q].outputs == \
            r1.feeds["a"].per_query[q].outputs
        assert r2.feeds["a"].per_query[q].window_results == \
            r1.feeds["a"].per_query[q].window_results
    assert r2.mllm_frames == r1.mllm_frames == 32
    assert len(r2.feeds["a"].per_query["Q2"].labels) == 32


@pytest.mark.slow
def test_multistream_heterogeneous_frame_budgets(ctx):
    feeds = [
        Feed("a", TollBoothStream(seed=2), [get_query("Q2").naive_plan()]),
        Feed("b", TollBoothStream(seed=9), [get_query("Q6").naive_plan()]),
    ]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16, max_pending=1)
    res = ms.run({"a": 48, "b": 16})
    assert res.feeds["a"].n_frames == 48 and res.feeds["b"].n_frames == 16
    ind_a = _indep("Q2", ctx, TollBoothStream(seed=2), 48)
    ind_b = _indep("Q6", ctx, TollBoothStream(seed=9), 16)
    assert res.feeds["a"].per_query["Q2"].outputs == ind_a.outputs
    assert res.feeds["b"].per_query["Q6"].outputs == ind_b.outputs
    assert res.feeds["b"].per_query["Q6"].window_results == \
        ind_b.window_results


# ---------------------------------------------------------------------------
# the sharing-tree equivalence property (hypothesis drives this over random
# subsets in test_properties.py; here it runs on fixed adversarial subsets
# so the property is exercised even where hypothesis is unavailable)
# ---------------------------------------------------------------------------

PROP_FRAMES = 48


def assert_sharing_tree_equals_independent(ctx, qids, seed,
                                           n_frames=PROP_FRAMES):
    """For ANY subset of the catalog — including mixed tollbooth+volleyball
    subsets whose global common prefix is empty — executing the sharing
    tree over one feed per dataset yields bitwise the outputs of N
    independent runs, and every query lands in exactly one tree group."""
    qids = sorted(qids)
    datasets = sorted({QUERIES[q].dataset for q in qids})

    def make_stream(ds):
        return TollBoothStream(seed=seed) if ds == "tollbooth" \
            else VolleyballStream(seed=seed)

    forest = SharingTreePlanner().plan(
        [get_query(q).naive_plan() for q in qids])
    placed = sorted(q for g in forest.groups() for q in g.execution.queries)
    assert placed == qids                 # exactly-once partition

    feeds = [Feed(ds, make_stream(ds),
                  [get_query(q).naive_plan() for q in qids
                   if QUERIES[q].dataset == ds])
             for ds in datasets]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16)
    res = ms.run(n_frames)
    for q in qids:
        ds = QUERIES[q].dataset
        ind = _indep(q, ctx, make_stream(ds), n_frames)
        shared_q = res.feeds[ds].per_query[q]
        assert shared_q.outputs == ind.outputs
        assert shared_q.window_results == ind.window_results


@pytest.mark.slow
@pytest.mark.parametrize("qids,seed", [
    (("Q2", "Q12"), 101),                # no global prefix, two singletons
    (("Q3", "Q7", "Q9", "Q13"), 77),     # plate trio shares; Q13 alone
])
def test_sharing_tree_equivalence_fixed_subsets(ctx, qids, seed):
    assert_sharing_tree_equals_independent(ctx, qids, seed)
