"""Shared fixtures.

``stream_ctx`` trains the tiny stream operator models ONCE per session —
test modules that need an OpContext (scheduler, property tests) depend on
it instead of training their own copy, which would double the dominant
fixture cost of the slow tier.
"""
import pytest


@pytest.fixture(scope="session")
def stream_ctx():
    # tiny training: enough for the plumbing; accuracy is benchmarks' job
    from repro.streaming.pretrain import quick_stream_models

    return quick_stream_models()
