"""Shared fixtures.

``stream_ctx`` trains the tiny stream operator models ONCE per session —
test modules that need an OpContext (scheduler, property tests) depend on
it instead of training their own copy, which would double the dominant
fixture cost of the slow tier.
"""
import pytest


@pytest.fixture(scope="session")
def stream_ctx():
    # tiny training: enough for the plumbing; accuracy is benchmarks' job
    from repro.streaming.pretrain import train_stream_models

    return train_stream_models(steps_mllm=40, steps_small=20, steps_det=30,
                               cache_dir=None, verbose=False)
