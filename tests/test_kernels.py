"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.int8_matmul.ops import int8_matmul, matmul_int8_dynamic
from repro.kernels.int8_matmul.ref import (
    int8_matmul_ref, quantize_colwise, quantize_rowwise)
from repro.kernels.ssd_scan.ops import ssd
from repro.models.ssm import _ssd_chunked
from repro.kernels.fused_preprocess.ops import fused_preprocess
from repro.kernels.fused_preprocess.ref import fused_preprocess_ref
from repro.kernels.frame_diff.ops import frame_diff
from repro.kernels.frame_diff.ref import frame_diff_ref
from repro.kernels.fused_prefix.ops import fused_prefix
from repro.kernels.fused_prefix.ref import fused_prefix_ref
from repro.kernels.fused_prefix.kernel import out_frame_shape


def rnd(i, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(jax.random.PRNGKey(i), shape)).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hk,g,s,d", [
    (1, 1, 1, 64, 32),
    (2, 2, 2, 128, 32),
    (1, 2, 4, 256, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["causal", "softcap", "window", "bidir"])
def test_flash_attention_sweep(b, hk, g, s, d, dtype, mode):
    q = rnd(0, (b, hk, g, s, d), dtype)
    k = rnd(1, (b, hk, s, d), dtype)
    v = rnd(2, (b, hk, s, d), dtype)
    kw = dict(causal=True)
    if mode == "softcap":
        kw["cap"] = 20.0
    elif mode == "window":
        kw["window"] = s // 4
    elif mode == "bidir":
        kw = dict(causal=False)
    out = flash_attention_kernel(q, k, v, bq=32, bk=32, interpret=True, **kw)
    ref = flash_attention_ref(q, k, v, **kw)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_model_layout():
    b, s, h, hk, d = 2, 128, 8, 2, 32
    q, k, v = rnd(0, (b, s, h, d)), rnd(1, (b, s, hk, d)), rnd(2, (b, s, hk, d))
    out = flash_attention(q, k, v, causal=True, interpret=True)
    g = h // hk
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b, hk, g, s, d),
        k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), causal=True)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hk,d,nsplit", [
    (2, 256, 4, 2, 32, 4),
    (1, 512, 8, 8, 64, 8),
    (3, 128, 4, 1, 32, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, h, hk, d, nsplit, dtype):
    q = rnd(0, (b, 1, h, d), dtype)
    k = rnd(1, (b, s, hk, d), dtype)
    v = rnd(2, (b, s, hk, d), dtype)
    kv_len = jnp.asarray(
        np.random.RandomState(0).randint(1, s + 1, (b, 1)), jnp.int32)
    out = decode_attention(q, k, v, kv_len, nsplit=nsplit, interpret=True)
    g = h // hk
    ref = decode_attention_ref(q[:, 0].reshape(b, hk, g, d), k, v, kv_len)
    ref = ref.reshape(b, 1, h, d)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_decode_attention_window():
    b, s, h, hk, d = 2, 256, 4, 2, 32
    q, k, v = rnd(0, (b, 1, h, d)), rnd(1, (b, s, hk, d)), rnd(2, (b, s, hk, d))
    kv_len = jnp.asarray([[200], [256]], jnp.int32)
    out = decode_attention(q, k, v, kv_len, window=64, interpret=True)
    g = h // hk
    ref = decode_attention_ref(q[:, 0].reshape(b, hk, g, d), k, v, kv_len,
                               window=64).reshape(b, 1, h, d)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 256),
                                   (64, 128, 512)])
def test_int8_matmul_sweep(m, k, n):
    x = rnd(0, (m, k))
    w = rnd(1, (k, n))
    xq, sx = quantize_rowwise(x)
    wq, sw = quantize_colwise(w)
    out = int8_matmul(xq, wq, sx, sw, interpret=True)
    ref = int8_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
    # quantization error against fp32 ground truth stays bounded
    rel = float(jnp.max(jnp.abs(out - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.05


def test_int8_dynamic_quant():
    x = rnd(0, (64, 128))
    w = rnd(1, (128, 256))
    wq, sw = quantize_colwise(w)
    out = matmul_int8_dynamic(x, wq, sw, interpret=True)
    rel = float(jnp.max(jnp.abs(out - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.05


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,h,p,g,n,q", [
    (2, 128, 4, 16, 2, 8, 32),
    (1, 64, 2, 32, 1, 16, 16),
    (2, 256, 8, 16, 4, 8, 64),
])
def test_ssd_kernel_vs_model(b, l, h, p, g, n, q):
    x = rnd(0, (b, l, h, p))
    dt = jax.nn.softplus(rnd(1, (b, l, h)))
    a = -jnp.exp(rnd(2, (h,), scale=0.2))
    bm = rnd(3, (b, l, g, n), scale=0.3)
    cm = rnd(4, (b, l, g, n), scale=0.3)
    d = jnp.ones((h,))
    y0, s0 = _ssd_chunked(x, dt, a, bm, cm, d, q)
    y1, s1 = ssd(x, dt, a, bm, cm, d, chunk=q, interpret=True)
    np.testing.assert_allclose(y1, y0, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s0, atol=1e-4, rtol=1e-4)


def test_ssd_matches_sequential_recurrence():
    b, l, h, p, g, n, q = 1, 64, 2, 8, 1, 4, 16
    x = rnd(0, (b, l, h, p))
    dt = jax.nn.softplus(rnd(1, (b, l, h)))
    a = -jnp.exp(rnd(2, (h,), scale=0.2))
    bm, cm = rnd(3, (b, l, g, n), scale=0.3), rnd(4, (b, l, g, n), scale=0.3)
    d = jnp.ones((h,))
    y, _ = ssd(x, dt, a, bm, cm, d, chunk=q, interpret=True)
    xs, dts, As = map(np.asarray, (x, dt, a))
    Bh = np.repeat(np.asarray(bm), h // g, 2)
    Ch = np.repeat(np.asarray(cm), h // g, 2)
    st = np.zeros((b, h, n, p))
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        dA = np.exp(dts[:, t] * As)
        st = dA[:, :, None, None] * st + (
            dts[:, t][:, :, None, None] * Bh[:, t][:, :, :, None]
            * xs[:, t][:, :, None, :])
        ys[:, t] = np.einsum("bhn,bhnp->bhp", Ch[:, t], st) + xs[:, t]
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused preprocess / frame diff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("crop,factor,grey", [
    ((0, 0, 128, 256), 1, False),
    ((0, 0, 128, 256), 2, False),
    ((32, 128, 64, 128), 2, True),
    ((96, 0, 32, 256), 4, False),
])
def test_fused_preprocess_sweep(crop, factor, grey):
    f = jax.random.randint(jax.random.PRNGKey(2), (2, 3, 128, 256), 0, 256,
                           jnp.uint8)
    out = fused_preprocess(f, crop=crop, factor=factor, grey=grey,
                           interpret=True)
    ref = fused_preprocess_ref(f, crop=crop, factor=factor, grey=grey)
    assert out.shape == ref.shape
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("regions", [(1, 1), (4, 4), (4, 8)])
def test_frame_diff_sweep(regions):
    f = jax.random.randint(jax.random.PRNGKey(2), (2, 3, 128, 256), 0, 256,
                           jnp.uint8)
    p = jax.random.randint(jax.random.PRNGKey(3), (2, 3, 128, 256), 0, 256,
                           jnp.uint8)
    out = frame_diff(f, p, regions=regions, interpret=True)
    ref = frame_diff_ref(f, p, regions=regions)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
    # identical frames diff to zero
    z = frame_diff(f, f, regions=regions, interpret=True)
    np.testing.assert_allclose(z, np.zeros_like(z), atol=1e-7)


# ---------------------------------------------------------------------------
# fused prefix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    # the canonical optimized-plan prefix: skip diff + color filter +
    # crop/downscale/normalize
    (("diff", (4, 8)), ("color", (190., 40., 40.), None),
     ("preprocess", (64, 0, 64, 256), 2, False)),
    # crop then greyscale preprocess (grey re-expansion inlined)
    (("diff", (4, 4)), ("crop", (32, 0, 64, 256)),
     ("preprocess", (0, 0, 64, 256), 2, True)),
    # two color filters, one ROI-restricted; no transform stages
    (("color", (190., 40., 40.), (0, 0, 64, 128)),
     ("color", (40., 40., 190.), None)),
    # transform-only chain (no diff, no filters)
    (("crop", (0, 64, 128, 128)), ("preprocess", (0, 0, 128, 128), 4, False)),
])
def test_fused_prefix_sweep(spec):
    from repro.semantic.signature import signature_layout

    b = 4
    f = jax.random.randint(jax.random.PRNGKey(2), (b, 3, 128, 256), 0, 256,
                           jnp.uint8)
    p = jax.random.randint(jax.random.PRNGKey(3), (b, 3, 128, 256), 0, 256,
                           jnp.uint8)
    gy, gx, _, proj = signature_layout(out_frame_shape(spec, (3, 128, 256)))
    spec = spec + (("signature", (gy, gx)),)
    has_diff = any(s[0] == "diff" for s in spec)
    prevs = p if has_diff else None
    out = fused_prefix(f, prevs, jnp.asarray(proj), spec=spec,
                       interpret=True)
    ref = fused_prefix_ref(f, prevs, jnp.asarray(proj), spec=spec)
    for name, o, r in zip(("d", "fracs", "x", "feats", "emb"), out, ref):
        if r is None:
            assert o is None
        elif name == "fracs":
            assert len(o) == len(r)
            for a, bb in zip(o, r):
                np.testing.assert_allclose(a, bb, atol=1e-5, rtol=1e-5)
        else:
            assert o.shape == r.shape
            np.testing.assert_allclose(o, r, atol=1e-5, rtol=1e-5)
