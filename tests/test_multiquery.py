"""Multi-query shared-execution runtime tests.

Covers the PR's contract: (a) shared-prefix results are bitwise identical
to independent execution per query, (b) total MLLM load under sharing is
strictly below the independent sum, (c) aligned snapshot/restore
round-trips across the fan-out, (d) the final partial tumbling window is
flushed at end of stream — plus the Op.reset() warmup contract the runtime
now relies on.
"""
import numpy as np
import pytest

from repro.core.multiquery import factor_plans, merge_mllm_column
from repro.data import TollBoothStream
from repro.queries import QUERIES, get_query
from repro.streaming.multiquery import MultiQueryRuntime
from repro.streaming.operators import (
    MLLMExtractOp,
    OpContext,
    SinkOp,
    SkipOp,
    SourceOp,
    WindowAggOp,
)
from repro.streaming.plan import Plan
from repro.streaming.pretrain import train_stream_models
from repro.streaming.runtime import StreamRuntime


@pytest.fixture(scope="module")
def ctx():
    # tiny training: enough for the plumbing; accuracy is benchmarks' job
    return train_stream_models(steps_mllm=40, steps_small=20, steps_det=30,
                               cache_dir=None, verbose=False)


MQ_QIDS = ("Q2", "Q6", "Q8")          # filter-only, window, divergent filter


def _indep(qid, ctx, seed, n):
    rt = StreamRuntime(get_query(qid).naive_plan(), ctx, micro_batch=16)
    return rt.run(TollBoothStream(seed=seed), n)


# ---------------------------------------------------------------------------
# planner pass (model-free)
# ---------------------------------------------------------------------------

def test_factor_plans_merges_mllm_union():
    plans = [get_query(q).naive_plan() for q in MQ_QIDS]
    sh = factor_plans(plans)
    assert [op.name for op in sh.prefix][0].startswith("source")
    merged = sh.prefix[1]
    assert isinstance(merged, MLLMExtractOp)
    # union of ("present","color"), ("present","color"), ("present","color",
    # "plate") — every requested task exactly once
    assert set(merged.tasks) == {"present", "color", "plate"}
    assert len(sh.tails) == 3
    for tail in sh.tails:
        assert isinstance(tail[-1], SinkOp)


def test_factor_plans_stops_at_divergence_and_sink():
    # identical plans: prefix extends through the filter but never eats a sink
    p1, p2 = get_query("Q2").naive_plan(), get_query("Q2").naive_plan()
    sh = factor_plans([p1, p2])
    assert len(sh.prefix) == 3                      # source, mllm, filter
    assert all(len(t) == 1 and isinstance(t[0], SinkOp) for t in sh.tails)
    assert sh.queries == ["Q2", "Q2#1"]             # no per_query collision
    # adversarial: a literal "Q2#1" submission must not collide either
    p3, p4, p5 = (get_query("Q2").naive_plan() for _ in range(3))
    p4.query = "Q2#1"
    ids = factor_plans([p3, p4, p5]).queries
    assert ids == ["Q2", "Q2#1", "Q2#2"] and len(set(ids)) == 3
    # different models never merge
    assert merge_mllm_column(
        [MLLMExtractOp(tasks=("present",), model="big"),
         MLLMExtractOp(tasks=("present",), model="small")]) is None


def test_factor_plans_rejects_mixed_streams():
    with pytest.raises(AssertionError):
        factor_plans([get_query("Q2").naive_plan(),
                      get_query("Q12").naive_plan()])


def test_plan_common_prefix_api():
    a = get_query("Q4").naive_plan()
    b = get_query("Q4").naive_plan()
    n = a.common_prefix(b)
    assert n == len(a.ops) - 1                      # everything but the sink
    prefix, suffix = a.split_at(n)
    assert len(prefix) == n and isinstance(suffix[-1], SinkOp)
    assert get_query("Q1").naive_plan().common_prefix(
        get_query("Q2").naive_plan()) == 1          # tasks differ at mllm


# ---------------------------------------------------------------------------
# (a) + (b): exact-match fan-out, reduced model load
# ---------------------------------------------------------------------------

def test_shared_matches_independent_bitwise(ctx):
    plans = [get_query(q).naive_plan() for q in MQ_QIDS]
    mq = MultiQueryRuntime(plans, ctx, micro_batch=16)
    shared = mq.run(TollBoothStream(seed=42), 96)
    for qid in MQ_QIDS:
        ind = _indep(qid, ctx, 42, 96)
        assert shared.per_query[qid].outputs == ind.outputs
        assert shared.per_query[qid].window_results == ind.window_results
        assert get_query(qid).evaluate(shared.per_query[qid]) == \
            get_query(qid).evaluate(ind)


def test_pipelined_server_path_matches_synchronous(ctx):
    # server= switches run() to the dispatch-ahead pipelined path; results
    # (outputs, windows, model load, counts) must match the in-line
    # synchronous path bitwise, and the run must actually overlap
    from repro.scheduler import SharedExtractServer

    plans = [get_query(q).naive_plan() for q in MQ_QIDS]
    sync_rt = MultiQueryRuntime([p.clone() for p in plans], ctx,
                                micro_batch=16)
    sync = sync_rt.run(TollBoothStream(seed=42), 64)
    srv = SharedExtractServer(ctx)
    pipe_rt = MultiQueryRuntime([p.clone() for p in plans], ctx,
                                micro_batch=16, server=srv)
    pipe = pipe_rt.run(TollBoothStream(seed=42), 64)
    for qid in MQ_QIDS:
        assert pipe.per_query[qid].outputs == sync.per_query[qid].outputs
        assert pipe.per_query[qid].window_results == \
            sync.per_query[qid].window_results
        assert pipe.per_query[qid].op_input_counts == \
            sync.per_query[qid].op_input_counts
    assert pipe.mllm_frames == sync.mllm_frames == 64
    # dispatch-ahead actually ran (>= 2 async dispatches); the peak
    # in-flight depth is timing-dependent on a fast device, so the
    # deterministic >= 2 claim lives in the server protocol unit test
    assert srv.stats["dispatches"] >= 2
    assert srv.stats["max_inflight_seen"] >= 1
    # a second run is a fresh measurement, identical to the first
    again = pipe_rt.run(TollBoothStream(seed=42), 64)
    for qid in MQ_QIDS:
        assert again.per_query[qid].outputs == pipe.per_query[qid].outputs


def test_shared_mllm_frames_strictly_less(ctx):
    plans = [get_query(q).naive_plan() for q in MQ_QIDS]
    mq = MultiQueryRuntime(plans, ctx, micro_batch=16)
    shared = mq.run(TollBoothStream(seed=7), 64)
    indep_sum = sum(_indep(q, ctx, 7, 64).mllm_frames for q in MQ_QIDS)
    assert shared.mllm_frames < indep_sum
    assert shared.mllm_frames == 64                # union extract, once/frame
    assert shared.n_queries == 3


# ---------------------------------------------------------------------------
# (c): snapshot/restore across the fan-out
# ---------------------------------------------------------------------------

def test_snapshot_restore_roundtrip(ctx):
    qids = ("Q6", "Q8")
    plans = [get_query(q).naive_plan() for q in qids]
    mq = MultiQueryRuntime(plans, ctx, micro_batch=16)
    s = TollBoothStream(seed=13)
    mq.run(s, 48, warmup=1, flush=False)           # first segment
    st = mq.snapshot()
    assert st["source_index"] == 48
    cont = mq.run(s, 48, warmup=0, flush=True)     # continue to frame 96
    # model load is per-run, not lifetime: the resumed segment saw 48 frames
    assert cont.mllm_frames == 48

    # resume: replay the source from the recorded offset into the restored
    # operator state — must reproduce the continuation exactly, even with
    # the default warmup (restore() suppresses the warmup reset)
    mq.restore(st)
    s2 = TollBoothStream(seed=13)
    s2.batch(48)                                   # replay to the offset
    resumed = mq.run(s2, 48, flush=True)
    for qid in qids:
        assert resumed.per_query[qid].outputs == cont.per_query[qid].outputs
        assert resumed.per_query[qid].window_results == \
            cont.per_query[qid].window_results


# ---------------------------------------------------------------------------
# (d): end-of-stream flush of the final partial window
# ---------------------------------------------------------------------------

def test_window_flush_emits_final_partial():
    op = WindowAggOp(kind="top_color", window=16)
    b = {"frames": np.zeros((10, 1, 1, 1)), "idx": np.arange(10),
         "attrs": {"color": np.zeros(10, np.int64)}}
    out = op.process(b)
    assert "window_results" not in out
    fb = op.flush()
    res = fb["window_results"][0]
    assert res["partial"] and res["window"] == (0, 16)
    assert res["top_color"] == "red" and res["n"] == 10
    # non-destructive early firing: the stream can continue and the window
    # still closes normally with its full contents
    b2 = {"frames": np.zeros((8, 1, 1, 1)), "idx": np.arange(10, 18),
          "attrs": {"color": np.ones(8, np.int64)}}
    out2 = op.process(b2)
    closed = out2["window_results"][0]
    assert closed["window"] == (0, 16) and "partial" not in closed
    assert closed["n"] == 16


def test_runtime_flushes_partial_window_model_free():
    # window 32 over 40 frames: one closed window + one flushed partial
    plan = Plan([SourceOp(), WindowAggOp(kind="top_color", window=32),
                 SinkOp()])
    rt = StreamRuntime(plan, OpContext(), micro_batch=16)
    res = rt.run(TollBoothStream(seed=3), 40, warmup=0)
    assert [w["window"] for w in res.window_results] == [(0, 32), (32, 64)]
    assert res.window_results[-1]["partial"]


def test_segmented_flush_does_not_corrupt_windows():
    """Flush is non-destructive early firing: a run segmented (with flush
    after each segment) closes exactly the same windows as one continuous
    run — partials are refinements, never reassignments."""
    def make_rt():
        return StreamRuntime(
            Plan([SourceOp(), WindowAggOp(kind="top_color", window=32),
                  SinkOp()]), OpContext(), micro_batch=16)

    cont = make_rt().run(TollBoothStream(seed=9), 80, warmup=0)
    rt = make_rt()
    s = TollBoothStream(seed=9)
    seg1 = rt.run(s, 40, warmup=0, flush=True)
    seg2 = rt.run(s, 40, warmup=0, flush=True)
    seg_windows = seg1.window_results + seg2.window_results

    def closed(wins):
        return [w for w in wins if not w.get("partial")]

    assert closed(seg_windows) == closed(cont.window_results)
    assert seg_windows[-1] == cont.window_results[-1]   # same final partial


def test_partial_window_superseded_by_closed():
    """Evaluator consumer: a closed window result supersedes the partial
    early-firing of the same span, keeping positional indexing aligned."""
    from repro.queries.catalog import _window_results

    r = type("R", (), {"window_results": [
        {"kind": "top_color", "window": (0, 32), "top_color": "red"},
        {"kind": "top_color", "window": (32, 64), "partial": True,
         "top_color": "blue"},
        {"kind": "top_color", "window": (32, 64), "top_color": "red"},
        {"kind": "top_color", "window": (64, 96), "partial": True,
         "top_color": "grey"},
    ]})()
    wins = _window_results(r, "top_color")
    assert [w["window"] for w in wins] == [(0, 32), (32, 64), (64, 96)]
    assert wins[1]["top_color"] == "red" and not wins[1].get("partial")
    assert wins[2].get("partial")                  # final partial survives


def test_multiquery_flushes_partial_window(ctx):
    # unfiltered window plan: every frame reaches the window op, so the
    # tumble/flush boundary is deterministic regardless of model quality
    def window_plan(qid):
        return Plan([SourceOp(stream_name="tollbooth"),
                     MLLMExtractOp(tasks=("present", "color")),
                     WindowAggOp(kind="top_color", window=256), SinkOp()],
                    query=qid)

    mq = MultiQueryRuntime([window_plan("W1"), window_plan("W2")], ctx,
                           micro_batch=16)
    shared = mq.run(TollBoothStream(seed=21), 300)  # window=256 -> partial
    for qid in ("W1", "W2"):
        wins = shared.per_query[qid].window_results
        assert [w["window"] for w in wins] == [(0, 256), (256, 512)]
        assert wins[-1].get("partial")


# ---------------------------------------------------------------------------
# Op.reset() contract (warmup must not pollute the measured stream)
# ---------------------------------------------------------------------------

def test_reset_contract_model_free():
    skip = SkipOp(amount=3)
    skip._prev, skip._skip_left = np.zeros((3, 4, 4)), 2
    skip.reset()
    assert skip._prev is None and skip._skip_left == 0

    win = WindowAggOp(kind="top_color", window=8)
    win._buf, win._window_start = [{"idx": 1}], 8
    win.reset()
    assert win._buf == [] and win._window_start == 0

    mllm = MLLMExtractOp(tasks=("present",), model="adaptive")
    mllm.frames_processed, mllm._density_ema = 99, 0.01
    mllm.reset()
    assert mllm.frames_processed == 0 and mllm._density_ema == 0.5

    sink = SinkOp()
    sink.collected = [{"idx": 0}]
    sink.reset()
    assert sink.collected == []


def test_warmup_resets_adaptive_density_ema(ctx):
    """Regression: warmup used to leave _density_ema polluted, skewing the
    first big-vs-pruned decision of the measured stream."""
    def make_plan():
        return Plan([SourceOp(), MLLMExtractOp(
            tasks=("present", "color"), model="adaptive"), SinkOp()])

    polluted = make_plan()
    rt1 = StreamRuntime(polluted, ctx, micro_batch=8)
    polluted.ops[1]._density_ema = 0.0             # as a stale warmup leaves it
    res1 = rt1.run(TollBoothStream(seed=17), 32, warmup=1)

    fresh = make_plan()
    rt2 = StreamRuntime(fresh, ctx, micro_batch=8)
    res2 = rt2.run(TollBoothStream(seed=17), 32, warmup=1)
    assert res1.outputs == res2.outputs
    assert polluted.ops[1]._density_ema == fresh.ops[1]._density_ema


def test_micro_batch_hint_threaded(ctx):
    plan = get_query("Q2").naive_plan()
    StreamRuntime(plan, ctx, micro_batch=8)
    assert plan.ops[1]._micro_batch_hint == 8
