"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.common.sharding import (DEFAULT_RULES, logical_to_mesh,
                                   rules_scope)
from repro.common.utils import ceil_div, pad_to_multiple
from repro.kernels.int8_matmul.ref import (int8_matmul_ref, quantize_colwise,
                                           quantize_rowwise)
from repro.models.attention import head_layout
from repro.common.config import AttentionConfig
from repro.streaming.operators import FilterOp, WindowAggOp, _mask_batch
from repro.training.optimizer import _dq8, _dq8_v, _q8, _q8_v

SETTINGS = dict(max_examples=30, deadline=None)


@given(st.integers(1, 10_000), st.integers(1, 512))
@settings(**SETTINGS)
def test_pad_to_multiple_props(x, m):
    p = pad_to_multiple(x, m)
    assert p % m == 0 and p >= x and p - x < m
    assert ceil_div(x, m) * m == p


@given(st.integers(1, 128), st.integers(1, 64), st.sampled_from([1, 2, 4, 8,
                                                                 16]))
@settings(**SETTINGS)
def test_head_layout_invariants(h, kv, tp):
    """TP head layout: padded q heads divide tp; kv map is grouping-valid."""
    kv = min(kv, h)
    att = AttentionConfig(n_heads=h, n_kv_heads=kv, head_dim=16)
    hq_p, hkv_e, kv_map = head_layout(att, tp)
    assert hq_p % tp == 0 and hq_p >= h
    assert hkv_e % tp == 0 or hkv_e == att.n_kv_heads
    assert hq_p % hkv_e == 0                  # even GQA grouping
    assert len(kv_map) == hkv_e
    assert kv_map.min() >= 0 and kv_map.max() < kv
    assert np.all(np.diff(kv_map) >= 0)       # monotone replication


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_int8_moment_quant_bounds(seed):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (8, 64)))
    q, s = _q8(jnp.asarray(x))
    back = np.asarray(_dq8(q, s))
    rowmax = np.abs(x).max(-1, keepdims=True) + 1e-12
    assert np.all(np.abs(back - x) <= rowmax / 127 + 1e-6)
    # v-path: non-negative in, non-negative out
    v = x * x
    vq, vs = _q8_v(jnp.asarray(v))
    assert np.all(np.asarray(_dq8_v(vq, vs)) >= 0)


@given(st.integers(0, 2**31 - 1), st.integers(8, 64), st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_int8_matmul_error_bound(seed, m, k):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, 16))
    xq, sx = quantize_rowwise(x)
    wq, sw = quantize_colwise(w)
    out = np.asarray(int8_matmul_ref(xq, wq, sx, sw))
    ref = np.asarray(x @ w)
    # per-element error bound: |e| <= (|x| row-areas) * quant steps
    denom = np.abs(ref).max() + 1e-6
    assert np.abs(out - ref).max() / denom < 0.08


@given(st.lists(st.booleans(), min_size=1, max_size=32))
@settings(**SETTINGS)
def test_mask_batch_preserves_order_and_alignment(keeps):
    n = len(keeps)
    batch = {"frames": np.arange(n * 4).reshape(n, 4).astype(np.uint8),
             "idx": np.arange(n),
             "attrs": {"color": np.arange(n)}}
    out = _mask_batch(batch, np.asarray(keeps))
    kept = [i for i, k in enumerate(keeps) if k]
    assert list(out["idx"]) == kept
    assert list(out["attrs"]["color"]) == kept
    np.testing.assert_array_equal(out["frames"][:, 0],
                                  np.asarray(kept) * 4)


@given(st.integers(1, 200), st.integers(8, 64))
@settings(**SETTINGS)
def test_window_agg_tumbles_exactly(n, window):
    """Every closed window covers exactly `window` indices, no gaps."""
    op = WindowAggOp(kind="top_color", window=window)
    batch = {"frames": np.zeros((n, 1, 1, 1)), "idx": np.arange(n),
             "attrs": {"color": np.zeros(n, np.int64)}}
    out = op.process(batch)
    results = out.get("window_results", [])
    for i, r in enumerate(results):
        assert r["window"] == (i * window, (i + 1) * window)
    # windows closed = floor of the max index over the window size
    assert len(results) == max(0, (n - 1)) // window


@given(st.sampled_from(["batch", "vocab", "heads", "mlp", "experts"]),
       st.booleans())
@settings(**SETTINGS)
def test_logical_rules_never_reference_missing_axes(axis, multipod):
    """PartitionSpecs only name axes that exist in the mesh."""
    import jax as _jax
    from jax.sharding import Mesh

    devs = np.asarray(_jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    spec = logical_to_mesh((axis,), mesh)
    for entry in spec:
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        assert all(nm in mesh.axis_names for nm in names)


# ---------------------------------------------------------------------------
# sharing-tree planner properties (the scheduler subsystem)
# ---------------------------------------------------------------------------

ALL_QIDS = None  # populated lazily: repro.queries pulls in the model stack


def _catalog():
    global ALL_QIDS
    if ALL_QIDS is None:
        from repro.queries import QUERIES
        ALL_QIDS = sorted(QUERIES)
    return ALL_QIDS


@given(data=st.data())
@settings(**SETTINGS)
def test_sharing_tree_partitions_exactly_once(data):
    """Model-free planner invariant: every submitted query lands in exactly
    one sharing group, groups never mix streams, and a shared group's
    estimated saving is positive."""
    from repro.queries import QUERIES, get_query
    from repro.scheduler import SharingTreePlanner

    qids = data.draw(st.lists(st.sampled_from(_catalog()), min_size=1,
                              max_size=8, unique=True))
    forest = SharingTreePlanner().plan(
        [get_query(q).naive_plan() for q in qids])
    placed = sorted(q for g in forest.groups() for q in g.execution.queries)
    assert placed == sorted(qids)
    for stream, groups in forest.streams.items():
        for g in groups:
            assert g.execution.prefix[0].stream_name == stream
            assert {QUERIES[q].dataset
                    for q in g.execution.queries} == {stream}
            if g.is_shared:
                assert g.saving_us > 0


# ---------------------------------------------------------------------------
# cost-catalog properties (the calibration subsystem)
# ---------------------------------------------------------------------------

_KEY = st.text(st.characters(whitelist_categories=("L", "N"),
                             whitelist_characters="[]@x_"),
               min_size=1, max_size=24)


@given(entries=st.dictionaries(
    _KEY,
    st.tuples(st.floats(0, 1e7, allow_nan=False),
              st.floats(0, 1, allow_nan=False),
              st.floats(0, 1e7, allow_nan=False),
              st.integers(1, 100), st.booleans()),
    min_size=0, max_size=12))
@settings(**SETTINGS)
def test_cost_catalog_roundtrips_exactly(entries, tmp_path_factory):
    """save() -> load() reproduces every entry bit for bit."""
    from repro.core.costs import CostCatalog, CostEntry

    cat = CostCatalog()
    for k, (us, pr, over, n, direct) in entries.items():
        cat.entries[k] = CostEntry(us=us, pass_rate=pr, overhead_us=over,
                                   n=n, direct=direct)
    path = str(tmp_path_factory.mktemp("cat") / "catalog.json")
    cat.save(path)
    back = CostCatalog.load(path)
    assert back.to_dict() == cat.to_dict()
    assert set(back.entries) == set(cat.entries)
    for k in cat.entries:
        assert back.entries[k] == cat.entries[k]


@given(st.lists(st.tuples(st.floats(0, 1e6, allow_nan=False),
                          st.booleans()), min_size=1, max_size=16))
@settings(**SETTINGS)
def test_cost_catalog_direct_outranks_run_estimates(samples):
    """Once a direct measurement lands, run-derived estimates never change
    the entry; direct samples always stay within the direct sample range."""
    from repro.core.costs import CostCatalog

    cat = CostCatalog()
    for us, direct in samples:
        cat.record("k", us, direct=direct)
    direct_vals = [us for us, d in samples if d]
    if direct_vals:
        assert cat.entries["k"].direct
        assert min(direct_vals) <= cat.lookup("k") <= max(direct_vals)
    else:
        run_vals = [us for us, _ in samples]
        assert min(run_vals) <= cat.lookup("k") <= max(run_vals)


@pytest.mark.slow
@given(data=st.data())
@settings(max_examples=3, deadline=None)
def test_fleet_execution_equals_solo_per_query(stream_ctx, data):
    """For random catalog subsets, fleet-optimized execution through the
    multi-stream runtime is bitwise identical, per query, to running each
    query's own fleet plan alone (the semantic phase alone exercises the
    canonicalization path at property-test cost)."""
    from repro.core.fleet import FleetOptimizer, FleetQuery
    from repro.data import TollBoothStream, VolleyballStream
    from repro.queries import QUERIES, get_query
    from repro.scheduler import MultiStreamRuntime
    from repro.streaming.runtime import StreamRuntime

    qids = data.draw(st.lists(st.sampled_from(_catalog()), min_size=2,
                              max_size=4, unique=True))
    seed = data.draw(st.integers(0, 2**16 - 1))

    def factory(ds):
        return (lambda s: TollBoothStream(seed=s)) if ds == "tollbooth" \
            else (lambda s: VolleyballStream(seed=s))

    workload = [FleetQuery(get_query(q), factory(QUERIES[q].dataset))
                for q in qids]
    fo = FleetOptimizer(stream_ctx, val_frames=32)
    res = fo.optimize(workload, phases=("semantic",))
    assert sorted(res.plans) == sorted(qids)

    streams = {feed: factory(feed)(seed) for feed in res.plans_by_feed}
    ms = MultiStreamRuntime.from_fleet(res, streams, stream_ctx,
                                       micro_batch=16)
    out = ms.run(32)
    for feed, plans in res.plans_by_feed.items():
        for p in plans:
            ind = StreamRuntime(p.clone(), stream_ctx, micro_batch=16).run(
                factory(feed)(seed), 32)
            sq = out.feeds[feed].per_query[p.query]
            assert sq.outputs == ind.outputs
            assert sq.window_results == ind.window_results


@pytest.mark.slow
@given(data=st.data())
@settings(max_examples=5, deadline=None)
def test_pipelined_serving_equals_synchronous_drain(stream_ctx, data):
    """Async dispatch-ahead serving is bitwise identical to the
    synchronous lock-step drain for random catalog workloads, per-feed
    frame budgets (randomized feed interleavings), backpressure settings
    and max_inflight ∈ {1, 2, 4}."""
    from repro.data import TollBoothStream, VolleyballStream
    from repro.queries import QUERIES, get_query
    from repro.scheduler import Feed, MultiStreamRuntime, SharedExtractServer

    qids = data.draw(st.lists(st.sampled_from(_catalog()), min_size=1,
                              max_size=4, unique=True))
    seed = data.draw(st.integers(0, 2**16 - 1))
    max_inflight = data.draw(st.sampled_from([1, 2, 4]))
    max_pending = data.draw(st.sampled_from([1, 2, 3]))
    datasets = sorted({QUERIES[q].dataset for q in qids})
    frames = {ds: data.draw(st.sampled_from([16, 24, 40]), label=ds)
              for ds in datasets}

    def feeds():
        return [Feed(ds,
                     TollBoothStream(seed=seed) if ds == "tollbooth"
                     else VolleyballStream(seed=seed),
                     [get_query(q).naive_plan() for q in qids
                      if QUERIES[q].dataset == ds])
                for ds in datasets]

    sync = MultiStreamRuntime(feeds(), stream_ctx, micro_batch=16,
                              pipelined=False,
                              max_pending=max_pending).run(frames)
    server = SharedExtractServer(stream_ctx, max_inflight=max_inflight)
    pipe = MultiStreamRuntime(feeds(), stream_ctx, micro_batch=16,
                              server=server,
                              max_pending=max_pending).run(frames)
    for ds in datasets:
        for qid, pq in pipe.feeds[ds].per_query.items():
            sq = sync.feeds[ds].per_query[qid]
            assert pq.outputs == sq.outputs
            assert pq.window_results == sq.window_results
    assert pipe.mllm_frames == sync.mllm_frames


# ---------------------------------------------------------------------------
# semantic gating tier properties
# ---------------------------------------------------------------------------

@given(data=st.data())
@settings(**SETTINGS)
def test_gate_revalidation_always_fires_within_budget(data):
    """Model-free gate invariant over random frame sequences (scenes with
    random revisits): no keyframe ever serves ``revalidate_every``
    consecutive answers without a model check, every admitted frame is
    classified exactly once, and a keyframe with enough lifetime hits has
    revalidated at least once."""
    from repro.semantic import GateConfig, SemanticGate

    every = data.draw(st.integers(2, 6), label="revalidate_every")
    gate = SemanticGate(GateConfig(threshold=0.05,
                                   revalidate_every=every))
    scenes = [-1.5, -0.5, 0.5, 1.5]
    n_frames = 0
    for _ in range(data.draw(st.integers(1, 6), label="batches")):
        vals = data.draw(st.lists(st.sampled_from(scenes), min_size=1,
                                  max_size=8), label="frames")
        frames = np.stack([np.full((3, 16, 16), v, np.float32)
                           for v in vals])
        n_frames += len(vals)
        adm = gate.admit("f", "big", frames)
        adm.bind({"present": np.zeros(adm.n_model, np.int32)}
                 if adm.n_model else None)
        adm.assemble()
        for entries in gate.cache._feeds.values():
            for e in entries.values():
                assert e.since_reval < every
                if e.hits >= every:
                    assert e.validations >= 1
    c = gate.counters
    assert c["cache_hits"] + c["cache_misses"] + c["revalidations"] \
        == n_frames
    assert c["cache_mismatches"] == 0          # fake model never drifts


@pytest.mark.slow
@given(data=st.data())
@settings(max_examples=3, deadline=None)
def test_gate_threshold_zero_is_bitwise_identity(stream_ctx, data):
    """A semantic gate with threshold=0 (gating off) leaves the serving
    tier bitwise identical to the pre-gate behavior for random catalog
    workloads — the no-regression contract of the semantic tier."""
    from repro.data import TollBoothStream, VolleyballStream
    from repro.queries import QUERIES, get_query
    from repro.scheduler import Feed, MultiStreamRuntime, SharedExtractServer
    from repro.semantic import GateConfig, SemanticGate

    qids = data.draw(st.lists(st.sampled_from(_catalog()), min_size=1,
                              max_size=4, unique=True))
    seed = data.draw(st.integers(0, 2**16 - 1))
    datasets = sorted({QUERIES[q].dataset for q in qids})

    def feeds():
        return [Feed(ds,
                     TollBoothStream(seed=seed) if ds == "tollbooth"
                     else VolleyballStream(seed=seed),
                     [get_query(q).naive_plan() for q in qids
                      if QUERIES[q].dataset == ds])
                for ds in datasets]

    base = MultiStreamRuntime(feeds(), stream_ctx, micro_batch=16).run(32)
    gate = SemanticGate(GateConfig(threshold=0.0))
    off = MultiStreamRuntime(
        feeds(), stream_ctx, micro_batch=16,
        server=SharedExtractServer(stream_ctx, gate=gate)).run(32)
    for ds in datasets:
        for qid, bq in base.feeds[ds].per_query.items():
            oq = off.feeds[ds].per_query[qid]
            assert oq.outputs == bq.outputs
            assert oq.window_results == bq.window_results
    assert off.server_stats["cache_hits"] == 0
    assert off.server_stats["forwards"] == base.server_stats["forwards"]


@pytest.mark.slow
@given(data=st.data())
@settings(max_examples=5, deadline=None)
def test_sharing_tree_execution_equals_independent(stream_ctx, data):
    """Random catalog subsets — including mixed tollbooth+volleyball
    subsets whose global common prefix is empty — execute through the
    sharing tree bitwise-identically to N independent runs."""
    from test_scheduler import assert_sharing_tree_equals_independent

    qids = data.draw(st.lists(st.sampled_from(_catalog()), min_size=1,
                              max_size=4, unique=True))
    seed = data.draw(st.integers(0, 2**16 - 1))
    assert_sharing_tree_equals_independent(stream_ctx, qids, seed)


# ---------------------------------------------------------------------------
# observability: histogram merge, SLO combine, snapshot round-trips
# ---------------------------------------------------------------------------

_values = st.floats(min_value=1e-4, max_value=1e9, allow_nan=False,
                    allow_infinity=False)
_records = st.lists(st.tuples(_values, st.integers(1, 50)), max_size=60)


@given(a=_records, b=_records)
@settings(**SETTINGS)
def test_histogram_merge_equals_interleaved_recording(a, b):
    """Bin-exact merge: folding two histograms equals recording the
    interleaved value stream into one — counts array, totals and
    min/max all identical, so merged percentiles are exact, not an
    approximation of the per-feed ones."""
    from repro.obs import Histogram
    ha, hb, ref = Histogram(), Histogram(), Histogram()
    for v, n in a:
        ha.record(v, n)
        ref.record(v, n)
    for v, n in b:
        hb.record(v, n)
        ref.record(v, n)
    ha.merge(hb)
    assert np.array_equal(ha.counts, ref.counts)
    assert ha.count == ref.count
    assert ha.total == pytest.approx(ref.total, rel=1e-9, abs=1e-12)
    if ref.count:
        assert ha.vmin == ref.vmin and ha.vmax == ref.vmax
        for p in (50, 95, 99):
            assert ha.percentile(p) == ref.percentile(p)


@given(data=st.data())
@settings(**SETTINGS)
def test_slo_combined_equals_single_feed_recording(data):
    """Workload-wide percentiles from ``combined()`` equal recording
    every frame into one feed: the merge loses nothing."""
    from repro.obs import Metrics, SLOTracker
    lat = st.floats(min_value=0.01, max_value=1e5, allow_nan=False,
                    allow_infinity=False)
    feeds = data.draw(st.lists(st.sampled_from("abcd"), min_size=1,
                               max_size=20))
    latencies = data.draw(st.lists(lat, min_size=len(feeds),
                                   max_size=len(feeds)))
    split = SLOTracker(Metrics(), target_ms=100.0)
    one = SLOTracker(Metrics(), target_ms=100.0)
    for feed, l in zip(feeds, latencies):
        split.record(feed, l)
        one.record("all", l)
    c = split.combined()
    r = one.row("all")
    assert c["frames"] == r["frames"]
    assert c["violations"] == r["violations"]
    for p in (50, 95, 99):
        assert c[f"p{p}_ms"] == r[f"p{p}_ms"]


@given(data=st.data())
@settings(**SETTINGS)
def test_metrics_snapshot_restore_roundtrip_random_sequences(data):
    """Snapshot → more traffic → restore returns every surface to its
    recorded state, under arbitrary record sequences (the aligned-
    checkpoint contract ``Metrics.restore`` promises)."""
    from repro.obs import Metrics
    names = st.sampled_from(["a", "b", "c/d"])
    ops = st.lists(st.tuples(st.sampled_from(["inc", "gauge", "observe"]),
                             names, _values), max_size=40)

    def apply(m, seq):
        for kind, name, v in seq:
            if kind == "inc":
                m.inc(name, int(v) % 100)
            elif kind == "gauge":
                m.set_gauge(name, v)
            else:
                m.observe(name, v)

    m = Metrics()
    apply(m, data.draw(ops))
    snap = m.snapshot()
    rows_before = m.to_rows()
    apply(m, data.draw(ops))
    m.restore(snap)
    assert m.to_rows() == rows_before
    # and restoring twice is idempotent
    m.restore(snap)
    assert m.to_rows() == rows_before
