"""Observability layer tests.

Three levels: (a) model-free — ring-buffer tracer semantics (capacity,
wraparound, Chrome export format), log-binned histogram percentiles
against a numpy reference, registry snapshot/restore and prefix drop,
SLO accounting; (b) the no-overhead contract — the disabled path costs
only no-op method calls, bounded analytically at well under 1% of any
plausible serving wall; (c) with models — the 4-feed / 9-query gated +
pipelined serving workload produces bitwise-identical per-query outputs
with observability enabled vs the ``NULL_OBS`` default, and the server's
``queue_depth`` / ``inflight`` stats entries stay truthful gauges across
``reset_stats()``.
"""
import dataclasses
import json
import time

import numpy as np
import pytest

from repro.obs import (
    NULL_OBS,
    NULL_TRACER,
    Histogram,
    Metrics,
    Observability,
    PHASES,
    SLOTracker,
    Tracer,
    resolve_obs,
)


@pytest.fixture(scope="module")
def ctx(stream_ctx):
    return stream_ctx


# ---------------------------------------------------------------------------
# (a) tracer: ring buffer, wraparound, export
# ---------------------------------------------------------------------------

def test_tracer_records_spans_instants_counters():
    tr = Tracer(capacity=16)
    t0 = tr.now()
    tr.span("prefix:skip", "prefix", t0, t0 + 1000, track="feed:a", n=16)
    tr.instant("gate:hit", "gate", track="feed:a", n=3)
    tr.counter("inflight", 2)
    evs = tr.events()
    assert [e["kind"] for e in evs] == ["X", "i", "C"]
    assert evs[0]["name"] == "prefix:skip" and evs[0]["n"] == 16
    assert evs[0]["t1_ns"] - evs[0]["t0_ns"] == 1000
    assert evs[2]["n"] == 2 and evs[2]["track"] == "counters"
    assert tr.recorded == 3 and tr.dropped == 0
    tr.reset()
    assert tr.events() == [] and tr.recorded == 0


def test_tracer_ring_wraparound_keeps_newest():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.span(f"s{i}", "prefix", i, i + 1)
    assert tr.recorded == 20 and tr.dropped == 12
    evs = tr.events()
    assert len(evs) == 8
    # oldest surviving first, newest last — overwrite, never shift
    assert [e["name"] for e in evs] == [f"s{i}" for i in range(12, 20)]


def test_chrome_export_is_perfetto_loadable_json(tmp_path):
    tr = Tracer()
    t0 = tr.now()
    tr.span("forward[big]", "forward", t0, t0 + 5_000_000, track="device",
            n=32)
    tr.span("queue_wait", "queue", t0, t0 + 1_000_000, track="feed:a",
            n=16)
    tr.instant("gate:miss", "gate", track="feed:a", n=1)
    tr.counter("inflight", 1)
    path = tmp_path / "trace.json"
    assert tr.export_chrome(str(path)) == 4
    data = json.loads(path.read_text())
    evs = data["traceEvents"]
    # thread-name metadata for every track + the process name
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert {"device", "feed:a", "counters", "repro-serving"} <= names
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(spans) == 2
    fwd = next(e for e in spans if e["name"] == "forward[big]")
    assert fwd["dur"] == pytest.approx(5000.0)      # µs
    assert fwd["args"]["n"] == 32
    assert all("ts" in e and "pid" in e and "tid" in e
               for e in evs if e["ph"] != "M")
    assert data["otherData"]["dropped_events"] == 0


# ---------------------------------------------------------------------------
# (a) metrics: histogram percentiles vs numpy, snapshot/restore, drop
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy_within_bin_width():
    rng = np.random.default_rng(7)
    # lognormal spans ~3 decades — the shape latencies actually have
    vals = rng.lognormal(mean=2.0, sigma=1.0, size=20_000)
    h = Histogram()
    for v in vals:
        h.record(float(v))
    rel = h.growth - 1.0                 # one bin's relative width
    for p in (50, 90, 95, 99):
        ref = np.percentile(vals, p)
        assert h.percentile(p) == pytest.approx(ref, rel=3 * rel + 1e-3)
    assert h.mean() == pytest.approx(vals.mean(), rel=1e-6)
    assert h.percentile(0) >= h.vmin and h.percentile(100) <= h.vmax


def test_histogram_weighted_and_clamped():
    h = Histogram()
    h.record(10.0, n=99)
    h.record(1e9, n=1)                   # beyond the binned range: clamps
    assert h.count == 100
    assert h.percentile(50) == pytest.approx(10.0, rel=0.05)
    assert h.percentile(99.9) <= h.vmax
    h2 = Histogram()
    h2.record(1e-9)                      # below lo: bin 0, clamped to vmin
    assert h2.percentile(50) == pytest.approx(1e-9)


def test_metrics_snapshot_restore_drops_later_metrics():
    m = Metrics()
    m.inc("requests", 5)
    m.set_gauge("wall_s", 1.5)
    m.observe("lat_ms/a", 3.0, 4)
    snap = m.snapshot()
    m.inc("requests", 100)
    m.observe("lat_ms/a", 50.0)
    m.inc("created_later")
    m.restore(snap)
    assert m.counter("requests").value == 5
    assert m.gauge("wall_s").value == 1.5
    assert m.histogram("lat_ms/a").count == 4
    assert "created_later" not in m._counters
    rows = {r["name"]: r for r in m.to_rows()}
    assert rows["lat_ms/a"]["p50"] == pytest.approx(3.0, rel=0.05)


def test_metrics_drop_prefix():
    m = Metrics()
    m.observe("queue_wait_ms/a", 1.0)
    m.observe("queue_wait_ms/b", 2.0)
    m.observe("forward_ms", 3.0)
    m.inc("forwards")
    m.drop("queue_wait_ms")
    m.drop("forward_ms")
    names = {r["name"] for r in m.to_rows()}
    assert names == {"forwards"}         # exact name + prefix/ both drop


def test_slo_tracker_rows_and_combined():
    m = Metrics()
    slo = SLOTracker(m, target_ms=100.0)
    slo.set_target("b", 10.0)
    for _ in range(90):
        slo.record("a", 50.0)
    for _ in range(10):
        slo.record("a", 400.0, staleness_ms=500.0)
    slo.record("b", 20.0, n=10)          # over b's tighter target
    ra = slo.row("a")
    assert ra["frames"] == 100 and ra["violations"] == 10
    assert ra["attainment"] == pytest.approx(0.9)
    assert ra["p50_ms"] == pytest.approx(50.0, rel=0.05)
    assert ra["p99_ms"] == pytest.approx(400.0, rel=0.05)
    rb = slo.row("b")
    assert rb["violations"] == 10 and rb["attainment"] == 0.0
    c = slo.combined()
    assert c["frames"] == 110 and c["violations"] == 20
    assert "ALL" in slo.table() and "a" in slo.table()


def test_observability_resolution_and_null():
    assert resolve_obs(None, None) is NULL_OBS
    o = Observability(tracer=NULL_TRACER)
    assert resolve_obs(None, o) is o
    assert NULL_OBS.now() == 0 and not NULL_OBS.enabled
    assert o.now() > 0                   # metrics-only mode keeps a clock
    assert o.tracer.events() == []


# ---------------------------------------------------------------------------
# (b) the no-overhead contract
# ---------------------------------------------------------------------------

def test_disabled_path_overhead_bounded_under_one_percent():
    # the disabled serving path executes only `obs.enabled` checks,
    # NULL_OBS.now() and NullTracer no-op calls; measure their cost and
    # bound the total against a deliberately pessimistic serving profile
    reps = 100_000
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        NULL_OBS.now()
        NULL_TRACER.span("x", "prefix", 0, 0)
    per_site_ns = (time.perf_counter_ns() - t0) / reps
    assert per_site_ns < 10_000          # ~100ns each in practice
    # pessimistic profile: 40 instrumented sites per frame, serving at
    # 200 frames/s (5ms/frame — far faster than this stack goes on CPU)
    overhead = (40 * per_site_ns) / 5e6
    assert overhead < 0.01


# ---------------------------------------------------------------------------
# (c) with models: bitwise identity + server gauges
# ---------------------------------------------------------------------------

#: the benchmark workload in miniature: 4 feeds, 9 queries
_FEEDS = (
    ("tb0", "tollbooth", 3, ("Q2", "Q6", "Q8")),
    ("tb1", "tollbooth", 11, ("Q1", "Q5")),
    ("tb2", "tollbooth", 7, ("Q3", "Q9")),
    ("vb0", "volleyball", 3, ("Q12", "Q13")),
)


def _run_ms(ctx, obs=None, frames=32):
    from repro.data import TollBoothStream, VolleyballStream
    from repro.queries import get_query
    from repro.scheduler import Feed, MultiStreamRuntime
    from repro.semantic import GateConfig, SemanticGate

    if obs is not None:
        ctx = dataclasses.replace(ctx, obs=obs)
    feeds = []
    for name, ds, seed, qids in _FEEDS:
        stream = TollBoothStream(seed=seed) if ds == "tollbooth" \
            else VolleyballStream(seed=seed)
        feeds.append(Feed(name, stream,
                          [get_query(q).naive_plan() for q in qids]))
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16,
                            gate=SemanticGate(GateConfig(threshold=0.06)))
    return ms.run(frames)


def test_observed_serving_bitwise_identical_and_traces_lifecycle(ctx):
    base = _run_ms(ctx)                  # NULL_OBS default
    obs = Observability(slo_target_ms=10_000.0)
    traced = _run_ms(ctx, obs=obs)
    for name, _, _, qids in _FEEDS:
        for q in qids:
            assert traced.feeds[name].per_query[q].outputs == \
                base.feeds[name].per_query[q].outputs
            assert traced.feeds[name].per_query[q].window_results == \
                base.feeds[name].per_query[q].window_results
    # the trace carries the lifecycle: >= 6 distinct span phases
    cats = {e["cat"] for e in obs.tracer.events()}
    assert len(cats & set(PHASES)) >= 6, sorted(cats)
    assert {"ingest", "prefix", "gate", "queue", "forward",
            "resume"} <= cats
    # SLO accounting saw every feed and every ingested frame
    assert sorted(obs.slo.feeds()) == sorted(f[0] for f in _FEEDS)
    for name, _, _, _ in _FEEDS:
        r = obs.slo.row(name)
        assert r["frames"] == 32 and r["p50_ms"] > 0
        assert r["stale_p50_ms"] >= 0
    # unified surfaces: server stats landed in the registry
    assert obs.metrics.counter("server/forwards").value == \
        traced.server_stats["forwards"]
    assert obs.metrics.gauge("run/wall_s").value > 0


def test_metrics_only_mode_records_without_tracing(ctx):
    obs = Observability(tracer=NULL_TRACER, slo_target_ms=10_000.0)
    _run_ms(ctx, obs=obs)
    assert obs.tracer.events() == []     # no spans recorded...
    assert obs.slo.combined()["frames"] == 32 * len(_FEEDS)   # ...but SLO is
    assert obs.metrics.histogram("forward_ms").count > 0


def test_server_stats_gauges_truthful_across_reset(ctx):
    # satellite fix: queue_depth / inflight are recomputed-on-read gauges,
    # not frozen counters — reset_stats() must not leave stale values
    from repro.data import TollBoothStream
    from repro.scheduler import SharedExtractServer

    srv = SharedExtractServer(ctx, max_batch=4, max_inflight=2)
    frames = TollBoothStream(seed=3).batch(4)[0].astype(np.float32)
    for _ in range(3):
        srv.submit("big", frames, feed="a")
    assert srv.stats["queue_depth"] == 3 and srv.stats["inflight"] == 0
    srv.dispatch()
    assert srv.stats["queue_depth"] == 1 and srv.stats["inflight"] == 2
    srv.reset_stats()
    # the gauges still reflect live state, not the fresh-stats zeros
    assert srv.stats["queue_depth"] == 1 and srv.stats["inflight"] == 2
    srv.drain()
    assert srv.stats["queue_depth"] == 0 and srv.stats["inflight"] == 0


def test_warmup_histograms_dropped_on_reset(ctx):
    from repro.data import TollBoothStream
    from repro.scheduler import SharedExtractServer

    obs = Observability(tracer=NULL_TRACER)
    srv = SharedExtractServer(ctx, obs=obs)
    frames = TollBoothStream(seed=3).batch(4)[0].astype(np.float32)
    srv.submit("big", frames, feed="a")
    srv.drain()
    assert obs.metrics.histogram("forward_ms").count == 1
    srv.reset_stats()                    # e.g. after warmup
    assert obs.metrics.histogram("forward_ms").count == 0
    assert obs.metrics.histogram("queue_wait_ms/a").count == 0
