"""Serving engine benchmark: continuous-batching throughput vs sequential."""
from __future__ import annotations

import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import LM, materialize
from repro.serving import Request, ServingEngine


def run_all() -> Iterator[str]:
    """Yield rows as they complete (partial-output-on-failure contract
    of the benchmark driver)."""
    cfg = smoke_config("chatglm3-6b")
    lm = LM(cfg, tp=1)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    rs = np.random.RandomState(0)

    def mk_reqs(n):
        return [Request(uid=i,
                        prompt=list(rs.randint(2, cfg.vocab_size, 12)),
                        max_new_tokens=8) for i in range(n)]

    # sequential: one slot
    eng1 = ServingEngine(cfg, params, max_slots=1, s_max=64, eos_id=-1)
    reqs = mk_reqs(6)
    eng1.run(reqs[:1])  # warmup/compile
    t0 = time.perf_counter()
    done = eng1.run(mk_reqs(6))
    seq_s = time.perf_counter() - t0
    tok = sum(len(r.output) for r in done)
    yield f"serve_sequential_6req,{seq_s*1e6/tok:.0f},{tok/seq_s:.1f}tok/s"

    # continuous batching: 4 slots
    eng4 = ServingEngine(cfg, params, max_slots=4, s_max=64, eos_id=-1)
    eng4.run(mk_reqs(1))
    t0 = time.perf_counter()
    done = eng4.run(mk_reqs(6))
    cb_s = time.perf_counter() - t0
    tok = sum(len(r.output) for r in done)
    yield (f"serve_continuous_6req,{cb_s*1e6/tok:.0f},{tok/cb_s:.1f}tok/s"
           f";speedup={seq_s/cb_s:.2f}x")
