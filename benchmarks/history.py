"""Benchmark history & comparison: the perf-trajectory substrate.

``benchmarks/run.py --json DIR`` writes one ``BENCH_<section>.json`` per
section, each row stamped with host provenance (cpu count, platform,
python, jax backend/devices/version).  This module turns those
per-run snapshots into a trajectory:

  * ``append_history`` folds a run's rows into a JSONL history file
    (one line per run, keyed by host provenance), so successive runs on
    the same machine accumulate instead of overwriting;
  * ``compare`` diffs two row sets with a *noise-aware* policy — rows
    sharing a name are collapsed to their best value (min for
    lower-is-better metrics, max for higher-is-better: the min-of-trials
    convention every serious benchmark harness uses, because scheduling
    noise only ever makes numbers worse) before ratios are taken;
  * ``direction`` is the metric-name heuristic deciding which way
    "better" points; names it cannot classify are skipped rather than
    guessed (a gate that misreads a counter as a latency would cry wolf
    forever).

``scripts/bench_gate.py`` is the CLI consumer: it compares the current
``reports/benchmarks`` rows against the committed
``reports/benchmarks/baseline`` snapshot per host key and exits nonzero
on regression.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

#: substrings marking a higher-is-better metric (checked first)
_HIGHER = ("fps", "speedup", "throughput", "hit_rate", "attainment",
           "availability")
#: suffix / substring cues for lower-is-better (latencies, walls, model
#: load); counts of forwards are model load — fewer forwards per frame
#: is the paper's headline win
_LOWER_SUFFIX = ("_ms", "_us", "_s", "_ns")
_LOWER = ("latency", "serving", "forwards", "wall", "us_per_call",
          "mllm_frames", "stale")


def direction(name: str) -> Optional[int]:
    """+1 higher-is-better, -1 lower-is-better, None unknown (skip)."""
    low = name.lower()
    if any(h in low for h in _HIGHER):
        return +1
    if low.endswith(_LOWER_SUFFIX) or any(l in low for l in _LOWER):
        return -1
    return None


def host_key(row: Dict[str, Any]) -> str:
    """Provenance key: perf numbers only compare within one of these."""
    return "|".join(str(row.get(k, "?")) for k in (
        "host_platform", "host_cpus", "host_python", "jax_backend",
        "jax_version"))


def load_bench_dir(path: str) -> List[Dict[str, Any]]:
    """All rows from every ``BENCH_*.json`` under ``path`` (sections
    that failed contribute nothing — an ERROR row has no numeric
    metric and would be skipped anyway, but ``ok: false`` sections are
    dropped outright so a crashed section can't half-compare)."""
    rows: List[Dict[str, Any]] = []
    for fp in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        with open(fp) as f:
            data = json.load(f)
        if not data.get("ok", True):
            continue
        for r in data.get("rows", []):
            r = dict(r)
            r["section"] = data.get("section", "")
            rows.append(r)
    return rows


def append_history(bench_dir: str, history_path: str) -> int:
    """Append one JSONL record (this run's rows, grouped under their
    host key) to the history file; returns the number of rows kept."""
    rows = [r for r in load_bench_dir(bench_dir)
            if isinstance(r.get("metric"), (int, float))]
    if not rows:
        return 0
    rec = {
        "written_at": time.time(),
        "host_key": host_key(rows[0]),
        "rows": [{"section": r["section"], "name": r["name"],
                  "metric": r["metric"]} for r in rows],
    }
    d = os.path.dirname(history_path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(history_path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return len(rows)


def best_by_name(rows: List[Dict[str, Any]]
                 ) -> Dict[str, Tuple[float, int]]:
    """Collapse trials: name → (best metric, direction).  Non-numeric
    metrics and direction-less names drop here."""
    best: Dict[str, Tuple[float, int]] = {}
    for r in rows:
        m = r.get("metric")
        if not isinstance(m, (int, float)):
            continue
        d = direction(r["name"])
        if d is None:
            continue
        prev = best.get(r["name"])
        if prev is None or (d > 0 and m > prev[0]) \
                or (d < 0 and m < prev[0]):
            best[r["name"]] = (float(m), d)
    return best


def compare(baseline: List[Dict[str, Any]], current: List[Dict[str, Any]],
            tolerance: float = 0.5) -> List[Dict[str, Any]]:
    """Per-metric deltas between two row sets (already host-matched).

    Each delta row: name, baseline, current, ratio (current/baseline,
    oriented so >1 means *worse*), regressed (ratio beyond
    ``1 + tolerance``).  Metrics present on only one side are skipped —
    a new benchmark must not fail the gate on its first run."""
    b_best = best_by_name(baseline)
    c_best = best_by_name(current)
    out: List[Dict[str, Any]] = []
    for name in sorted(set(b_best) & set(c_best)):
        b, d = b_best[name]
        c, _ = c_best[name]
        if b <= 0 or c <= 0:
            continue                      # ratios need positive metrics
        worse = c / b if d < 0 else b / c
        out.append({
            "name": name, "baseline": b, "current": c,
            "direction": "higher" if d > 0 else "lower",
            "ratio": worse,
            "regressed": worse > 1.0 + tolerance,
        })
    return out
