"""Benchmark driver: one section per paper table/figure + substrate benches.

Prints ``name,us_per_call_or_metric,derived`` CSV rows; with ``--json DIR``
each section additionally writes machine-readable rows to
``DIR/BENCH_<section>.json`` (name, metric, derived, timestamp, plus host
provenance: cpu count, platform, python and jax backend/devices) so the
perf trajectory across PRs can be diffed without scraping stdout — and
attributed to the machine that produced it.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-samsara]
                                          [--sections LIST]
                                          [--samsara-figs LIST]
                                          [--quick-models] [--json DIR]

The CI smoke tier tracks the serving-path perf trajectory per PR with
``--sections samsara --samsara-figs fig_ms,fig_pipeline --quick-models
--json reports/benchmarks`` (tiny models, short streams, no result
cache) and uploads the ``BENCH_*.json`` files as workflow artifacts.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import platform
import sys
import time
import traceback
from typing import List


@functools.lru_cache(maxsize=1)
def _host_info() -> dict:
    """Host provenance stamped on every --json row: perf numbers are
    meaningless in a cross-PR diff without knowing what ran them."""
    info = {
        "host_cpus": os.cpu_count(),
        "host_platform": platform.platform(),
        "host_python": platform.python_version(),
    }
    try:
        import jax

        info["jax_backend"] = jax.default_backend()
        info["jax_devices"] = [str(d) for d in jax.devices()]
        info["jax_version"] = jax.__version__
    except Exception:  # noqa: BLE001 — no-jax hosts still get CPU info
        pass
    return info


def _structured(row: str) -> dict:
    """Split a CSV row into JSON fields with a *numeric* metric.

    Kernel/serving rows are ``name,value,derived``; samsara rows are
    ``section,label,value,derived`` — for those the label folds into the
    name (``fig_ms.forwards``) so ``metric`` always carries the
    measurement.  The derived remainder keeps its commas."""
    parts = row.split(",")
    name = parts[0]
    metric = parts[1] if len(parts) > 1 else ""
    rest = parts[2:]
    if len(parts) >= 3 and metric != "ERROR":
        try:
            float(metric)
        except ValueError:
            name = f"{parts[0]}.{parts[1]}"
            metric = parts[2]
            rest = parts[3:]
    try:
        metric = float(metric)
    except ValueError:
        pass                    # ERROR / non-numeric stays a string
    return {
        "name": name,
        "metric": metric,
        "derived": ",".join(rest),
        "timestamp": time.time(),
        **_host_info(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fig1b only for the Saṃsāra section")
    ap.add_argument("--skip-samsara", action="store_true")
    ap.add_argument("--sections", default=None,
                    help="comma list of top-level sections to run "
                         "(kernels,serving,samsara,fig_semantic,"
                         "fig_fused,fig_chaos — the last three are "
                         "figures promoted to their own sections, each "
                         "written to BENCH_<name>.json); default: all")
    ap.add_argument("--samsara-figs", default=None,
                    help="comma list of Saṃsāra figures (fig1b,fig5,"
                         "table2,fig_mq,fig_ms,fig_pipeline,fig_fleet,"
                         "fig_semantic,fig_fused); overrides --quick's "
                         "figure choice")
    ap.add_argument("--quick-models", action="store_true",
                    help="tiny smoke models + short serving streams for "
                         "the Saṃsāra section (disables its result cache "
                         "— smoke rows must never mix with full-model "
                         "ones); the CI smoke tier uses this")
    ap.add_argument("--json", metavar="DIR", default=None,
                    help="also write BENCH_<section>.json files to DIR")
    ap.add_argument("--write-baseline", action="store_true",
                    help="after a fully-successful run, copy this run's "
                         "BENCH_*.json into DIR/baseline/ — the anchor "
                         "scripts/bench_gate.py compares against "
                         "(requires --json)")
    args = ap.parse_args()
    assert not args.write_baseline or args.json, \
        "--write-baseline needs --json DIR"

    wanted = args.sections.split(",") if args.sections else None
    known = {"kernels", "serving", "samsara", "fig_semantic", "fig_fused",
             "fig_chaos"}
    assert wanted is None or set(wanted) <= known, \
        f"unknown sections {sorted(set(wanted) - known)} (known: {sorted(known)})"

    def want(name: str) -> bool:
        return wanted is None or name in wanted

    sections = []
    if want("kernels") or want("serving"):
        from benchmarks import kernel_bench, serving_bench

        if want("kernels"):
            sections.append(("kernels", kernel_bench.run_all))
        if want("serving"):
            sections.append(("serving", serving_bench.run_all))
    if not args.skip_samsara and want("samsara"):
        from benchmarks import samsara_bench

        figs = args.samsara_figs.split(",") if args.samsara_figs else None
        # a figure also requested as its own top-level section must not
        # run twice when the samsara default list would include it
        exclude = [s for s in ("fig_semantic", "fig_fused", "fig_chaos")
                   if wanted is not None and s in wanted] or None
        sections.append(("samsara",
                         lambda: samsara_bench.run_all(
                             quick=args.quick,
                             quick_models=args.quick_models,
                             sections=figs, exclude=exclude)))
    for own in ("fig_semantic", "fig_fused", "fig_chaos"):
        if want(own) and wanted is not None:
            # its own top-level section (not just a samsara figure) so
            # these rows land in a dedicated BENCH_<name>.json next to
            # the existing artifacts
            from benchmarks import samsara_bench

            sections.append((own,
                             lambda own=own: samsara_bench.run_all(
                                 quick=args.quick,
                                 quick_models=args.quick_models,
                                 sections=[own])))

    print("name,us_per_call,derived")
    failed: List[str] = []
    for name, fn in sections:
        rows: List[str] = []
        try:
            for row in fn():
                print(row, flush=True)
                rows.append(row)
        except Exception:  # noqa: BLE001
            failed.append(name)
            err = f"{name},ERROR,{traceback.format_exc()[-300:]!r}"
            print(err)
            rows.append(err)       # the JSON must carry the reason too
        if args.json:
            os.makedirs(args.json, exist_ok=True)
            path = os.path.join(args.json, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({"section": name,
                           "ok": name not in failed,
                           "rows": [_structured(r) for r in rows]},
                          f, indent=1)
    if args.json:
        # perf trajectory: every --json run appends its rows (host-keyed)
        # to the JSONL history riding next to the snapshots
        from benchmarks.history import append_history

        kept = append_history(args.json,
                              os.path.join(args.json, "history.jsonl"))
        print(f"history: {kept} rows appended to "
              f"{os.path.join(args.json, 'history.jsonl')}",
              file=sys.stderr)
    if args.write_baseline and not failed:
        import shutil

        bdir = os.path.join(args.json, "baseline")
        os.makedirs(bdir, exist_ok=True)
        for name, _ in sections:
            src = os.path.join(args.json, f"BENCH_{name}.json")
            if os.path.exists(src):
                shutil.copy2(src, bdir)
        print(f"baseline refreshed under {bdir}", file=sys.stderr)
    if failed:
        print(f"FAILED sections: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)
    sys.exit(0)


if __name__ == "__main__":
    main()
