"""Benchmark driver: one section per paper table/figure + substrate benches.

Prints ``name,us_per_call_or_metric,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-samsara]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fig1b only for the Saṃsāra section")
    ap.add_argument("--skip-samsara", action="store_true")
    args = ap.parse_args()

    rows = []
    sections = []
    from benchmarks import kernel_bench, serving_bench

    sections.append(("kernels", kernel_bench.run_all))
    sections.append(("serving", serving_bench.run_all))
    if not args.skip_samsara:
        from benchmarks import samsara_bench

        sections.append(("samsara",
                         lambda: samsara_bench.run_all(quick=args.quick)))

    print("name,us_per_call,derived")
    ok = True
    for name, fn in sections:
        try:
            for row in fn():
                print(row, flush=True)
                rows.append(row)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{name},ERROR,{traceback.format_exc()[-300:]!r}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
