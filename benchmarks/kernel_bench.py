"""Micro-benchmarks for the Pallas kernels' XLA-path wrappers on CPU.

On this container the kernels execute via their reference path (interpret
mode is Python-slow and only used for correctness); these timings track the
*wrapper overhead + XLA fallback* cost per call and the derived bandwidth,
and serve as the regression harness the TPU deployment reuses.
Output: ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn: Callable, *args, reps: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run_all() -> Iterator[str]:
    """Yield rows one at a time — the driver persists each section's
    partial output even when a later benchmark in the section raises."""
    # fused preprocess: the streaming hot path
    from repro.kernels.fused_preprocess.ops import fused_preprocess

    frames = jnp.asarray(
        np.random.randint(0, 255, (16, 3, 128, 256), np.uint8))
    us = _time(lambda f: fused_preprocess(f, crop=(64, 0, 64, 256), factor=2),
               frames)
    mb = 16 * 3 * 128 * 256 / 2**20
    yield f"fused_preprocess_16f,{us:.1f},{mb/(us/1e6)/1024:.2f}GiB/s"

    # frame diff (skip operator)
    from repro.kernels.frame_diff.ops import frame_diff

    prev = jnp.asarray(np.random.randint(0, 255, (16, 3, 128, 256), np.uint8))
    us = _time(lambda a, b: frame_diff(a, b, regions=(4, 8)), frames, prev)
    yield f"frame_diff_16f,{us:.1f},{2*mb/(us/1e6)/1024:.2f}GiB/s"

    # fused prefix: diff + color fraction + preprocess + gate signature in
    # one pass (the per-micro-batch chain FusedPrefixOp dispatches once)
    from repro.kernels.fused_prefix.kernel import out_frame_shape
    from repro.kernels.fused_prefix.ops import fused_prefix
    from repro.semantic.signature import signature_layout

    spec = (("diff", (4, 8)), ("color", (190.0, 40.0, 40.0), None),
            ("preprocess", (64, 0, 64, 256), 2, False))
    gy, gx, _, proj = signature_layout(out_frame_shape(spec, (3, 128, 256)))
    spec = spec + (("signature", (gy, gx)),)
    pj = jnp.asarray(proj)
    us = _time(lambda a, b: fused_prefix(a, b, pj, spec=spec), frames, prev)
    yield f"fused_prefix_16f,{us:.1f},{2*mb/(us/1e6)/1024:.2f}GiB/s"

    # flash attention fallback (prefill path)
    from repro.kernels.flash_attention.ops import flash_attention

    q = jnp.asarray(np.random.randn(1, 1024, 8, 64), jnp.float32)
    k = jnp.asarray(np.random.randn(1, 1024, 2, 64), jnp.float32)
    us = _time(lambda q, k: flash_attention(q, k, k, causal=True), q, k)
    fl = 2 * 2 * 1024 * 1024 * 8 * 64 / 2  # causal half
    yield f"flash_attention_1k,{us:.1f},{fl/(us/1e6)/1e9:.2f}GFLOP/s"

    # int8 matmul fallback
    from repro.kernels.int8_matmul.ref import quantize_colwise
    from repro.kernels.int8_matmul.ops import matmul_int8_dynamic

    x = jnp.asarray(np.random.randn(256, 512), jnp.float32)
    w = jnp.asarray(np.random.randn(512, 512), jnp.float32)
    wq, sw = quantize_colwise(w)
    us = _time(lambda x: matmul_int8_dynamic(x, wq, sw), x)
    fl = 2 * 256 * 512 * 512
    yield f"int8_matmul_256x512x512,{us:.1f},{fl/(us/1e6)/1e9:.2f}GOP/s"

    # SSD scan
    from repro.kernels.ssd_scan.ops import ssd

    B, L, H, P, G, N = 2, 512, 8, 32, 1, 32
    xs = jnp.asarray(np.random.randn(B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(np.random.randn(B, L, H), jnp.float32))
    a = -jnp.exp(jnp.asarray(np.random.randn(H) * 0.2, jnp.float32))
    bm = jnp.asarray(np.random.randn(B, L, G, N) * 0.3, jnp.float32)
    cm = jnp.asarray(np.random.randn(B, L, G, N) * 0.3, jnp.float32)
    d = jnp.ones((H,))
    us = _time(lambda x: ssd(x, dt, a, bm, cm, d, chunk=128), xs)
    yield f"ssd_scan_b2l512,{us:.1f},chunked"
