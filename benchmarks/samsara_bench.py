"""Saṃsāra benchmarks — one function per paper table/figure.

  fig1b_q8_naive_vs_optimized : the running example (Fig 1b): naive vs
                                fully-optimized FPS on the stolen-car query.
  fig5_end_to_end             : all 13 queries, naive vs optimized FPS +
                                query accuracy (Fig 5 + the ~7% accuracy
                                claim).
  table2_ablation             : min/avg/max speedup per optimization phase
                                (semantic / +logical / +physical), Table 2.
  fig_multiquery              : all 13 catalog queries served concurrently —
                                one shared-execution runtime per stream vs
                                N independent runtimes (the cross-query
                                model-load reduction; per-query accuracy is
                                exact-match vs independent execution).
  fig_multistream             : 4 concurrent feeds (3 tollbooth cameras +
                                1 volleyball court, 9 queries) through one
                                SharedExtractServer — cross-stream sharing:
                                strictly fewer MLLM forwards than the sum
                                of independent runs, outputs bitwise
                                identical, and the sharing-tree planner
                                factoring per-stream subsets although the
                                global common prefix is empty.
  fig_pipeline                : pipelined dispatch-ahead serving vs the
                                synchronous lock-step drain on the same
                                4-feed / 9-query workload — the host-side
                                stream work of round k (source batching,
                                Skip/window ops, tail fan-out) overlaps
                                round k−1's device forwards behind the
                                SharedExtractServer's dispatch/poll
                                protocol (max_inflight=2 double
                                buffering); per-query outputs stay
                                bitwise identical to independent
                                execution and ≥ 2 in-flight forwards are
                                observed.
  fig_fleet                   : jointly-optimized (FleetOptimizer) vs
                                per-query-optimized vs naive sharing on
                                the mixed tollbooth+volleyball multi-
                                stream workload — sharing survives joint
                                optimization (≥ as many queries in shared
                                groups as naive sharing), per-query
                                outputs bitwise identical to solo runs of
                                the same plans, and every planned op cost
                                calibrated (no static-default fallback);
                                emits the measured cost catalog as
                                structured rows.
  fig_semantic                : the semantic gating tier (temporal-
                                redundancy extract cache + accuracy-
                                budgeted admission) on the 4-feed /
                                9-query workload — gated vs ungated
                                serving: ≥ 2× fewer MLLM forwards, every
                                query's accuracy within the configured
                                budget of its ungated score, measured
                                hit/miss/revalidation/mismatch rates, and
                                bitwise-identical outputs when the gate is
                                disabled (threshold=0).
  fig_fused                   : fused prefix execution — the 4-op
                                surviving-frame prefix plus the gate
                                signature as ONE compiled device pass per
                                micro-batch vs the unfused op sequence:
                                ≥ 3× fewer prefix dispatches, prefix wall
                                no worse, bitwise-identical results,
                                end-to-end serving fps, and the physical
                                phase's calibrated fuse/refuse decision
                                in both stream-density regimes.

Wall-clock numbers are CPU-scale; the *relative* speedups are the paper's
claims being reproduced.  Results are written to reports/benchmarks/.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.superopt import SuperOptimizer
from repro.data import TollBoothStream, VolleyballStream
from repro.queries import QUERIES, get_query
from repro.streaming.multiquery import MultiQueryRuntime
from repro.streaming.pretrain import train_stream_models
from repro.streaming.runtime import StreamRuntime

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "benchmarks")

N_FRAMES = 512          # evaluation stream length per run
EVAL_SEED = 1234        # held-out stream seed (optimizer never sees it)


def _stream_factory(dataset: str):
    def make(seed: int):
        if dataset == "tollbooth":
            return TollBoothStream(seed=seed)
        return VolleyballStream(seed=seed)

    return make


def _run_plan(plan, ctx, dataset: str, n_frames: int = N_FRAMES,
              seed: int = EVAL_SEED):
    rt = StreamRuntime(plan, ctx, micro_batch=16)
    return rt.run(_stream_factory(dataset)(seed), n_frames)


def _measure(qid: str, ctx, phases: Tuple[str, ...], cache: Dict
             ) -> Dict[str, Any]:
    """Optimize with the given phases and measure FPS + accuracy."""
    q = get_query(qid)
    key = (qid, phases)
    if key in cache:
        return cache[key]
    if phases:
        opt = SuperOptimizer(ctx, val_frames=256)
        plan, report = opt.optimize(q, _stream_factory(q.dataset),
                                    phases=phases)
    else:
        plan, report = q.naive_plan(), None
    res = _run_plan(plan, ctx, q.dataset)
    acc = q.evaluate(res)
    out = {
        "qid": qid, "phases": list(phases), "fps": res.fps,
        "accuracy": acc, "mllm_frames": res.mllm_frames,
        "n_frames": res.n_frames, "plan": plan.describe(),
        "report": report.describe() if report else None,
    }
    cache[key] = out
    return out


# ---------------------------------------------------------------------------
# Figure 1b — the running example
# ---------------------------------------------------------------------------

def fig1b_q8_naive_vs_optimized(ctx, cache) -> List[str]:
    naive = _measure("Q8", ctx, (), cache)
    full = _measure("Q8", ctx, ("semantic", "logical", "physical"), cache)
    rows = [
        f"fig1b,naive_fps,{naive['fps']:.2f},acc={naive['accuracy']:.3f}"
        f";mllm_frames={naive['mllm_frames']}",
        f"fig1b,samsara_fps,{full['fps']:.2f},acc={full['accuracy']:.3f}"
        f";mllm_frames={full['mllm_frames']}",
        f"fig1b,speedup,{full['fps']/naive['fps']:.2f},paper_claims~9x",
    ]
    return rows


# ---------------------------------------------------------------------------
# Figure 5 — end-to-end gains, all 13 queries
# ---------------------------------------------------------------------------

def fig5_end_to_end(ctx, cache) -> List[str]:
    rows = []
    drops = []
    for qid in QUERIES:
        naive = _measure(qid, ctx, (), cache)
        full = _measure(qid, ctx, ("semantic", "logical", "physical"), cache)
        speedup = full["fps"] / max(naive["fps"], 1e-9)
        drop = naive["accuracy"] - full["accuracy"]
        drops.append(drop)
        rows.append(
            f"fig5,{qid},{speedup:.2f},naive_fps={naive['fps']:.2f};"
            f"opt_fps={full['fps']:.2f};acc_naive={naive['accuracy']:.3f};"
            f"acc_opt={full['accuracy']:.3f};"
            f"mllm_reduction={1 - full['mllm_frames']/max(naive['mllm_frames'],1):.2%}")
    rows.append(f"fig5,mean_accuracy_drop,{np.mean(drops):.4f},"
                "paper_claims~0.07")
    return rows


# ---------------------------------------------------------------------------
# Table 2 — ablation by phase
# ---------------------------------------------------------------------------

def table2_ablation(ctx, cache) -> List[str]:
    stages = {
        "semantic": ("semantic",),
        "+logical": ("semantic", "logical"),
        "+physical": ("semantic", "logical", "physical"),
    }
    speedups: Dict[str, List[float]] = {k: [] for k in stages}
    for qid in QUERIES:
        naive = _measure(qid, ctx, (), cache)
        for name, phases in stages.items():
            r = _measure(qid, ctx, phases, cache)
            speedups[name].append(r["fps"] / max(naive["fps"], 1e-9))
    rows = []
    for name in stages:
        s = np.asarray(speedups[name])
        rows.append(f"table2,{name},min={s.min():.2f};avg={s.mean():.2f};"
                    f"max={s.max():.2f},paper:semantic=1.9/4.8/8.0 "
                    "+logical=2.1/7.3/10.1 +physical=2.3/7.4/10.4")
    return rows


# ---------------------------------------------------------------------------
# Multi-query shared execution — all 13 queries concurrently
# ---------------------------------------------------------------------------

def fig_multiquery(ctx, cache) -> List[str]:
    """Serve every catalog query over its stream at once: one shared runtime
    per stream (common prefix + union-task MLLM factored by the planner) vs
    N independent StreamRuntimes, on the same held-out stream."""
    rows = []
    tot_q = 0
    tot_shared_wall = tot_indep_wall = 0.0
    tot_shared_mllm = tot_indep_mllm = 0
    for dataset in ("tollbooth", "volleyball"):
        qids = [qid for qid, q in QUERIES.items() if q.dataset == dataset]
        # the cached aggregate covers exactly this query set — key on it so
        # a catalog change remeasures instead of hitting a stale entry
        key = (f"MQ-{dataset}", ("multiquery",) + tuple(qids))
        if key in cache:
            out = cache[key]
        else:
            plans = [get_query(qid).naive_plan() for qid in qids]
            mq = MultiQueryRuntime(plans, ctx, micro_batch=16)
            shared = mq.run(_stream_factory(dataset)(EVAL_SEED), N_FRAMES)
            out = {
                "qids": qids, "wall_s": shared.wall_s, "fps": shared.fps,
                "mllm_frames": shared.mllm_frames,
                "accuracy": {qid: get_query(qid).evaluate(
                    shared.per_query[qid]) for qid in qids},
            }
            cache[key] = out
        indep = {qid: _measure(qid, ctx, (), cache) for qid in qids}
        indep_wall = sum(N_FRAMES / max(r["fps"], 1e-9)
                         for r in indep.values())
        indep_mllm = sum(r["mllm_frames"] for r in indep.values())
        indep_fps = len(qids) * N_FRAMES / max(indep_wall, 1e-9)
        acc_match = all(
            abs(out["accuracy"][qid] - indep[qid]["accuracy"]) < 1e-9
            for qid in qids)
        rows.append(
            f"fig_mq,{dataset},shared_fps={out['fps']:.2f},"
            f"indep_fps={indep_fps:.2f};n_queries={len(qids)};"
            f"mllm_shared={out['mllm_frames']};mllm_indep={indep_mllm};"
            f"acc_exact_match={acc_match}")
        tot_q += len(qids)
        tot_shared_wall += out["wall_s"]
        tot_indep_wall += indep_wall
        tot_shared_mllm += out["mllm_frames"]
        tot_indep_mllm += indep_mllm
    rows.append(
        f"fig_mq,total,fps_gain={tot_indep_wall/max(tot_shared_wall,1e-9):.2f},"
        f"queries={tot_q};mllm_reduction="
        f"{1 - tot_shared_mllm/max(tot_indep_mllm,1):.2%};"
        f"shared_fps={tot_q*N_FRAMES/max(tot_shared_wall,1e-9):.2f};"
        f"indep_fps={tot_q*N_FRAMES/max(tot_indep_wall,1e-9):.2f}")
    return rows


# ---------------------------------------------------------------------------
# Multi-stream serving — 4 feeds, one SharedExtractServer
# ---------------------------------------------------------------------------

MS_FRAMES = 256
MS_FEEDS = (
    ("tb0", "tollbooth", EVAL_SEED, ("Q2", "Q6", "Q8")),
    ("tb1", "tollbooth", 4321, ("Q1", "Q5")),
    ("tb2", "tollbooth", 2025, ("Q3", "Q9")),
    ("vb0", "volleyball", EVAL_SEED, ("Q12", "Q13")),
)


def _ms_feeds():
    from repro.scheduler import Feed

    return [Feed(name, _stream_factory(ds)(seed),
                 [get_query(qid).naive_plan() for qid in qids])
            for name, ds, seed, qids in MS_FEEDS]


def fig_multistream(ctx, cache, frames: int = MS_FRAMES) -> List[str]:
    """Cross-stream shared-MLLM serving: K feeds, one extract server.

    The sharing claim measured here is *forwards*, not frames: the server
    coalesces union extracts from all feeds into shape-bucketed batches,
    so the jitted model runs strictly fewer times than the sum over
    independent per-query runs — with every query's outputs bitwise
    identical to its independent execution."""
    import dataclasses as _dc

    from repro.obs import NULL_TRACER, Observability
    from repro.scheduler import MultiStreamRuntime, SharingTreePlanner

    # no commas inside elements: the cache round-trips keys via ","-join
    key = ("MS-4feeds", ("multistream", str(frames)) + tuple(
        f"{name}:{seed}:{'+'.join(qids)}" for name, _, seed, qids in MS_FEEDS))
    if key in cache:
        out = cache[key]
    else:
        # the acceptance scenario, demonstrated on plan sets that are
        # actually executed: plan tb0's + vb0's workloads together — the
        # global common prefix across their tollbooth+volleyball sources
        # is empty, yet each per-stream subset still factors into a shared
        # group (the same groups the runtime executes for those feeds)
        demo_plans = [get_query(qid).naive_plan()
                      for name, _, _, qids in MS_FEEDS
                      if name in ("tb0", "vb0") for qid in qids]
        demo = SharingTreePlanner().plan(demo_plans)
        group_sizes = sorted((g.n_queries for g in demo.groups()),
                             reverse=True)

        # metrics-only observability (NullTracer: no span recording, just
        # the latency/staleness histograms) — outputs stay bitwise
        # identical, so the exact-match check below still covers it
        obs = Observability(tracer=NULL_TRACER)
        ms = MultiStreamRuntime(_ms_feeds(), _dc.replace(ctx, obs=obs),
                                micro_batch=16)
        exec_groups = {
            name: sorted((g.n_queries for g in ms.forests[name].groups()),
                         reverse=True)
            for name, _, _, _ in MS_FEEDS}
        shared = ms.run(frames)
        lat = obs.slo.combined()
        lat_feeds = {r["feed"]: [r["p50_ms"], r["p95_ms"], r["p99_ms"]]
                     for r in obs.slo.rows()}

        indep_forwards = 0
        indep_wall = 0.0
        exact = True
        for name, ds, seed, qids in MS_FEEDS:
            for qid in qids:
                plan = get_query(qid).naive_plan()
                rt = StreamRuntime(plan, ctx, micro_batch=16)
                ind = rt.run(_stream_factory(ds)(seed), frames)
                indep_forwards += sum(
                    op.forwards for op in plan.ops
                    if hasattr(op, "forwards"))
                indep_wall += ind.wall_s
                sq = shared.feeds[name].per_query[qid]
                exact = exact and sq.outputs == ind.outputs \
                    and sq.window_results == ind.window_results
        out = {
            "n_feeds": shared.n_feeds, "n_queries": shared.n_queries,
            "wall_s": shared.wall_s, "fps": shared.fps,
            "indep_wall_s": indep_wall,
            "mllm_frames": shared.mllm_frames,
            "forwards": shared.server_stats["forwards"],
            "coalesced": shared.server_stats["coalesced_batches"],
            "indep_forwards": indep_forwards,
            "exact": exact,
            "planner_streams": len(demo.streams),
            "planner_groups": group_sizes,
            "exec_groups": exec_groups,
            "lat_p50_ms": lat["p50_ms"], "lat_p95_ms": lat["p95_ms"],
            "lat_p99_ms": lat["p99_ms"], "lat_feeds": lat_feeds,
        }
        cache[key] = out
    rows = [
        f"fig_ms,serving,{out['fps']:.2f},n_feeds={out['n_feeds']};"
        f"n_queries={out['n_queries']};"
        f"indep_fps={out['n_queries'] * frames / max(out['indep_wall_s'], 1e-9):.2f};"
        f"wall_gain={out['indep_wall_s'] / max(out['wall_s'], 1e-9):.2f}x",
        f"fig_ms,forwards,{out['forwards']},indep={out['indep_forwards']};"
        f"ratio={out['forwards'] / max(out['indep_forwards'], 1):.3f};"
        f"coalesced_batches={out['coalesced']};"
        f"acc_exact_match={out['exact']}",
        f"fig_ms,sharing_tree,{len(out['planner_groups'])},"
        f"streams={out['planner_streams']};"
        "global_prefix=empty;tb0+vb0_group_sizes="
        f"{'/'.join(str(s) for s in out['planner_groups'])};"
        "exec_groups=" + "|".join(
            f"{name}:{'+'.join(str(s) for s in sizes)}"
            for name, sizes in out["exec_groups"].items()),
        f"fig_ms,latency_p95_ms,{out['lat_p95_ms']:.1f},"
        f"p50={out['lat_p50_ms']:.1f};p99={out['lat_p99_ms']:.1f};"
        "per_feed=" + "|".join(
            f"{name}:{p50:.0f}/{p95:.0f}/{p99:.0f}"
            for name, (p50, p95, p99) in out["lat_feeds"].items()),
    ]
    return rows


# ---------------------------------------------------------------------------
# Pipelined serving — dispatch-ahead drains vs the synchronous barrier
# ---------------------------------------------------------------------------

def fig_pipeline(ctx, cache, frames: int = MS_FRAMES) -> List[str]:
    """Pipelined async extract serving vs the lock-step synchronous drain,
    on the 4-feed / 9-query mixed workload.

    The pipelined runtime launches coalesced forwards asynchronously
    (``SharedExtractServer.dispatch``) and keeps doing host-side stream
    work while the device computes, double-buffered at ``max_inflight=2``;
    the synchronous baseline (``pipelined=False``) is PR 2's barrier
    drain.  Claims: higher fps (target ≥ 1.25×; the realizable gain is
    the host-side share of the wall — on a CPU-only box whose XLA
    "device" work saturates every core, overlap is contention-bound and
    the measured gain approaches 1×), ≥ 2 in-flight forwards observed,
    and per-query outputs bitwise identical to independent execution —
    pipelining changes *when* forwards run, never what any query
    observes.

    Measurement hygiene: both modes share one server (one compiled
    program cache), each mode gets an untimed compile-warm pass over the
    coalesced bucket shapes it uses, and the measured trials interleave
    (sync, pipe, sync, pipe) with the best trial per mode kept — a
    mid-measure jit compile or a monotonic CPU-share throttle would
    otherwise swamp the effect being measured."""
    from repro.obs import NULL_TRACER, Observability
    from repro.scheduler import MultiStreamRuntime, SharedExtractServer

    key = ("PIPE-4feeds", ("pipeline-v3", str(frames)) + tuple(
        f"{name}:{seed}:{'+'.join(qids)}" for name, _, seed, qids in MS_FEEDS))
    if key in cache:
        out = cache[key]
    else:
        # metrics-only observability rides the shared server; the
        # registry resets before each pipelined trial so the reported
        # latency columns describe pipelined serving, not a sync/pipe mix
        obs = Observability(tracer=NULL_TRACER)
        server = SharedExtractServer(ctx, obs=obs)
        warm = min(frames, 48)
        sync_ms = MultiStreamRuntime(_ms_feeds(), ctx, micro_batch=16,
                                     pipelined=False, server=server)
        pipe_ms = MultiStreamRuntime(_ms_feeds(), ctx, micro_batch=16,
                                     server=server)
        sync_ms.run(warm)
        pipe_ms.run(warm)
        sync = pipe = None
        for _ in range(2):
            s = sync_ms.run(frames)
            obs.metrics.reset()
            p = pipe_ms.run(frames)
            sync = s if sync is None or s.fps > sync.fps else sync
            pipe = p if pipe is None or p.fps > pipe.fps else pipe
        lat = obs.slo.combined()           # the final pipelined trial
        stale = {r["feed"]: r["stale_p99_ms"] for r in obs.slo.rows()}

        exact = True
        for name, ds, seed, qids in MS_FEEDS:
            for qid in qids:
                rt = StreamRuntime(get_query(qid).naive_plan(), ctx,
                                   micro_batch=16)
                ind = rt.run(_stream_factory(ds)(seed), frames)
                pq = pipe.feeds[name].per_query[qid]
                exact = exact and pq.outputs == ind.outputs \
                    and pq.window_results == ind.window_results
        out = {
            "pipe_fps": pipe.fps, "sync_fps": sync.fps,
            "speedup": pipe.fps / max(sync.fps, 1e-9),
            "stats": dict(pipe.server_stats),
            "sync_forwards": sync.server_stats["forwards"],
            "exact": exact,
            "lat_p50_ms": lat["p50_ms"], "lat_p95_ms": lat["p95_ms"],
            "lat_p99_ms": lat["p99_ms"], "stale_p99_ms": stale,
        }
        cache[key] = out
    st = out["stats"]
    rows = [
        f"fig_pipeline,fps,{out['pipe_fps']:.2f},"
        f"sync_fps={out['sync_fps']:.2f};"
        f"speedup={out['speedup']:.2f}x;target>=1.25x",
        f"fig_pipeline,inflight,{st['max_inflight_seen']},"
        f"dispatches={st['dispatches']};forwards={st['forwards']};"
        f"sync_forwards={out['sync_forwards']};"
        f"staging_reused={st['staging_reused']};"
        f"staging_allocated={st['staging_allocated']};"
        f"staging_skipped={st['staging_skipped']}",
        f"fig_pipeline,exact,{out['exact']},per-query outputs bitwise "
        "identical to independent execution",
        f"fig_pipeline,latency_p95_ms,{out['lat_p95_ms']:.1f},"
        f"p50={out['lat_p50_ms']:.1f};p99={out['lat_p99_ms']:.1f};"
        "stale_p99=" + "|".join(
            f"{name}:{v:.0f}" for name, v in out["stale_p99_ms"].items()),
    ]
    return rows


# ---------------------------------------------------------------------------
# Semantic gating — temporal-redundancy extract cache, gated vs ungated
# ---------------------------------------------------------------------------

#: gate configuration the figure measures (also what the acceptance
#: criterion's "configured budget" refers to)
GATE_THRESHOLD = 0.06
GATE_REVALIDATE_EVERY = 8
GATE_ACC_BUDGET = 0.05


def fig_semantic(ctx, cache, frames: int = MS_FRAMES) -> List[str]:
    """Semantic gating tier on the 4-feed / 9-query workload.

    Three serving runs over identical streams: *ungated* (PR 4 serving),
    *gated* (a ``SemanticGate`` in front of the ``SharedExtractServer``:
    near-duplicate frames answered from keyframe caches, every Nth hit
    revalidated through the model, per-feed thresholds tuned online
    against the accuracy budget), and *disabled* (a gate with
    ``threshold=0`` — must be bitwise identical to ungated, the semantic
    tier's no-regression contract).

    Claims measured: ≥ 2× fewer MLLM forwards gated vs ungated, every
    query's accuracy within ``GATE_ACC_BUDGET`` of its ungated score, and
    hit/miss/revalidation/mismatch rates reported (measured, not
    assumed)."""
    from repro.scheduler import MultiStreamRuntime, SharedExtractServer
    from repro.semantic import GateConfig, SemanticGate

    # v2: churn-aware mismatches + newest-keyframe fallback probe
    key = ("SEM-4feeds",
           ("semantic-v2", str(frames), str(GATE_THRESHOLD),
            str(GATE_REVALIDATE_EVERY), str(GATE_ACC_BUDGET)) + tuple(
               f"{name}:{seed}:{'+'.join(qids)}"
               for name, _, seed, qids in MS_FEEDS))
    if key in cache:
        out = cache[key]
    else:
        base = MultiStreamRuntime(_ms_feeds(), ctx, micro_batch=16
                                  ).run(frames)
        gate = SemanticGate(GateConfig(
            threshold=GATE_THRESHOLD,
            revalidate_every=GATE_REVALIDATE_EVERY,
            accuracy_budget=GATE_ACC_BUDGET))
        gated = MultiStreamRuntime(
            _ms_feeds(), ctx, micro_batch=16,
            server=SharedExtractServer(ctx, gate=gate)).run(frames)
        off = MultiStreamRuntime(
            _ms_feeds(), ctx, micro_batch=16,
            server=SharedExtractServer(
                ctx, gate=SemanticGate(GateConfig(threshold=0.0)))
        ).run(frames)

        identical = True
        acc = {}
        for name, _, _, qids in MS_FEEDS:
            for qid in qids:
                bq = base.feeds[name].per_query[qid]
                gq = gated.feeds[name].per_query[qid]
                oq = off.feeds[name].per_query[qid]
                identical = identical and oq.outputs == bq.outputs \
                    and oq.window_results == bq.window_results
                acc[f"{name}:{qid}"] = (get_query(qid).evaluate(bq),
                                        get_query(qid).evaluate(gq))
        st = dict(gated.server_stats)
        out = {
            "gated_forwards": st["forwards"],
            "ungated_forwards": base.server_stats["forwards"],
            "gated_model_frames": st["frames"],
            "ungated_model_frames": base.server_stats["frames"],
            "hits": st["cache_hits"], "misses": st["cache_misses"],
            "revalidations": st["revalidations"],
            "mismatches": st["cache_mismatches"],
            "gated_fps": gated.fps, "ungated_fps": base.fps,
            "accuracy": acc,
            "disabled_identical": identical,
        }
        cache[key] = out

    worst_drop = max(u - g for u, g in out["accuracy"].values())
    within = worst_drop <= GATE_ACC_BUDGET
    served = out["hits"] + out["misses"] + out["revalidations"]
    reduction = out["ungated_forwards"] / max(out["gated_forwards"], 1)
    rows = [
        f"fig_semantic,forwards,{out['gated_forwards']},"
        f"ungated={out['ungated_forwards']};reduction={reduction:.2f}x;"
        f"target>=2x;model_frames={out['gated_model_frames']};"
        f"ungated_frames={out['ungated_model_frames']}",
        f"fig_semantic,cache,{out['hits'] / max(served, 1):.3f},"
        f"hits={out['hits']};misses={out['misses']};"
        f"revalidations={out['revalidations']};"
        f"mismatches={out['mismatches']}",
        f"fig_semantic,fps,{out['gated_fps']:.2f},"
        f"ungated={out['ungated_fps']:.2f};"
        f"speedup={out['gated_fps'] / max(out['ungated_fps'], 1e-9):.2f}x",
        f"fig_semantic,accuracy,{worst_drop:.4f},"
        f"budget={GATE_ACC_BUDGET};within_budget={within};per_query="
        + "|".join(f"{k}:{u:.3f}->{g:.3f}"
                   for k, (u, g) in sorted(out["accuracy"].items())),
        f"fig_semantic,disabled_identity,{out['disabled_identical']},"
        "threshold=0 serving bitwise identical to the ungated tier",
    ]
    return rows


# ---------------------------------------------------------------------------
# Fleet optimization — joint vs per-query optimization under sharing
# ---------------------------------------------------------------------------

FLEET_FRAMES = 256
FLEET_VAL_FRAMES = 128


def _shared_queries(forests) -> int:
    """Queries served by a shared (n>1) group across a set of forests."""
    return sum(g.n_queries
               for forest in forests for g in forest.groups()
               if g.is_shared)


def _run_config(plans_by_feed, ctx, planner=None, with_baseline=True):
    """Execute one plan-set configuration over the MS_FEEDS workload —
    plus, when ``with_baseline``, its independent (per-plan StreamRuntime)
    baseline and the bitwise-exactness check against it (only the fleet
    configuration reports those rows; skipping the baseline for the others
    drops the section's dominant cost)."""
    from repro.scheduler import Feed, MultiStreamRuntime
    from repro.streaming.runtime import StreamRuntime

    seeds = {name: (ds, seed) for name, ds, seed, _ in MS_FEEDS}
    feeds = [Feed(name, _stream_factory(seeds[name][0])(seeds[name][1]),
                  [p.clone() for p in plans])
             for name, plans in plans_by_feed.items()]
    ms = MultiStreamRuntime(feeds, ctx, micro_batch=16, planner=planner)
    shared = ms.run(FLEET_FRAMES)
    out = {
        "fps": shared.fps,
        "wall_s": shared.wall_s,
        "forwards": shared.server_stats["forwards"],
        "coalesced": shared.server_stats["coalesced_batches"],
        "mllm_frames": shared.mllm_frames,
        "shared_queries": _shared_queries(ms.forests.values()),
    }
    if not with_baseline:
        return out

    indep_forwards = 0
    indep_wall = 0.0
    exact = True
    for name, plans in plans_by_feed.items():
        ds, seed = seeds[name]
        for p in plans:
            plan = p.clone()
            rt = StreamRuntime(plan, ctx, micro_batch=16)
            ind = rt.run(_stream_factory(ds)(seed), FLEET_FRAMES)
            indep_forwards += sum(op.forwards for op in plan.ops
                                  if hasattr(op, "forwards"))
            indep_wall += ind.wall_s
            sq = shared.feeds[name].per_query[p.query]
            exact = exact and sq.outputs == ind.outputs \
                and sq.window_results == ind.window_results
    out.update(indep_forwards=indep_forwards, indep_wall_s=indep_wall,
               exact=exact)
    return out


def fig_fleet(ctx, cache) -> List[str]:
    """Joint sharing-aware optimization vs per-query optimization vs naive
    sharing, all executed through the multi-stream serving tier.

    The claim: per-query super-optimization destroys the prefix alignment
    sharing depends on; the fleet optimizer keeps (canonicalizes) it, so
    jointly-optimized plans retain at least as many queries in shared
    groups as unoptimized sharing — while still enjoying the optimizer's
    model-load reductions — with every planned op cost measured (zero
    static-default fallbacks) and outputs bitwise identical to solo runs
    of the same plans."""
    from repro.core.fleet import FleetOptimizer, FleetQuery
    from repro.scheduler.sharing_tree import uncalibrated

    # v3: tails costed at the prefix's survivor fraction (no boundary
    # asymmetry); v2: overhead-aware calibrated cost model
    key = ("FLEET", ("fleet-v3", str(FLEET_FRAMES), str(FLEET_VAL_FRAMES))
           + tuple(f"{name}:{seed}:{'+'.join(qids)}"
                   for name, _, seed, qids in MS_FEEDS))
    if key in cache:
        out = cache[key]
    else:
        workload = [FleetQuery(get_query(qid), _stream_factory(ds),
                               feed=name)
                    for name, ds, seed, qids in MS_FEEDS for qid in qids]
        fo = FleetOptimizer(ctx, val_frames=FLEET_VAL_FRAMES)
        fleet = fo.optimize(workload)

        def by_feed(plan_map):
            return {feed: [plan_map[k] for k in keys]
                    for feed, keys in fleet.feed_keys.items()}

        naive = _run_config(by_feed(fleet.naive_plans), ctx,
                            planner=fo.planner, with_baseline=False)
        solo = _run_config(by_feed(fleet.solo_plans), ctx,
                           planner=fo.planner, with_baseline=False)
        joint = _run_config(fleet.plans_by_feed, ctx, planner=fo.planner)

        uncal = [n for p in fleet.plans.values()
                 for n in uncalibrated(p.ops)]
        opt_wall = {}
        for rep in fleet.reports.values():
            for ph, w in rep.phase_wall_s.items():
                opt_wall[ph] = opt_wall.get(ph, 0.0) + w
        out = {
            "naive": naive, "solo": solo, "fleet": joint,
            "est_cost_us": fleet.fleet_cost_us,
            "uncalibrated": uncal,
            "catalog_rows": fleet.catalog.rows(),
            "opt_wall_s": opt_wall,
            "decisions": len(fleet.decisions),
        }
        cache[key] = out

    nv, so, fl = out["naive"], out["solo"], out["fleet"]
    survives = fl["shared_queries"] >= nv["shared_queries"]
    rows = [
        f"fig_fleet,fps,{fl['fps']:.2f},naive={nv['fps']:.2f};"
        f"solo={so['fps']:.2f};"
        f"gain_vs_naive={fl['fps'] / max(nv['fps'], 1e-9):.2f}x",
        f"fig_fleet,forwards,{fl['forwards']},naive={nv['forwards']};"
        f"solo={so['forwards']};indep_fleet={fl['indep_forwards']};"
        f"coalesced={fl['coalesced']}",
        f"fig_fleet,shared_queries,{fl['shared_queries']},"
        f"naive={nv['shared_queries']};solo={so['shared_queries']};"
        f"sharing_survives={survives}",
        f"fig_fleet,exact,{fl['exact']},per-query outputs bitwise equal "
        "to solo runs of the fleet plans",
        f"fig_fleet,uncalibrated_ops,{len(out['uncalibrated'])},"
        f"est_cost_us={';'.join(f'{k}={v:.0f}' for k, v in out['est_cost_us'].items())}",
        f"fig_fleet,opt_wall_s,"
        f"{sum(out['opt_wall_s'].values()):.2f},"
        + ";".join(f"{k}={v:.2f}" for k, v in out["opt_wall_s"].items()),
    ]
    for r in out["catalog_rows"]:
        rows.append(
            f"fig_fleet,cost.{r['op']},{r['us']:.2f},"
            f"overhead_us={r.get('overhead_us', 0.0):.1f};"
            f"pass_rate={r['pass_rate']:.3f};n={r['n']};"
            f"direct={r['direct']}")
    return rows


# ---------------------------------------------------------------------------
# Fused prefix execution — one device pass per surviving micro-batch
# ---------------------------------------------------------------------------

FUSED_MB = 16           # serving micro-batch the fused pass dispatches on
FUSED_CAR_RATE = 0.2    # dense stream: survivors actually reach the tail


def _fused_chain():
    from repro.streaming.operators import (
        CheapColorFilterOp,
        DetectOp,
        FusedPreprocessOp,
        SkipOp,
    )

    return [SkipOp(), CheapColorFilterOp(color="red", min_frac=0.0),
            FusedPreprocessOp(crop=(64, 0, 64, 256), factor=2),
            DetectOp(threshold=0.1)]


def fig_fused(ctx, cache, frames: int = MS_FRAMES) -> List[str]:
    """Fused prefix execution: the 4-op surviving-frame prefix (Skip's
    frame diff, cheap color filter, fused preprocess, TinyDet) **plus**
    the semantic gate's ``TemporalSignature`` compiled into ONE device
    pass per micro-batch (``FusedPrefixOp``), vs the unfused op sequence
    — one dispatch per op plus the gate's separate signature pass.

    Claims measured: ≥ 3× fewer prefix dispatches per micro-batch (5 → 1
    on the 4-op chain), fused prefix wall per micro-batch no worse than
    unfused on the dense stream, bitwise-identical results (kept rows,
    transformed frames, gate signature), end-to-end serving fps through
    ``MultiStreamRuntime``, and the physical phase's calibrated choice in
    both regimes — fuse where the one-pass wins, refuse on the sparse
    default stream where Skip kills nearly every row before the
    expensive stages (fusing there would compute them on all rows)."""
    import copy

    from repro.core.costs import CostCatalog
    from repro.core.physical import PhysicalOptimizer
    from repro.scheduler import Feed, MultiStreamRuntime
    from repro.semantic.signature import TemporalSignature
    from repro.streaming.fused import FusedPrefixOp
    from repro.streaming.operators import MLLMExtractOp

    key = ("FUSED", ("fused-v1", str(frames), str(FUSED_MB),
                     str(FUSED_CAR_RATE)))
    if key in cache:
        out = cache[key]
    else:
        chain = _fused_chain()
        stream = TollBoothStream(seed=3, car_rate=FUSED_CAR_RATE)
        batches = [stream.batch(FUSED_MB)[0]
                   for _ in range(max(frames // FUSED_MB, 4))]
        sig = TemporalSignature()

        def run_unfused(record=None):
            ops = [copy.deepcopy(o) for o in chain]
            for o in ops:
                o.open(ctx)
                o.reset()
            for fr in batches:
                b = {"frames": fr, "idx": np.arange(fr.shape[0])}
                for o in ops:           # the runtime's chain walk
                    if b["frames"].shape[0] == 0:
                        break
                    b = o.process(b)
                s = sig.features(b["frames"]) \
                    if b["frames"].shape[0] else None
                if record is not None:
                    record.append((b, s))

        def run_fused(record=None):
            fop = FusedPrefixOp(
                stage_ops=tuple(copy.deepcopy(o) for o in chain), sig=True)
            fop.open(ctx)
            fop.reset()
            for fr in batches:
                b = fop.process({"frames": fr,
                                 "idx": np.arange(fr.shape[0])})
                if record is not None:
                    record.append(b)

        ru: List = []
        rf: List = []
        run_unfused(ru)     # compile warmup doubles as the bitwise pass
        run_fused(rf)
        bitwise = True
        for (bu, su), bf in zip(ru, rf):
            feats, emb = bf.pop("_sig")
            bitwise = bitwise and np.array_equal(bu["idx"], bf["idx"])
            if bu["idx"].shape[0] == 0:
                bitwise = bitwise and feats.shape[0] == 0
                continue
            bitwise = bitwise \
                and np.array_equal(bu["frames"], bf["frames"]) \
                and np.array_equal(np.asarray(su[0]), feats) \
                and np.array_equal(np.asarray(su[1]), emb)

        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            run_unfused()
        unfused_us = (time.perf_counter() - t0) / (reps * len(batches)) * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            run_fused()
        fused_us = (time.perf_counter() - t0) / (reps * len(batches)) * 1e6

        # ---- end-to-end serving: fused vs unfused plan -----------------
        def plan(fuse):
            p = get_query("Q2").naive_plan()
            ops = _fused_chain()
            if fuse:
                ops = [FusedPrefixOp(stage_ops=tuple(ops), sig=True)]
            for op in ops:  # each lands immediately before the extract
                p.insert_before(MLLMExtractOp, op)
            return p

        def run_ms(fuse):
            ms = MultiStreamRuntime(
                [Feed("tb",
                      TollBoothStream(seed=3, car_rate=FUSED_CAR_RATE),
                      [plan(fuse)])],
                ctx, micro_batch=FUSED_MB)
            return ms.run(frames)

        base = run_ms(False)
        fused = run_ms(True)
        bq = base.feeds["tb"].per_query["Q2"]
        fq = fused.feeds["tb"].per_query["Q2"]
        e2e_identical = fq.outputs == bq.outputs \
            and fq.window_results == bq.window_results

        # ---- the physical phase's calibrated decision, both regimes ----
        def decide(sample):
            p = get_query("Q2").naive_plan()
            for op in _fused_chain():
                p.insert_before(MLLMExtractOp, op)
            report: Dict[str, Any] = {"decisions": []}
            PhysicalOptimizer(ctx)._fuse_prefix(
                p, report, CostCatalog(), None, sample)
            return report["fused_prefix"]

        dense = decide(TollBoothStream(
            seed=3, car_rate=FUSED_CAR_RATE).batch(FUSED_MB)[0])
        sparse = decide(TollBoothStream(seed=404).batch(64)[0])

        out = {
            "dispatches_fused": 1,
            # one jitted call per member op + the gate's signature pass
            "dispatches_unfused": len(chain) + 1,
            "chain_len": len(chain),
            "fused_us": fused_us, "unfused_us": unfused_us,
            "bitwise": bitwise,
            "fused_fps": fused.fps, "base_fps": base.fps,
            "e2e_identical": e2e_identical,
            "dense": dense, "sparse": sparse,
        }
        cache[key] = out

    ratio = out["dispatches_unfused"] / max(out["dispatches_fused"], 1)
    rows = [
        f"fig_fused,dispatches,{out['dispatches_fused']},"
        f"unfused={out['dispatches_unfused']};reduction={ratio:.1f}x;"
        f"target>=3x;chain={out['chain_len']}ops+signature",
        f"fig_fused,prefix_wall_us,{out['fused_us']:.1f},"
        f"unfused={out['unfused_us']:.1f};"
        f"speedup={out['unfused_us'] / max(out['fused_us'], 1e-9):.2f}x;"
        f"micro_batch={FUSED_MB}",
        f"fig_fused,bitwise,{out['bitwise']},kept rows + frames + gate "
        "signature identical fused vs unfused",
        f"fig_fused,fps,{out['fused_fps']:.2f},"
        f"unfused={out['base_fps']:.2f};"
        f"speedup={out['fused_fps'] / max(out['base_fps'], 1e-9):.2f}x;"
        f"e2e_identical={out['e2e_identical']}",
        f"fig_fused,decision_dense,{out['dense']['fused']},"
        f"fused_us={out['dense']['fused_us']:.0f};"
        f"unfused_us={out['dense']['unfused_us']:.0f};"
        f"batch={out['dense']['batch']}",
        f"fig_fused,decision_sparse,{out['sparse']['fused']},"
        f"fused_us={out['sparse']['fused_us']:.0f};"
        f"unfused_us={out['sparse']['unfused_us']:.0f};"
        f"batch={out['sparse']['batch']};"
        "calibrated refusal: Skip kills the batch up front",
    ]
    return rows


#: the feed sacrificed to the fault injector in ``fig_chaos`` (one of
#: the four MS_FEEDS; the other three are the healthy fleet)
CHAOS_SICK = "tb1"
CHAOS_SEED = 11


def _chaos_rules(regime: str):
    """The three failure regimes of fig_chaos, as fault schedules.

    ``crash``: the sick feed's transport goes dead (corrupt deliveries
    past any retry budget) — the breaker must trip and quarantine it.
    ``slow``: every sick-feed forward completes late (injected device
    latency) — absorbed, bitwise.  ``flaky``: transient forward errors
    that clear on retry plus periodic source stalls — absorbed, bitwise,
    paid for in retries."""
    from repro.faults import FaultRule

    if regime == "crash":
        return [FaultRule(site="source", kind="corrupt", feed=CHAOS_SICK,
                          start=1, every=1, param=99)]
    if regime == "slow":
        return [FaultRule(site="forward", kind="latency", feed=CHAOS_SICK,
                          every=1, param=2)]
    assert regime == "flaky"
    return [FaultRule(site="forward", kind="error", feed=CHAOS_SICK,
                      every=3, param=1),
            FaultRule(site="source", kind="stall", feed=CHAOS_SICK,
                      start=2, every=4)]


def fig_chaos(ctx, cache, frames: int = MS_FRAMES) -> List[str]:
    """Fleet serving with 1-of-4 feeds failing, vs fault-free.

    Claims measured, per regime (crash / slow / flaky): the three
    healthy feeds keep their outputs bitwise identical to the fault-free
    run at ≥ 0.9× its throughput (``healthy_fps_ratio`` = fault-free
    wall / faulted wall over the same healthy workload); *zero* wrong
    results — every served answer matches the fault-free run at its
    frame index, losses are marked degraded/dropped, and served +
    degraded + dropped exactly partitions the sick feed's frames."""
    import dataclasses as _dc  # noqa: F401  (parallel to fig_multistream)

    from repro.faults import FaultInjector
    from repro.scheduler import MultiStreamRuntime

    key = ("MS-chaos", ("chaos", str(frames), str(CHAOS_SEED)))
    if key in cache:
        out = cache[key]
    else:
        base = MultiStreamRuntime(_ms_feeds(), ctx, micro_batch=16)\
            .run(frames)
        base_out = {f: {q: r.outputs
                        for q, r in base.feeds[f].per_query.items()}
                    for f in base.feeds}
        healthy = [n for n, _, _, _ in MS_FEEDS if n != CHAOS_SICK]
        regimes: Dict[str, Dict] = {}
        for regime in ("crash", "slow", "flaky"):
            inj = FaultInjector(_chaos_rules(regime), seed=CHAOS_SEED)
            res = MultiStreamRuntime(_ms_feeds(), ctx, micro_batch=16,
                                     faults=inj).run(frames)
            wrong = 0
            for f in res.feeds:
                for q, r in res.feeds[f].per_query.items():
                    want = {w["idx"]: w for w in base_out[f][q]}
                    wrong += sum(1 for o in r.outputs
                                 if want.get(o["idx"]) != o)
            healthy_exact = all(
                {q: r.outputs
                 for q, r in res.feeds[f].per_query.items()} == base_out[f]
                for f in healthy)
            sick = res.feeds[CHAOS_SICK]
            regimes[regime] = {
                "wall_s": res.wall_s,
                "healthy_fps_ratio":
                    base.wall_s / max(res.wall_s, 1e-9),
                "wrong": wrong, "healthy_exact": healthy_exact,
                "served": sick.served, "degraded": sick.degraded,
                "dropped": sick.dropped,
                "availability": sick.served / max(frames, 1),
                "trips": sick.breaker.get("trips", 0),
                "recoveries": sick.breaker.get("recoveries", 0),
                "faults_fired": len(inj.log),
            }
        out = {"base_wall_s": base.wall_s, "base_fps": base.fps,
               "regimes": regimes}
        cache[key] = out
    rows = [f"fig_chaos,fault_free,{out['base_fps']:.2f},"
            f"wall_s={out['base_wall_s']:.2f};sick_feed={CHAOS_SICK}"]
    for regime, r in out["regimes"].items():
        ok = r["healthy_fps_ratio"] >= 0.9 and r["wrong"] == 0 \
            and r["healthy_exact"] \
            and r["served"] + r["degraded"] + r["dropped"] == frames
        rows.append(
            f"fig_chaos,{regime},{r['healthy_fps_ratio']:.2f},"
            f"availability={r['availability']:.2f};"
            f"served={r['served']};degraded={r['degraded']};"
            f"dropped={r['dropped']};wrong={r['wrong']};"
            f"healthy_exact={r['healthy_exact']};trips={r['trips']};"
            f"recoveries={r['recoveries']};"
            f"faults_fired={r['faults_fired']};target_met={ok}")
    return rows


CACHE_PATH = os.path.join(REPORT_DIR, "samsara_bench.json")

#: bump when runtime semantics change measured results (v2: end-of-stream
#: partial-window flush; v3: per-frame extract normalization shared with
#: the SharedExtractServer; v4: pipelined dispatch-ahead serving is the
#: multi-stream default and CheapColor/Detect normalize per frame;
#: v5: fig_ms/fig_pipeline rows gain latency-percentile columns whose
#: fields a v4 cache entry lacks; v6: fused-prefix execution — one device
#: pass per surviving micro-batch — changes prefix dispatch behavior and
#: adds fig_fused; v7: fault-tolerant serving adds fig_chaos and the
#: chaos accounting fields) — a stale cache would silently mix semantics
CACHE_VERSION = 7


def _load_cache() -> Dict:
    """Reuse previously-measured (query, phases) results if present —
    the streaming benchmark is expensive on CPU; delete the JSON (or pass
    use_cache=False) to force remeasurement."""
    cache: Dict = {}
    if os.path.exists(CACHE_PATH):
        with open(CACHE_PATH) as f:
            data = json.load(f)
        if data.pop("_version", None) != CACHE_VERSION:
            return {}
        for key, val in data.items():
            qid, phases = key.split("|")
            cache[(qid, tuple(p for p in phases.split(",") if p))] = val
    return cache


#: frames per feed for the smoke-tier (quick-models) serving figures
MS_QUICK_FRAMES = 48


def run_all(quick: bool = False, use_cache: bool = True,
            quick_models: bool = False,
            sections: Optional[List[str]] = None,
            exclude: Optional[List[str]] = None) -> Iterator[str]:
    """Run the Saṃsāra figures.

    ``sections`` picks figures by name (None: fig1b under ``quick``, all
    figures otherwise); ``exclude`` drops figures from that default (the
    driver uses it when a figure also runs as its own top-level section).
    ``quick_models`` swaps in the tiny smoke models and short serving
    streams — and disables the result cache, so smoke-tier measurements
    never mix with full-model ones (this is what ``scripts/smoke.sh`` /
    CI run for the per-PR perf trajectory)."""
    if quick_models:
        from repro.streaming.pretrain import quick_stream_models

        ctx = quick_stream_models()
        use_cache = False
    else:
        ctx = train_stream_models(verbose=False)
    cache: Dict = _load_cache() if use_cache else {}
    os.makedirs(REPORT_DIR, exist_ok=True)
    ms_frames = MS_QUICK_FRAMES if quick_models else MS_FRAMES
    figs = {
        "fig1b": fig1b_q8_naive_vs_optimized,
        "fig5": fig5_end_to_end,
        "table2": table2_ablation,
        "fig_mq": fig_multiquery,
        "fig_ms": lambda c, k: fig_multistream(c, k, frames=ms_frames),
        "fig_pipeline": lambda c, k: fig_pipeline(c, k, frames=ms_frames),
        "fig_fleet": fig_fleet,
        "fig_semantic": lambda c, k: fig_semantic(c, k, frames=ms_frames),
        "fig_fused": lambda c, k: fig_fused(c, k, frames=ms_frames),
        "fig_chaos": lambda c, k: fig_chaos(c, k, frames=ms_frames),
    }
    if sections is None:
        sections = ["fig1b"] if quick else list(figs)
        if exclude:
            sections = [s for s in sections if s not in exclude]
    unknown = [s for s in sections if s not in figs]
    assert not unknown, f"unknown samsara sections {unknown}"
    # a generator with the cache save in ``finally``: the driver gets
    # every completed figure's rows even when a later figure raises, and
    # the result cache still lands on disk either way
    try:
        for name in sections:
            for row in figs[name](ctx, cache):
                yield row
    finally:
        if use_cache:
            with open(CACHE_PATH, "w") as f:
                payload = {f"{q}|{','.join(p)}": r
                           for (q, p), r in cache.items()}
                payload["_version"] = CACHE_VERSION
                json.dump(payload, f, indent=1)
