#!/usr/bin/env bash
# Smoke tier: the fast test suite, a quick-mode run of every example,
# the deterministic chaos smoke (fault-injection contract tests + the
# fixed-seed fault-timeline trace check), and the quick serving
# benchmarks (fig_multistream + fig_pipeline + fig_semantic + fig_fused
# on tiny models — the per-PR perf trajectory, written to
# reports/benchmarks/).
#
#   scripts/smoke.sh              # everything
#   scripts/smoke.sh tests        # tests only
#   scripts/smoke.sh examples     # examples only
#   scripts/smoke.sh bench        # quick serving benchmarks only
#   scripts/smoke.sh gate         # bench gate vs committed baseline
#   scripts/smoke.sh obs          # observability walkthrough + trace check
#   scripts/smoke.sh chaos        # fault-injection smoke + fault-timeline check
#
# Matches the CI workflow (.github/workflows/ci.yml); keep the two in sync.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

what="${1:-all}"

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "=== pytest -m 'not slow' ==="
    python -m pytest -x -q -m "not slow"
fi

if [[ "$what" == "all" || "$what" == "examples" ]]; then
    # every example must run to completion in quick mode
    for ex in examples/*.py; do
        echo "=== $ex --quick ==="
        python "$ex" --quick
    done
fi

if [[ "$what" == "all" || "$what" == "obs" ]]; then
    # the examples loop above already ran the walkthrough in "all" mode;
    # standalone "obs" runs it itself, then both validate the exported
    # trace (Chrome trace-event JSON, >= 6 lifecycle span phases)
    if [[ "$what" == "obs" ]]; then
        echo "=== examples/observe_serve.py --quick ==="
        python examples/observe_serve.py --quick
    fi
    echo "=== reports/trace.json sanity ==="
    python - <<'EOF'
import json
from repro.obs import PHASES
evs = json.load(open("reports/trace.json"))["traceEvents"]
cats = {e["cat"] for e in evs if e.get("ph") == "X"}
phases = sorted(cats & set(PHASES))
assert len(phases) >= 6, f"trace has too few lifecycle phases: {phases}"
print(f"trace.json OK: {len(evs)} events, phases={phases}")
EOF
fi

if [[ "$what" == "all" || "$what" == "chaos" ]]; then
    # deterministic chaos smoke: the fault-injection contract tests, then
    # the 4-feed / 9-query workload under a fixed-seed fault schedule
    # (examples/chaos_serve.py; the "all"-mode examples loop already ran
    # it and exported reports/chaos_trace.json), and a fault-timeline
    # sanity check on the exported Perfetto trace
    echo "=== pytest -m chaos ==="
    python -m pytest -q -m chaos
    if [[ "$what" == "chaos" ]]; then
        echo "=== examples/chaos_serve.py --quick ==="
        python examples/chaos_serve.py --quick
    fi
    echo "=== reports/chaos_trace.json sanity ==="
    python - <<'EOF'
import json
from repro.obs import FAULT_PHASES
evs = json.load(open("reports/chaos_trace.json"))["traceEvents"]
cats = {e["cat"] for e in evs if e.get("ph") in ("X", "i", "I")}
fault = sorted(cats & set(FAULT_PHASES))
assert len(fault) >= 2, f"chaos trace has no fault timeline: {sorted(cats)}"
print(f"chaos_trace.json OK: {len(evs)} events, fault categories={fault}")
EOF
fi

if [[ "$what" == "all" || "$what" == "bench" ]]; then
    echo "=== benchmarks: fig_multistream + fig_pipeline + fig_semantic + fig_fused (quick models) ==="
    python -m benchmarks.run --sections samsara,fig_semantic,fig_fused \
        --samsara-figs fig_ms,fig_pipeline --quick-models \
        --json reports/benchmarks
fi

if [[ "$what" == "all" || "$what" == "gate" ]]; then
    # compare this run's BENCH rows against the committed baseline.
    # Warn-only for now: CI runner hardware differs from the host that
    # seeded the baseline (cross-host deltas never fail the build), and
    # the gate itself is new — flip to blocking by dropping --warn-only
    # once a CI-host baseline has been committed (tracked in ROADMAP).
    echo "=== bench gate (vs reports/benchmarks/baseline, warn-only) ==="
    python scripts/bench_gate.py --warn-only \
        --report reports/flight_report.md
fi

echo "smoke OK"
