#!/usr/bin/env bash
# Smoke tier: the fast test suite, a quick-mode run of every example, and
# the quick serving benchmarks (fig_multistream + fig_pipeline +
# fig_semantic on tiny models — the per-PR perf trajectory, written to
# reports/benchmarks/).
#
#   scripts/smoke.sh              # everything
#   scripts/smoke.sh tests        # tests only
#   scripts/smoke.sh examples     # examples only
#   scripts/smoke.sh bench        # quick serving benchmarks only
#
# Matches the CI workflow (.github/workflows/ci.yml); keep the two in sync.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

what="${1:-all}"

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "=== pytest -m 'not slow' ==="
    python -m pytest -x -q -m "not slow"
fi

if [[ "$what" == "all" || "$what" == "examples" ]]; then
    # every example must run to completion in quick mode
    for ex in examples/*.py; do
        echo "=== $ex --quick ==="
        python "$ex" --quick
    done
fi

if [[ "$what" == "all" || "$what" == "bench" ]]; then
    echo "=== benchmarks: fig_multistream + fig_pipeline + fig_semantic (quick models) ==="
    python -m benchmarks.run --sections samsara,fig_semantic \
        --samsara-figs fig_ms,fig_pipeline --quick-models \
        --json reports/benchmarks
fi

echo "smoke OK"
