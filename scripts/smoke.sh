#!/usr/bin/env bash
# Smoke tier: the fast test suite plus a quick-mode run of every example.
#
#   scripts/smoke.sh              # everything
#   scripts/smoke.sh tests        # tests only
#   scripts/smoke.sh examples     # examples only
#
# Matches the CI workflow (.github/workflows/ci.yml); keep the two in sync.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

what="${1:-all}"

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "=== pytest -m 'not slow' ==="
    python -m pytest -x -q -m "not slow"
fi

if [[ "$what" == "all" || "$what" == "examples" ]]; then
    # every example must run to completion in quick mode
    for ex in examples/*.py; do
        echo "=== $ex --quick ==="
        python "$ex" --quick
    done
fi

echo "smoke OK"
