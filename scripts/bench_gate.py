"""CI bench gate: compare current BENCH rows against the committed baseline.

  PYTHONPATH=src python scripts/bench_gate.py \\
      [--baseline reports/benchmarks/baseline] \\
      [--current reports/benchmarks] [--tolerance 0.5] \\
      [--warn-only] [--report reports/flight_report.md]

Rows compare per host-provenance key (``benchmarks.history.host_key``):
only the baseline rows whose host matches the current run gate hard —
perf numbers from a different machine are rendered for context but
flagged as cross-host and never fail the build (they still warn, so a
grossly wrong trajectory is visible even when CI hardware rotated).

Noise policy: trials collapse to best-of (min for lower-is-better), and
the tolerance is deliberately loose by default (50% — shared CI runners
jitter hugely); the gate is for 2×-class regressions, the flight report
carries the precise numbers.

Exit status: 0 when nothing regressed (or ``--warn-only``), 1 on a
same-host regression, 2 on usage errors (missing baseline dir).
``--report`` appends a "## Bench deltas" markdown section to the flight
report so one artifact carries SLO + audit + perf trajectory.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.history import compare, host_key, load_bench_dir  # noqa: E402


def _render_markdown(deltas, cross_host: bool) -> str:
    lines = ["## Bench deltas", ""]
    if cross_host:
        lines += ["> baseline was produced on a different host — deltas "
                  "are context, not gated", ""]
    if not deltas:
        lines += ["no comparable metrics between baseline and current "
                  "run", ""]
        return "\n".join(lines)
    lines += ["| metric | baseline | current | worse-by | status |",
              "|---|---:|---:|---:|---|"]
    for d in deltas:
        status = "**REGRESSED**" if d["regressed"] else "ok"
        lines.append(
            f"| {d['name']} ({d['direction']} better) "
            f"| {d['baseline']:.4g} | {d['current']:.4g} "
            f"| {d['ratio']:.2f}x | {status} |")
    lines.append("")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="compare BENCH_*.json against the committed baseline")
    ap.add_argument("--baseline", default="reports/benchmarks/baseline")
    ap.add_argument("--current", default="reports/benchmarks")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed relative worsening before a metric "
                         "counts as regressed (0.5 = 50%%)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (first-PR mode)")
    ap.add_argument("--report", metavar="MD", default=None,
                    help="append a '## Bench deltas' section to this "
                         "markdown file")
    args = ap.parse_args()

    if not os.path.isdir(args.baseline):
        print(f"bench_gate: baseline dir {args.baseline!r} missing — "
              "seed it with benchmarks/run.py --write-baseline",
              file=sys.stderr)
        sys.exit(2)
    baseline = load_bench_dir(args.baseline)
    current = load_bench_dir(args.current)
    if not current:
        print(f"bench_gate: no BENCH_*.json under {args.current!r} — "
              "run benchmarks/run.py --json first", file=sys.stderr)
        sys.exit(2)

    cur_keys = {host_key(r) for r in current}
    matched = [r for r in baseline if host_key(r) in cur_keys]
    cross_host = not matched
    if cross_host:
        print("bench_gate: WARNING — no baseline rows share this host's "
              "provenance key; comparing cross-host (warn-only for these "
              "deltas)", file=sys.stderr)
        matched = baseline

    deltas = compare(matched, current, tolerance=args.tolerance)
    regressed = [d for d in deltas if d["regressed"]]
    for d in deltas:
        tag = "REGRESSED" if d["regressed"] else "ok"
        print(f"{tag:>9}  {d['name']:<40} baseline={d['baseline']:.4g} "
              f"current={d['current']:.4g} worse-by={d['ratio']:.2f}x "
              f"({d['direction']} is better)")
    if not deltas:
        print("bench_gate: no comparable metrics (nothing gated)")

    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "a") as f:
            if f.tell():
                f.write("\n")
            f.write(_render_markdown(deltas, cross_host))
        print(f"bench_gate: deltas appended to {args.report}")

    if regressed:
        print(f"bench_gate: {len(regressed)} metric(s) regressed beyond "
              f"{args.tolerance:.0%} tolerance", file=sys.stderr)
        if not (args.warn_only or cross_host):
            sys.exit(1)
        print("bench_gate: warn-only — not failing the build",
              file=sys.stderr)
    sys.exit(0)


if __name__ == "__main__":
    main()
