"""Per-feed SLO accounting: frame latency, staleness, violation budget.

The serving claim the paper stakes out is *latency under load*, not just
throughput: a query's answers are worthless if they arrive long after
the frames they describe.  ``SLOTracker`` gives each feed:

  * **frame latency** — emit − ingest of the frame's own micro-batch:
    the time a frame spends inside the serving stack (prefix ops, gate
    consult, server queue-wait, device forward, resume, tail);
  * **staleness** — emit − newest arrival: how far the feed's freshest
    served answer lags behind its stream head.  Under pipelined serving
    staleness exceeds latency whenever new frames arrive while older
    ones are still in flight — the backlog the per-feed backpressure
    budget bounds;
  * **violations** — emitted frames whose latency exceeded the feed's
    target (one target per tracker; per-feed overrides via
    ``set_target``).

Distributions live in the shared ``Metrics`` registry (histograms
``frame_latency_ms/<feed>`` and ``staleness_ms/<feed>``, counters
``frames_emitted/<feed>`` / ``slo_violations/<feed>``), so the SLO view
is a *reader* of the same registry everything else reports into.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.metrics import Metrics


class SLOTracker:
    """Per-feed latency/staleness accounting over a ``Metrics`` registry."""

    def __init__(self, metrics: Metrics, target_ms: float = 100.0):
        self.metrics = metrics
        self.target_ms = target_ms
        self._targets: Dict[str, float] = {}
        self._feeds: List[str] = []

    def set_target(self, feed: str, target_ms: float) -> None:
        self._targets[feed] = target_ms

    def target(self, feed: str) -> float:
        return self._targets.get(feed, self.target_ms)

    # -- recording (called at emit) -------------------------------------
    def record(self, feed: str, latency_ms: float,
               staleness_ms: Optional[float] = None, n: int = 1) -> None:
        """Account ``n`` frames emitted with the given latency (ms) and
        optional staleness (ms)."""
        if feed not in self._feeds:
            self._feeds.append(feed)
        m = self.metrics
        m.observe(f"frame_latency_ms/{feed}", latency_ms, n)
        if staleness_ms is not None:
            m.observe(f"staleness_ms/{feed}", staleness_ms, n)
        m.inc(f"frames_emitted/{feed}", n)
        if latency_ms > self.target(feed):
            m.inc(f"slo_violations/{feed}", n)

    def record_degraded(self, feed: str, n: int = 1) -> None:
        """Account ``n`` frames answered in degraded mode — a stale
        keyframe answer served while the feed's circuit was open.  They
        count against availability, not against the latency SLO (a
        marked-stale answer makes no latency promise)."""
        if feed not in self._feeds:
            self._feeds.append(feed)
        self.metrics.inc(f"frames_degraded/{feed}", n)

    def record_dropped(self, feed: str, n: int = 1) -> None:
        """Account ``n`` frames dropped during an outage (no stale
        answer was available) — exact loss accounting."""
        if feed not in self._feeds:
            self._feeds.append(feed)
        self.metrics.inc(f"frames_dropped/{feed}", n)

    # -- reporting ------------------------------------------------------
    def feeds(self) -> List[str]:
        return list(self._feeds)

    def row(self, feed: str) -> Dict[str, Any]:
        m = self.metrics
        lat = m.histogram(f"frame_latency_ms/{feed}")
        stale = m.histogram(f"staleness_ms/{feed}")
        emitted = m.counter(f"frames_emitted/{feed}").value
        viol = m.counter(f"slo_violations/{feed}").value
        degraded = m.counter(f"frames_degraded/{feed}").value
        dropped = m.counter(f"frames_dropped/{feed}").value
        accounted = emitted + degraded + dropped
        return {
            "feed": feed, "frames": emitted,
            "p50_ms": lat.percentile(50), "p95_ms": lat.percentile(95),
            "p99_ms": lat.percentile(99), "mean_ms": lat.mean(),
            "stale_p50_ms": stale.percentile(50),
            "stale_p99_ms": stale.percentile(99),
            "target_ms": self.target(feed), "violations": viol,
            "attainment": 1.0 - viol / emitted if emitted else 1.0,
            # degraded-mode accounting: availability = fully served /
            # everything the feed had to answer for
            "degraded": degraded, "dropped": dropped,
            "availability": emitted / accounted if accounted else 1.0,
        }

    def rows(self) -> List[Dict[str, Any]]:
        return [self.row(f) for f in self._feeds]

    def combined(self) -> Dict[str, Any]:
        """Workload-wide percentiles: one histogram merged across feeds
        (bin-exact — every per-feed histogram shares the binning)."""
        m = self.metrics
        agg = None
        emitted = viol = 0
        for feed in self._feeds:
            h = m.histogram(f"frame_latency_ms/{feed}")
            if agg is None:
                agg = type(h)()
            agg.merge(h)
            emitted += m.counter(f"frames_emitted/{feed}").value
            viol += m.counter(f"slo_violations/{feed}").value
        if agg is None:
            return {"frames": 0, "p50_ms": 0.0, "p95_ms": 0.0,
                    "p99_ms": 0.0, "violations": 0, "attainment": 1.0}
        return {"frames": emitted, "p50_ms": agg.percentile(50),
                "p95_ms": agg.percentile(95), "p99_ms": agg.percentile(99),
                "violations": viol,
                "attainment": 1.0 - viol / emitted if emitted else 1.0}

    def table(self) -> str:
        """The per-feed SLO table (what ``examples/observe_serve.py``
        prints)."""
        head = (f"{'feed':<12} {'frames':>7} {'p50':>8} {'p95':>8} "
                f"{'p99':>8} {'stale p50':>10} {'stale p99':>10} "
                f"{'target':>7} {'viol':>5} {'attain':>7} "
                f"{'degr':>5} {'drop':>5} {'avail':>7}")
        lines = [head, "-" * len(head)]
        for r in self.rows():
            lines.append(
                f"{r['feed']:<12} {r['frames']:>7d} "
                f"{r['p50_ms']:>7.1f}ms {r['p95_ms']:>7.1f}ms "
                f"{r['p99_ms']:>7.1f}ms {r['stale_p50_ms']:>8.1f}ms "
                f"{r['stale_p99_ms']:>8.1f}ms {r['target_ms']:>6.0f}ms "
                f"{r['violations']:>5d} {r['attainment']:>6.1%} "
                f"{r['degraded']:>5d} {r['dropped']:>5d} "
                f"{r['availability']:>6.1%}")
        c = self.combined()
        lines.append(
            f"{'ALL':<12} {c['frames']:>7d} {c['p50_ms']:>7.1f}ms "
            f"{c['p95_ms']:>7.1f}ms {c['p99_ms']:>7.1f}ms "
            f"{'':>10} {'':>10} {'':>7} {c['violations']:>5d} "
            f"{c['attainment']:>6.1%}")
        return "\n".join(lines)
