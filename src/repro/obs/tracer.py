"""Frame-lifecycle tracer: monotonic ring-buffer span recording.

The serving stack is instrumented with *spans* (named intervals on a
track: ``prefix:skip`` on ``feed:tb0``, ``forward[big]`` on ``device``),
*instants* (point events: a gate revalidation) and *counter* samples (the
server's in-flight forward occupancy over time).  Recording is designed
for the hot path:

  * fixed capacity — events land in pre-allocated parallel arrays
    addressed by a monotonically increasing index modulo the capacity, so
    the buffer never grows and old events are overwritten, never moved;
  * no per-event containers — an event is five scalar stores (kind, name,
    category, track are interned strings; timestamps are int64 slots in a
    numpy array), not a dict or tuple allocation;
  * timestamps are ``time.perf_counter_ns()`` — monotonic, ns resolution.

``NullTracer`` is the default everywhere: every recording method is a
no-op ``pass`` and ``enabled`` is False, so instrumented code paths can
skip even the clock reads (``if tracer.enabled:``).  The contract —
enforced by ``tests/test_obs.py`` — is that serving with a ``NullTracer``
is bitwise identical to serving before the instrumentation existed, and
within noise of its wall clock.

Export is Chrome trace-event JSON (``export_chrome``), loadable in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``: tracks map to
named threads, spans to complete ("X") events, counters to "C" events —
the per-phase timeline evidence the latency work needs.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np


class NullTracer:
    """No-op tracer: the inert default.  Subclassed by ``Tracer`` so both
    present one API; every recording method here must stay a ``pass`` —
    the disabled serving path's overhead is exactly these empty calls."""

    enabled = False

    def now(self) -> int:
        return 0

    def span(self, name: str, cat: str, t0_ns: int,
             t1_ns: Optional[int] = None, track: str = "main",
             n: int = 0) -> None:
        pass

    def instant(self, name: str, cat: str, track: str = "main",
                n: int = 0) -> None:
        pass

    def counter(self, name: str, value: int,
                track: str = "counters") -> None:
        pass

    def events(self) -> List[Dict[str, Any]]:
        return []

    def reset(self) -> None:
        pass


#: process-wide inert default (stateless, safe to share)
NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Ring-buffer recording tracer.  See module docstring."""

    enabled = True

    def __init__(self, capacity: int = 65536):
        assert capacity >= 1
        self.capacity = capacity
        # parallel pre-allocated columns — one store per field per event
        self._kind: List[Optional[str]] = [None] * capacity
        self._name: List[Optional[str]] = [None] * capacity
        self._cat: List[Optional[str]] = [None] * capacity
        self._track: List[Optional[str]] = [None] * capacity
        self._t0 = np.zeros(capacity, np.int64)
        self._t1 = np.zeros(capacity, np.int64)
        self._n = np.zeros(capacity, np.int64)
        self._idx = 0                  # total events ever recorded

    # -- recording (hot path) -------------------------------------------
    def now(self) -> int:
        return time.perf_counter_ns()

    def _store(self, kind: str, name: str, cat: str, track: str,
               t0_ns: int, t1_ns: int, n: int) -> None:
        i = self._idx % self.capacity
        self._kind[i] = kind
        self._name[i] = name
        self._cat[i] = cat
        self._track[i] = track
        self._t0[i] = t0_ns
        self._t1[i] = t1_ns
        self._n[i] = n
        self._idx += 1

    def span(self, name: str, cat: str, t0_ns: int,
             t1_ns: Optional[int] = None, track: str = "main",
             n: int = 0) -> None:
        """Record a completed interval [t0_ns, t1_ns] (t1 defaults to
        now); ``n`` annotates the batch size the span covered."""
        if t1_ns is None:
            t1_ns = time.perf_counter_ns()
        self._store("X", name, cat, track, t0_ns, t1_ns, n)

    def instant(self, name: str, cat: str, track: str = "main",
                n: int = 0) -> None:
        t = time.perf_counter_ns()
        self._store("i", name, cat, track, t, t, n)

    def counter(self, name: str, value: int,
                track: str = "counters") -> None:
        t = time.perf_counter_ns()
        self._store("C", name, "counter", track, t, t, value)

    # -- inspection / export (cold path) --------------------------------
    @property
    def recorded(self) -> int:
        """Total events ever recorded (including overwritten ones)."""
        return self._idx

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wraparound."""
        return max(0, self._idx - self.capacity)

    def reset(self) -> None:
        self._idx = 0

    def events(self) -> List[Dict[str, Any]]:
        """Retained events in recording order (oldest surviving first)."""
        n = min(self._idx, self.capacity)
        start = self._idx % self.capacity if self._idx > self.capacity \
            else 0
        out = []
        for k in range(n):
            i = (start + k) % self.capacity
            out.append({"kind": self._kind[i], "name": self._name[i],
                        "cat": self._cat[i], "track": self._track[i],
                        "t0_ns": int(self._t0[i]), "t1_ns": int(self._t1[i]),
                        "n": int(self._n[i])})
        return out

    def export_chrome(self, path: str) -> int:
        """Write Chrome trace-event JSON loadable in Perfetto; returns the
        number of events exported.

        Tracks become named threads of one process (metadata "M" events);
        spans become complete "X" events (ts/dur in µs, relative to the
        oldest retained event), instants "i", counters "C"."""
        evs = self.events()
        t_base = min((e["t0_ns"] for e in evs), default=0)
        tids: Dict[str, int] = {}
        out: List[Dict[str, Any]] = []
        for e in evs:
            tid = tids.setdefault(e["track"], len(tids) + 1)
            ts = (e["t0_ns"] - t_base) / 1e3
            rec: Dict[str, Any] = {
                "name": e["name"], "cat": e["cat"], "ph": e["kind"],
                "ts": ts, "pid": 1, "tid": tid,
            }
            if e["kind"] == "X":
                rec["dur"] = (e["t1_ns"] - e["t0_ns"]) / 1e3
                rec["args"] = {"n": e["n"]}
            elif e["kind"] == "i":
                rec["s"] = "t"
                rec["args"] = {"n": e["n"]}
            else:                      # "C": sampled counter value
                rec["args"] = {"value": e["n"]}
            out.append(rec)
        meta = [{"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                 "args": {"name": track}} for track, tid in tids.items()]
        meta.append({"name": "process_name", "ph": "M", "pid": 1,
                     "args": {"name": "repro-serving"}})
        with open(path, "w") as f:
            json.dump({"traceEvents": meta + out,
                       "displayTimeUnit": "ms",
                       "otherData": {"dropped_events": self.dropped}}, f)
        if self.dropped:
            # a truncated trace looks complete in Perfetto — say so loudly
            # instead of burying the count in the otherData blob
            print(f"WARNING: trace {path} dropped {self.dropped} events "
                  f"(ring capacity {self.capacity}; oldest overwritten) — "
                  "raise Tracer(capacity=...) for a complete timeline",
                  file=sys.stderr)
        return len(out)
