"""Flight report: one markdown summary of a serving run's health.

``write_flight_report`` renders the run's observability surfaces — the
per-feed SLO table, the optimizer's per-decision audit table with drift
flags, the device-vs-observed forward gap, and headline metrics — into
a single markdown file (``reports/flight_report.md`` by convention).
``scripts/bench_gate.py`` appends its bench-delta section to the same
file, so after a full CI run one artifact answers "did this change make
serving worse, and did the planner's predictions hold?".

Every section is optional (pass None to skip): the report renders
whatever the caller measured, never demands surfaces a given run didn't
produce.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional


def _code_block(text: str) -> List[str]:
    return ["```", text, "```", ""]


def render_flight_report(title: str = "Serving flight report",
                         slo=None, audit=None, metrics=None,
                         flagged: Optional[List[str]] = None,
                         gap: Optional[Dict[str, Any]] = None,
                         notes: Optional[List[str]] = None) -> str:
    """Render the report body (see ``write_flight_report`` for args)."""
    lines: List[str] = [f"# {title}", ""]
    if notes:
        lines += [f"- {n}" for n in notes] + [""]

    if metrics is not None:
        fps = metrics.gauge("run/fps").value
        wall = metrics.gauge("run/wall_s").value
        forwards = metrics.counter("server/forwards").value
        frames = metrics.counter("server/frames").value
        if fps or wall or forwards:
            lines += ["## Headline", "",
                      f"- wall: {wall:.2f} s, throughput: "
                      f"{fps:.1f} query-frames/s",
                      f"- forwards: {forwards} ({frames} model frames)"]
            dropped = metrics.counter("tracer/dropped_events").value
            if dropped:
                lines.append(f"- **trace truncated**: {dropped} events "
                             "dropped by the tracer ring")
            lines.append("")

    if slo is not None:
        lines += ["## SLO attainment", ""]
        lines += _code_block(slo.table())

    if audit is not None:
        lines += ["## Optimizer audit (predicted vs measured)", ""]
        lines += _code_block(audit.table(metrics))
        if gap is None and metrics is not None:
            from repro.obs.audit import forward_gap
            gap = forward_gap(metrics)

    if gap is not None:
        lines += ["## Forward timing: device vs observed", "",
                  f"- observed (launch → polled completion): "
                  f"{gap['observed_ms']:.2f} ms mean over "
                  f"{gap['forwards']} forwards",
                  f"- device (launch → probed completion): "
                  f"{gap['device_ms']:.2f} ms mean over "
                  f"{gap['probes']} probes",
                  f"- gap: {gap['gap_ms']:.2f} ms "
                  f"({gap['gap_frac']:.0%} of the observed span is poll "
                  "latency, not device time)", ""]

    if flagged is not None:
        lines += ["## Cost-model drift flags", ""]
        if flagged:
            lines += [f"- `{k}`: realized cost drifted beyond tolerance; "
                      "catalog entry EMA-corrected" for k in flagged]
        else:
            lines.append("- none: every reconciled entry was within "
                         "tolerance")
        lines.append("")

    return "\n".join(lines)


def write_flight_report(path: str = "reports/flight_report.md",
                        **kw) -> str:
    """Render and write the flight report; returns the path.

    Keyword args (all optional): ``slo`` (an ``SLOTracker``), ``audit``
    (a ``PlanAudit``), ``metrics`` (the run's ``Metrics`` registry —
    enables the measured audit columns, headline numbers and the forward
    gap), ``flagged`` (drift-flagged catalog keys from ``reconcile``),
    ``gap`` (a ``forward_gap`` dict, derived from ``metrics`` when
    omitted), ``notes`` (free-form bullet lines), ``title``."""
    body = render_flight_report(**kw)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        f.write(body)
    return path
