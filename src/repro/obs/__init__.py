"""Observability: frame-lifecycle tracing, metrics, SLO accounting.

``Observability`` bundles the three surfaces the serving stack reports
into — a ``Tracer`` (ring-buffer span recording, Perfetto-exportable), a
``Metrics`` registry (counters / gauges / log-binned histograms with
p50/p95/p99 extraction) and an ``SLOTracker`` (per-feed frame latency,
staleness, violation budget) — behind one object threaded through
``OpContext.obs``.

The default everywhere is ``NULL_OBS``: ``enabled`` is False, the tracer
is the no-op ``NullTracer``, and every instrumented call site guards its
clock reads with ``if obs.enabled:`` — so un-observed serving pays only
empty attribute checks and stays bitwise identical to pre-instrumentation
behavior (enforced by ``tests/test_obs.py``).

Usage::

    obs = Observability()                       # tracing + metrics + SLO
    ctx = dataclasses.replace(ctx, obs=obs)
    MultiStreamRuntime(feeds, ctx).run(256)
    print(obs.slo.table())                      # per-feed p50/p95/p99
    obs.tracer.export_chrome("reports/trace.json")   # open in Perfetto

    obs = Observability(tracer=NULL_TRACER)     # metrics/SLO, no tracing

The canonical span phases a served frame's lifecycle passes through (the
``cat`` field of every span, one Perfetto track per feed plus a shared
``server``/``device`` pair):

    ingest -> prefix -> gate -> queue -> staging -> dispatch
           -> forward -> resume -> tail
"""
from __future__ import annotations

import time
from typing import Optional

from repro.obs.metrics import Counter, Gauge, Histogram, Metrics
from repro.obs.slo import SLOTracker
from repro.obs.tracer import NULL_TRACER, NullTracer, Tracer

#: the span categories instrumented across the serving stack, in
#: lifecycle order (export sanity checks assert against this list)
PHASES = ("ingest", "prefix", "gate", "queue", "staging", "dispatch",
          "forward", "resume", "tail")

#: the additional categories the fault-tolerance tier emits (instants,
#: not lifecycle spans): injected faults and retries, circuit-breaker
#: trips/probes/recoveries, degraded-mode serving — kept out of PHASES
#: so a fault-free trace still covers exactly the lifecycle categories
FAULT_PHASES = ("fault", "retry", "quarantine", "degraded")


class Observability:
    """Tracer + metrics + SLO tracker, one handle (see module docs)."""

    enabled = True

    def __init__(self, tracer: Optional[NullTracer] = None,
                 metrics: Optional[Metrics] = None,
                 capacity: int = 65536, slo_target_ms: float = 100.0):
        self.tracer = tracer if tracer is not None \
            else Tracer(capacity=capacity)
        self.metrics = metrics if metrics is not None else Metrics()
        self.slo = SLOTracker(self.metrics, target_ms=slo_target_ms)

    def now(self) -> int:
        """Monotonic ns stamp for lifecycle accounting (real even when
        the tracer is a ``NullTracer`` — latency histograms don't require
        span recording)."""
        return time.perf_counter_ns()


class _NullObservability(Observability):
    """The inert default: ``enabled`` False, no clock reads, no state.

    One process-wide instance (``NULL_OBS``) backs every un-observed
    context; its metrics registry exists (cold-path readers need not
    null-check) but instrumented hot paths skip it entirely."""

    enabled = False

    def __init__(self) -> None:
        super().__init__(tracer=NULL_TRACER)

    def now(self) -> int:
        return 0


NULL_OBS = _NullObservability()


def resolve_obs(*candidates) -> Observability:
    """First non-None observability among ``candidates``, else NULL_OBS —
    the one lookup rule every component uses (explicit arg outranks
    context, context outranks the inert default)."""
    for c in candidates:
        if c is not None:
            return c
    return NULL_OBS


# imported after the core surfaces exist: audit/report lazy-import the
# planner/scheduler layers (which import this package at module scope)
from repro.obs.audit import PlanAudit, forward_gap          # noqa: E402
from repro.obs.report import write_flight_report            # noqa: E402

__all__ = [
    "Counter", "Gauge", "Histogram", "Metrics", "NULL_OBS", "NULL_TRACER",
    "NullTracer", "Observability", "PHASES", "PlanAudit", "SLOTracker",
    "Tracer", "forward_gap", "resolve_obs", "write_flight_report",
]
