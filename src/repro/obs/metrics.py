"""Metrics registry: counters, gauges, log-binned histograms.

One ``Metrics`` instance is the serving stack's single accounting
surface: the extract server's stats dict, the semantic gate's
hit/miss/revalidation counters, runtime wall clocks, the optimizer's
per-phase walls and per-feed latency/staleness distributions all land
here (``ingest`` for existing dict-shaped counters, ``observe`` for
samples), so benchmarks and the SLO tracker read one registry instead of
scraping per-component dicts.

``Histogram`` is log-binned (geometric bins, ``bins_per_decade`` per
decade): recording is O(1) — one log, one increment into a fixed int64
array — and quantile extraction (p50/p95/p99) is exact to one bin's
relative width (``10**(1/bins_per_decade)``, ~3.7% at the default 64),
verified against a numpy percentile reference in ``tests/test_obs.py``.

``snapshot()``/``restore()`` round-trip the whole registry (the same
aligned-checkpoint idiom as ``Op.snapshot``): restore drops metrics
created after the snapshot and returns every surviving one to its
recorded state.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Any, Dict, List, Optional

import numpy as np


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v: int) -> None:
        self.value = v


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Log-binned histogram over positive values (unit-agnostic).

    Bins are geometric: bin k covers ``lo * g**k .. lo * g**(k+1)`` with
    ``g = 10**(1/bins_per_decade)``; values below ``lo`` clamp into bin
    0, values above the last edge into the last bin.  Exact count, sum,
    min and max ride alongside, so ``mean()`` is exact and percentiles
    clamp into the observed range."""

    __slots__ = ("lo", "growth", "nbins", "counts", "count", "total",
                 "vmin", "vmax", "_log_g", "_log_lo")

    def __init__(self, bins_per_decade: int = 64, lo: float = 1e-3,
                 decades: int = 15):
        self.lo = lo
        self.growth = 10.0 ** (1.0 / bins_per_decade)
        self.nbins = bins_per_decade * decades
        self.counts = np.zeros(self.nbins, np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._log_g = math.log(self.growth)
        self._log_lo = math.log(lo)

    def _bin(self, v: float) -> int:
        if v <= self.lo:
            return 0
        b = int((math.log(v) - self._log_lo) / self._log_g)
        return b if b < self.nbins else self.nbins - 1

    def record(self, v: float, n: int = 1) -> None:
        """Record ``n`` observations of value ``v`` (``n>1``: a batch of
        frames sharing one measured latency)."""
        self.counts[self._bin(v)] += n
        self.count += n
        self.total += v * n
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Value at the p-th percentile (geometric bin midpoint, clamped
        to the observed [min, max]); 0.0 when empty."""
        if not self.count:
            return 0.0
        target = (p / 100.0) * self.count
        cum = 0
        for b in range(self.nbins):
            c = int(self.counts[b])
            if not c:
                continue
            cum += c
            if cum >= target:
                mid = self.lo * self.growth ** (b + 0.5)
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into this histogram, bin-exactly: both must share
        the binning (same ``lo``/``growth``/``nbins``), so summed counts
        are identical to having recorded the interleaved value stream into
        one histogram (the property ``tests/test_obs.py`` asserts with
        hypothesis).  Returns self for chaining."""
        assert (self.lo, self.growth, self.nbins) == \
            (other.lo, other.growth, other.nbins), \
            "merging histograms with different binning"
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    # -- checkpoint state ------------------------------------------------
    def state(self) -> Dict[str, Any]:
        return {"counts": self.counts.copy(), "count": self.count,
                "total": self.total, "vmin": self.vmin, "vmax": self.vmax}

    def load(self, st: Dict[str, Any]) -> None:
        self.counts[:] = st["counts"]
        self.count = st["count"]
        self.total = st["total"]
        self.vmin = st["vmin"]
        self.vmax = st["vmax"]


class Metrics:
    """Create-on-first-use registry of counters, gauges and histograms."""

    def __init__(self) -> None:
        self._counters: "OrderedDict[str, Counter]" = OrderedDict()
        self._gauges: "OrderedDict[str, Gauge]" = OrderedDict()
        self._hists: "OrderedDict[str, Histogram]" = OrderedDict()

    # -- access ----------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, **kw) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(**kw)
        return h

    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float, n: int = 1) -> None:
        self.histogram(name).record(v, n)

    def drop(self, prefix: str) -> None:
        """Remove every metric whose name is ``prefix`` or starts with
        ``prefix/`` — how warmup-polluted histograms (compile time would
        swamp a measured p99) are cleared before the measured run."""
        for d in (self._counters, self._gauges, self._hists):
            for k in [k for k in d
                      if k == prefix or k.startswith(prefix + "/")]:
                del d[k]

    def ingest(self, prefix: str, stats: Dict[str, Any]) -> None:
        """Adopt an existing dict-shaped counter surface (the extract
        server's ``stats``, the gate's counters) into the registry as
        ``prefix/key`` counters — set, not incremented, so repeated
        ingestion of a cumulative dict stays idempotent."""
        for k, v in stats.items():
            if isinstance(v, (int, np.integer)):
                self.counter(f"{prefix}/{k}").set(int(v))
            elif isinstance(v, float):
                self.gauge(f"{prefix}/{k}").set(v)

    # -- reporting -------------------------------------------------------
    def to_rows(self) -> List[Dict[str, Any]]:
        """Structured rows for the benchmark driver's ``--json``."""
        rows: List[Dict[str, Any]] = []
        for name, c in self._counters.items():
            rows.append({"kind": "counter", "name": name, "value": c.value})
        for name, g in self._gauges.items():
            rows.append({"kind": "gauge", "name": name, "value": g.value})
        for name, h in self._hists.items():
            rows.append({"kind": "histogram", "name": name,
                         "count": h.count, "mean": h.mean(),
                         "p50": h.percentile(50), "p95": h.percentile(95),
                         "p99": h.percentile(99),
                         "min": h.vmin if h.count else 0.0,
                         "max": h.vmax if h.count else 0.0})
        return rows

    def describe(self) -> str:
        lines = []
        for r in self.to_rows():
            if r["kind"] == "histogram":
                lines.append(
                    f"{r['name']:<44s} n={r['count']:<7d} "
                    f"mean={r['mean']:.3f} p50={r['p50']:.3f} "
                    f"p95={r['p95']:.3f} p99={r['p99']:.3f}")
            else:
                lines.append(f"{r['name']:<44s} {r['value']}")
        return "\n".join(lines)

    # -- checkpoint ------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "hists": {k: h.state() for k, h in self._hists.items()},
        }

    def restore(self, st: Dict[str, Any]) -> None:
        """Return the registry to exactly the snapshot's state: metrics
        created after the snapshot are dropped, surviving ones reloaded."""
        self._counters = OrderedDict(
            (k, Counter()) for k in st["counters"])
        for k, v in st["counters"].items():
            self._counters[k].value = v
        self._gauges = OrderedDict((k, Gauge()) for k in st["gauges"])
        for k, v in st["gauges"].items():
            self._gauges[k].value = v
        hists: "OrderedDict[str, Histogram]" = OrderedDict()
        for k, hst in st["hists"].items():
            old = self._hists.get(k)
            h = old if old is not None else Histogram()
            h.load(hst)
            hists[k] = h
        self._hists = hists

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()
