"""Optimizer audit loop: predicted-vs-measured cost reconciliation.

The planner stack (``SharingTreePlanner``, ``FleetOptimizer``,
``PhysicalOptimizer``) decides share-vs-solo and fuse-vs-unfuse from a
``CostCatalog`` calibrated offline — and nothing in the serving path ever
checked whether the predicted savings were *realized*.  ``PlanAudit``
closes that loop:

  * it holds the planner's recorded decisions — per-feed sharing forests
    (each ``SharingGroup`` carries the predicted shared / independent
    per-frame cost that justified it) and, when available, the per-query
    ``OptimizationReport``'s fused-prefix verdicts;
  * ``verify_predictions()`` re-derives every group's predicted cost
    through the same ``chain_cost_us`` model the planner used — the
    audit is only trustworthy if it prices plans *identically* to the
    planner (``tests/test_audit.py`` asserts exact reproduction);
  * ``measured_costs(metrics)`` joins the serving run's measured
    surfaces — ``op_wall_us/<key>`` + ``op_frames/<key>`` +
    ``op_rows_out/<key>`` from the prefix executor and the
    device-probed ``forward_device_ms/<variant>`` histograms from the
    extract server — into catalog-keyed marginal-cost/pass-rate
    measurements;
  * ``rows(metrics)`` prices each decision both ways (predicted lookups
    vs measured lookups) into a per-decision table: predicted saving,
    realized saving, drift ratio, and a flag when realized cost exceeds
    prediction beyond ``tolerance``;
  * ``reconcile(metrics, catalog)`` EMA-feeds the measurements back into
    the catalog (``CostCatalog.reconcile``) the way gate hit rates
    already flow, so the next planning pass self-corrects.

Everything ``repro.*`` outside ``repro.obs`` is imported lazily: this
module loads as part of the ``repro.obs`` package, which the scheduler
and core layers import at module scope — a top-level import back into
them would cycle.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional


def _hist_totals(metrics) -> Dict[str, Dict[str, float]]:
    """Histogram name → {sum, count} and counter name → value, read off
    the registry's reporting surface (no private attribute reach-ins)."""
    hists: Dict[str, Dict[str, float]] = {}
    counters: Dict[str, float] = {}
    for r in metrics.to_rows():
        if r["kind"] == "histogram":
            hists[r["name"]] = {"sum": r["mean"] * r["count"],
                                "count": r["count"]}
        elif r["kind"] == "counter":
            counters[r["name"]] = r["value"]
    return {"hists": hists, "counters": counters}


def forward_gap(metrics) -> Optional[Dict[str, float]]:
    """Device-vs-observed forward gap: how much of the recorded
    ``forward_ms`` (launch → *observed* completion, poll-quantized) is
    actually poll latency rather than device time, per the sampled
    ``forward_device_ms`` probes.  None until both surfaces have data."""
    obs_h = metrics.histogram("forward_ms")
    dev_h = metrics.histogram("forward_device_ms")
    if not obs_h.count or not dev_h.count:
        return None
    observed = obs_h.mean()
    device = dev_h.mean()
    return {
        "observed_ms": observed,
        "device_ms": device,
        "gap_ms": observed - device,
        "gap_frac": (observed - device) / observed if observed else 0.0,
        "probes": dev_h.count,
        "forwards": obs_h.count,
    }


class PlanAudit:
    """Join planner decisions against serving-time measurements.

    ``forests`` maps feed name → ``SharingForest`` (a single forest is
    also accepted); ``reports`` optionally maps query id →
    ``OptimizationReport`` for fused-prefix decision rows.  The pricing
    parameters (``catalog``, ``micro_batch``, ``gate_hit_rate``) must be
    the ones the planner decided with — ``from_runtime`` /
    ``from_fleet`` capture them for you."""

    def __init__(self, forests: Any, reports: Optional[Dict] = None,
                 catalog=None, micro_batch: int = 16,
                 gate_hit_rate: float = 0.0, tolerance: float = 0.5):
        if hasattr(forests, "streams"):       # a bare SharingForest
            forests = {"": forests}
        self.forests: Dict[str, Any] = dict(forests)
        self.reports = dict(reports) if reports else {}
        self.catalog = catalog
        self.micro_batch = micro_batch
        self.gate_hit_rate = gate_hit_rate
        self.tolerance = tolerance

    # -- constructors ---------------------------------------------------
    @classmethod
    def from_runtime(cls, runtime, tolerance: float = 0.5) -> "PlanAudit":
        """Audit a live ``MultiStreamRuntime``: its forests, priced with
        its planner's catalog / micro-batch / gate-hit-rate."""
        planner = runtime.planner
        return cls(runtime.forests,
                   catalog=getattr(planner, "catalog", None),
                   micro_batch=getattr(planner, "micro_batch", 16),
                   gate_hit_rate=getattr(planner, "gate_hit_rate", 0.0),
                   tolerance=tolerance)

    @classmethod
    def from_fleet(cls, fleet, tolerance: float = 0.5) -> "PlanAudit":
        """Audit a ``FleetResult``: its per-feed forests plus the solo
        optimization reports (fused-prefix decisions ride along)."""
        return cls(fleet.forests, reports=fleet.reports,
                   catalog=fleet.catalog, tolerance=tolerance)

    # -- predicted side -------------------------------------------------
    def _predict_group(self, group) -> Dict[str, float]:
        """Re-price one sharing group exactly as ``SharingTreePlanner.
        _group`` did — same cost function, same parameters."""
        from repro.scheduler.sharing_tree import chain_cost_us, chain_reach
        exe = group.execution
        h = self.gate_hit_rate
        p_reach = chain_reach(exe.prefix, self.catalog)
        shared = chain_cost_us(exe.prefix, self.catalog, self.micro_batch,
                               gate_hit_rate=h) \
            + sum(chain_cost_us(tail, self.catalog, self.micro_batch,
                                reach=p_reach, gate_hit_rate=h)
                  for tail in exe.tails)
        # the independent side was priced over the original *member
        # plans*; a factored group's member chains are prefix + tail,
        # which the factorization preserves op-for-op
        indep = sum(chain_cost_us(list(exe.prefix) + list(tail),
                                  self.catalog, self.micro_batch,
                                  gate_hit_rate=h)
                    for tail in exe.tails)
        return {"shared": shared, "indep": indep}

    def verify_predictions(self) -> float:
        """Max relative error between each group's stored predicted cost
        and this audit's re-derivation — ~0 when the audit prices plans
        identically to the planner (the trust precondition; nonzero
        means the catalog mutated since planning and the stored
        prediction is stale)."""
        worst = 0.0
        for forest in self.forests.values():
            for g in forest.groups():
                p = self._predict_group(g)
                for stored, derived in ((g.shared_cost_us, p["shared"]),
                                        (g.indep_cost_us, p["indep"])):
                    if stored:
                        worst = max(worst,
                                    abs(stored - derived) / abs(stored))
                    elif derived:
                        worst = max(worst, 1.0)
        return worst

    # -- measured side --------------------------------------------------
    def measured_costs(self, metrics) -> Dict[str, Dict[str, float]]:
        """Catalog-keyed serving measurements, ready for
        ``CostCatalog.reconcile``: marginal µs/frame (and survivor
        fraction where countable) per op key.

        Prefix ops: ``op_wall_us/<key>`` per-invocation walls over
        ``op_frames/<key>`` input frames (→ marginal), with
        ``op_rows_out/<key>`` survivors (→ pass rate).  Extracts: the
        device-probed ``forward_device_ms/<variant>`` over
        ``forward_device_frames/<variant>`` — device-accurate, not the
        poll-quantized observed span."""
        t = _hist_totals(metrics)
        hists, counters = t["hists"], t["counters"]
        measured: Dict[str, Dict[str, float]] = {}
        for name, h in hists.items():
            if name.startswith("op_wall_us/"):
                key = name[len("op_wall_us/"):]
                frames = counters.get(f"op_frames/{key}", 0)
                if frames <= 0 or h["count"] <= 0:
                    continue
                m: Dict[str, float] = {"us": h["sum"] / frames,
                                       "frames": frames}
                rows_out = counters.get(f"op_rows_out/{key}")
                if rows_out is not None:
                    m["pass_rate"] = min(1.0, rows_out / frames)
                measured[key] = m
            elif name.startswith("forward_device_ms/"):
                variant = name[len("forward_device_ms/"):]
                frames = counters.get(
                    f"forward_device_frames/{variant}", 0)
                if frames <= 0 or h["count"] <= 0:
                    continue
                measured[f"mllm[{variant}]"] = {
                    "us": h["sum"] * 1e3 / frames, "frames": frames}
        return measured

    def _measured_chain(self, ops, measured: Dict[str, Dict[str, float]],
                        reach: float = 1.0) -> float:
        """``chain_cost_us`` with measured marginals/pass-rates patched
        in wherever the run produced them (predicted values fill the
        gaps, so a partially-measured chain still prices end to end)."""
        from repro.core.costs import op_cost_key
        from repro.scheduler.sharing_tree import (
            op_cost_us,
            op_overhead_us,
            op_pass_rate,
        )
        from repro.streaming.operators import MLLMExtractOp
        discount = 1.0 - min(max(self.gate_hit_rate, 0.0), 1.0)
        total = 0.0
        for op in ops:
            m = measured.get(op_cost_key(op))
            us = m["us"] if m is not None else op_cost_us(op, self.catalog)
            if discount < 1.0 and isinstance(op, MLLMExtractOp) \
                    and m is None:
                # measured extract cost already reflects gating (cached
                # frames never reached the device) — only the predicted
                # fallback still needs the discount
                us *= discount
            total += reach * us
            over = op_overhead_us(op, self.catalog)
            if over > 0.0:
                mb = reach * self.micro_batch
                total += over * min(1.0, mb) / self.micro_batch
            pr = m.get("pass_rate") if m is not None else None
            reach *= pr if pr is not None else op_pass_rate(
                op, self.catalog)
        return total

    # -- the per-decision table -----------------------------------------
    def rows(self, metrics=None) -> List[Dict[str, Any]]:
        """One row per planner decision.  Sharing rows always; with
        ``metrics`` the measured side and drift join in; fused-prefix
        rows when optimization reports were supplied."""
        from repro.scheduler.sharing_tree import chain_reach
        measured = self.measured_costs(metrics) \
            if metrics is not None else {}
        rows: List[Dict[str, Any]] = []
        for feed, forest in sorted(self.forests.items()):
            for g in forest.groups():
                exe = g.execution
                row: Dict[str, Any] = {
                    "kind": "share" if g.is_shared else "solo",
                    "feed": feed,
                    "decision": "+".join(exe.queries),
                    "n_queries": len(exe.queries),
                    "predicted_shared_us": g.shared_cost_us,
                    "predicted_indep_us": g.indep_cost_us,
                    "predicted_saving_us": g.saving_us,
                }
                if measured:
                    p_reach = chain_reach(exe.prefix, self.catalog)
                    m_shared = self._measured_chain(exe.prefix, measured) \
                        + sum(self._measured_chain(t, measured,
                                                   reach=p_reach)
                              for t in exe.tails)
                    m_indep = sum(
                        self._measured_chain(
                            list(exe.prefix) + list(t), measured)
                        for t in exe.tails)
                    drift = m_shared / g.shared_cost_us \
                        if g.shared_cost_us else 1.0
                    row.update({
                        "measured_shared_us": m_shared,
                        "measured_indep_us": m_indep,
                        "realized_saving_us": m_indep - m_shared,
                        "drift": drift,
                        "flagged": drift > 1.0 + self.tolerance,
                    })
                rows.append(row)
        rows.extend(self._fusion_rows(measured))
        return rows

    def _fusion_rows(self, measured: Dict[str, Dict[str, float]]
                     ) -> List[Dict[str, Any]]:
        fused_seen = set()
        rows: List[Dict[str, Any]] = []
        for qid, report in sorted(self.reports.items()):
            for phase in getattr(report, "phases", []):
                info = phase.get("fused_prefix") if isinstance(phase, dict) \
                    else None
                if not info or "fused_us" not in info:
                    continue
                seg = tuple(info.get("segment", ()))
                if seg in fused_seen:
                    continue          # one row per distinct fused segment
                fused_seen.add(seg)
                row = {
                    "kind": "fuse" if info["fused"] else "unfuse",
                    "feed": "",
                    "decision": "+".join(seg) or qid,
                    "n_queries": 1,
                    "predicted_shared_us": info["fused_us"],
                    "predicted_indep_us": info["unfused_us"],
                    "predicted_saving_us":
                        info["unfused_us"] - info["fused_us"],
                }
                m = measured.get("FusedPrefixOp")
                if m is not None and info["fused"] and \
                        info.get("fused_marginal_us") is not None:
                    n = info["batch"]
                    predicted = info.get("fused_overhead_us", 0.0) \
                        + info["fused_marginal_us"] * n
                    realized = m["us"] * n
                    drift = realized / predicted if predicted else 1.0
                    row.update({
                        "measured_shared_us": realized,
                        "drift": drift,
                        "flagged": drift > 1.0 + self.tolerance,
                    })
                rows.append(row)
        return rows

    # -- reconciliation --------------------------------------------------
    def reconcile(self, metrics, catalog=None) -> List[str]:
        """Feed the run's measurements back into the catalog (EMA, like
        gate hit rates); returns the drift-flagged keys."""
        catalog = catalog if catalog is not None else self.catalog
        if catalog is None or not hasattr(catalog, "reconcile"):
            return []
        measured = self.measured_costs(metrics)
        if not measured:
            return []
        return catalog.reconcile(measured, tolerance=self.tolerance)

    # -- rendering --------------------------------------------------------
    def table(self, metrics=None) -> str:
        """The per-decision audit table (what ``examples/
        observe_serve.py`` prints and the flight report embeds)."""
        rows = self.rows(metrics)
        head = (f"{'kind':<6} {'feed':<10} {'decision':<28} "
                f"{'pred shared':>12} {'pred indep':>11} {'pred save':>10} "
                f"{'real save':>10} {'drift':>6} {'flag':>4}")
        lines = [head, "-" * len(head)]
        for r in rows:
            dec = r["decision"]
            if len(dec) > 28:
                dec = dec[:25] + "..."
            real = r.get("realized_saving_us")
            lines.append(
                f"{r['kind']:<6} {r['feed']:<10} {dec:<28} "
                f"{r['predicted_shared_us']:>10.0f}µs "
                f"{r['predicted_indep_us']:>9.0f}µs "
                f"{r['predicted_saving_us']:>8.0f}µs "
                + (f"{real:>8.0f}µs " if real is not None
                   else f"{'—':>10} ")
                + f"{r.get('drift', 1.0):>5.2f}x "
                + ("FLAG" if r.get("flagged") else "  ok"))
        return "\n".join(lines)
