"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel subpackage has:
  kernel.py — ``pl.pallas_call`` with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (dispatches kernel on TPU, interpret-mode
              kernel or the reference on CPU)
  ref.py    — pure-jnp oracle used by the shape/dtype sweep tests

Kernels:
  flash_attention  — causal/local GQA attention with online softmax
                     (the MLLM operator's prefill hot spot)
  decode_attention — flash-decoding split-KV single-token attention
  int8_matmul      — per-channel-scaled int8×int8→bf16 (physical-opt quantization)
  ssd_scan         — Mamba2 SSD within-chunk compute
  fused_preprocess — crop+downscale+normalize(+greyscale) in one HBM pass
                     (the semantic-optimization data-reduction operators, fused)
  frame_diff       — per-region frame differencing (Skip operator's condition)
"""
