"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel subpackage has:
  kernel.py — ``pl.pallas_call`` with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (dispatches kernel on TPU, interpret-mode
              kernel or the reference on CPU)
  ref.py    — pure-jnp oracle used by the shape/dtype sweep tests

Kernels:
  flash_attention  — causal/local GQA attention with online softmax
                     (the MLLM operator's prefill hot spot)
  decode_attention — flash-decoding split-KV single-token attention
  int8_matmul      — per-channel-scaled int8×int8→bf16 (physical-opt quantization)
  ssd_scan         — Mamba2 SSD within-chunk compute
  fused_preprocess — crop+downscale+normalize(+greyscale) in one HBM pass
                     (the semantic-optimization data-reduction operators, fused)
  frame_diff       — per-region frame differencing (Skip operator's condition)
  fused_prefix     — a plan's whole surviving-frame prefix in one pass:
                     frame diff + cheap color fractions + crop/downscale/
                     normalize(+greyscale) + semantic-gate signature pooling
                     (``streaming.fused.FusedPrefixOp`` adds the TinyDet
                     forward inside the same jit — one dispatch per
                     micro-batch for the whole pre-extract chain)

Dispatch rules (every ops.py wrapper follows them):
  * TPU backend      — the Pallas kernel, compiled (``_use_pallas()``).
  * CPU/GPU backend  — the pure-jnp reference by default: it lowers to a
    single fused XLA program under the wrapper's ``jax.jit``, so the
    "one device pass" contract holds on every backend.
  * ``interpret=True`` — the Pallas kernel in interpret mode on any
    backend; the sweep tests use this to pin kernel math to the oracle
    without TPU hardware.
"""
