"""Fused surviving-frame prefix Pallas kernel.

Extends ``fused_preprocess`` with the rest of the streaming prefix: one
program per frame reads the raw (C, H, W) uint8 frame (plus its
predecessor when a diff stage is present) from HBM **once** and emits
every per-frame statistic the chain needs — the (RY, RX) frame-diff
activity grid, one near-color pixel fraction per cheap filter, the
cropped/downscaled/normalized frame, and the semantic-gate signature
pooling — as separate outputs of a single ``pl.pallas_call``.  The
embedding projection (a tiny (B, D) @ (D, 16) matmul) runs outside the
kernel on the same device, inside the same jit.

Grid: (B,).  VMEM per program: the raw frame pair as f32 plus the
reduced intermediates — ≤ ~1 MiB for the 3×128×256 streaming shape, in
budget.  W = 256 keeps the lane dimension aligned.

Stage math mirrors ``ref.fused_prefix_ref`` expression for expression
(which in turn inlines the unfused operators' jitted bodies); the sweep
test ``tests/test_kernels.py::test_fused_prefix_sweep`` pins
interpret-mode output to the oracle.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def out_frame_shape(spec, shape: Tuple[int, int, int]
                    ) -> Tuple[int, int, int]:
    """(C, H, W) after the spec's transform stages."""
    c, h, w = shape
    for stage in spec:
        if stage[0] == "crop":
            h, w = stage[1][2], stage[1][3]
        elif stage[0] == "preprocess":
            _, crop, factor, grey = stage
            h, w = crop[2] // factor, crop[3] // factor
            # grey output is host-re-expanded to 3 channels; c unchanged
    return c, h, w


def _prefix_kernel(*refs, spec, sig_d: int):
    """One frame: walk the stages, writing each statistic's output ref."""
    it = iter(refs)
    x_ref = next(it)
    prev_ref = next(it) if any(s[0] == "diff" for s in spec) else None
    d_ref = next(it) if any(s[0] == "diff" for s in spec) else None
    ncolor = sum(1 for s in spec if s[0] == "color")
    frac_ref = next(it) if ncolor else None
    o_ref = next(it)
    feat_ref = next(it) if any(s[0] == "signature" for s in spec) else None

    cur = x_ref[0]                                    # (C, H, W)
    ci = 0
    for stage in spec:
        kind = stage[0]
        if kind == "diff":
            ry, rx = stage[1]
            c, h, w = cur.shape
            a = cur.astype(jnp.float32)
            b = prev_ref[0].astype(jnp.float32)
            dd = jnp.abs(a - b) / 255.0
            dd = dd.reshape(c, ry, h // ry, rx, w // rx)
            d_ref[0] = dd.mean(axis=(0, 2, 4))
        elif kind == "color":
            roi = stage[2]
            x = cur
            if roi is not None:
                y0, x0, h, w = roi
                x = x[:, y0:y0 + h, x0:x0 + w]
            x = x.astype(jnp.float32)
            norm = x.max() <= 8.0
            x = jnp.where(norm, (x * 0.25 + 0.5) * 255.0, x)
            # per-channel scalar arithmetic: Pallas kernels cannot
            # capture array constants, so the target color stays Python
            # floats (same trick as fused_preprocess's mean/std)
            dist = jnp.sqrt(sum((x[k] - float(stage[1][k])) ** 2
                                for k in range(x.shape[0])))
            frac_ref[0, ci] = (dist < 70.0).astype(jnp.float32).mean()
            ci += 1
        elif kind == "crop":
            y0, x0, h, w = stage[1]
            cur = cur[:, y0:y0 + h, x0:x0 + w]
        elif kind == "preprocess":
            _, crop, factor, grey = stage
            y0, x0, ch, cw = crop
            c = cur.shape[0]
            x = cur[:, y0:y0 + ch, x0:x0 + cw].astype(jnp.float32) / 255.0
            x = x.reshape(c, ch // factor, factor,
                          cw // factor, factor).mean(axis=(2, 4))
            chans = [(x[k] - 0.5) / 0.25 for k in range(c)]
            if grey:
                lum = (0.299, 0.587, 0.114)
                g = chans[0] * lum[0]
                for k in range(1, c):
                    g = g + chans[k] * lum[k]
                chans = [g] * c                       # host-repeat inlined
            cur = jnp.stack(chans, axis=0)
        elif kind == "signature":
            gy, gx = stage[1]
            c, h, w = cur.shape
            x = cur.astype(jnp.float32)
            raw = x.max() > 8.0
            x = jnp.where(raw, (x / 255.0 - 0.5) / 0.25, x)
            p = x.reshape(c, gy, h // gy, gx, w // gx)
            feat_ref[0] = p.mean(axis=(2, 4)).reshape(sig_d)
    o_ref[0] = cur.astype(o_ref.dtype)


def fused_prefix_kernel(frames: jax.Array, prevs=None, proj=None, *,
                        spec, interpret: bool = False):
    """frames (B, C, H, W); returns (d, fracs, x, feats, emb) like the
    oracle (absent stages -> None / empty tuple)."""
    b, c, h, w = frames.shape
    has_diff = any(s[0] == "diff" for s in spec)
    has_sig = any(s[0] == "signature" for s in spec)
    ncolor = sum(1 for s in spec if s[0] == "color")
    oc, oh, ow = out_frame_shape(spec, (c, h, w))

    gy = gx = sig_d = 0
    if has_sig:
        gy, gx = next(s[1] for s in spec if s[0] == "signature")
        sig_d = oc * gy * gx

    frame_spec = pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))
    in_specs = [frame_spec] + ([frame_spec] if has_diff else [])
    out_specs, out_shape = [], []
    if has_diff:
        ry, rx = next(s[1] for s in spec if s[0] == "diff")
        out_specs.append(pl.BlockSpec((1, ry, rx), lambda i: (i, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, ry, rx), jnp.float32))
    if ncolor:
        out_specs.append(pl.BlockSpec((1, ncolor), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, ncolor), jnp.float32))
    out_dtype = jnp.float32 if any(s[0] == "preprocess" for s in spec) \
        else frames.dtype
    out_specs.append(pl.BlockSpec((1, oc, oh, ow),
                                  lambda i: (i, 0, 0, 0)))
    out_shape.append(jax.ShapeDtypeStruct((b, oc, oh, ow), out_dtype))
    if has_sig:
        out_specs.append(pl.BlockSpec((1, sig_d), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, sig_d), jnp.float32))

    args = (frames, prevs) if has_diff else (frames,)
    outs = pl.pallas_call(
        functools.partial(_prefix_kernel, spec=spec, sig_d=sig_d),
        grid=(b,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    outs = list(outs) if isinstance(outs, (tuple, list)) else [outs]

    d = outs.pop(0) if has_diff else None
    fracs = tuple(outs.pop(0).T) if ncolor else ()
    x = outs.pop(0)
    feats = emb = None
    if has_sig:
        from repro.kernels.fused_prefix.ref import project_rowwise

        feats = outs.pop(0)
        emb = project_rowwise(feats, proj)
    return d, fracs, x, feats, emb
