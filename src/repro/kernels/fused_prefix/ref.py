"""Pure-jnp oracle for the fused surviving-frame prefix chain.

One traced function evaluates every *pixel* stage of a plan's prefix —
frame-diff activity, cheap color fractions, crop, fused preprocess (and
its grey re-expansion), and the semantic-gate signature pooling — in plan
order on the full micro-batch.  Filters never transform frames, so their
per-row statistics computed here on all rows equal the values the unfused
ops compute on their compacted survivor batches (the per-row determinism
contract the serving tier already relies on for coalesced-vs-solo
equality); transforms apply to every row exactly as the unfused chain
applies them to survivors.

The stage expressions are *inlined copies* of the unfused operators'
math (``frame_diff_ref``, ``CheapColorFilterOp.open``'s jitted body,
``fused_preprocess_ref``, ``TemporalSignature._fn``) — any drift breaks
the bitwise-identity contract ``tests/test_fused_prefix.py`` enforces.

``spec`` is a static tuple of stage tuples, in plan order:

  ("diff", (ry, rx))                      at most one, first if present
  ("color", (r, g, b), roi_or_None)       per CheapColorFilterOp
  ("crop", (y0, x0, h, w))                per CropOp
  ("preprocess", (y0, x0, h, w), f, grey) per FusedPreprocessOp
  ("signature", (gy, gx))                 at most one, last if present

Returns ``(d, fracs, x, feats, emb)``: the (B, ry, rx) diff grid (or
None), a tuple of per-color (B,) fractions, the transformed frames, and
the signature feats/emb (or None, None).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.frame_diff.ref import frame_diff_ref
from repro.kernels.fused_preprocess.ref import fused_preprocess_ref


def project_rowwise(feats, proj):
    """``feats @ proj`` as broadcast-multiply + axis reduce.

    A plain gemm's accumulation order varies with the batch dimension
    and with the surrounding fusion context (XLA picks different kernels
    for different M), so the same row can round differently between the
    gate's padded-survivor program and the fused prefix's full-micro-
    batch program.  The explicit reduce keeps each row's accumulation
    order fixed — per-row bitwise determinism is what lets the fused
    path hand its signatures to the gate.  ``TemporalSignature`` imports
    this so both programs share the one formulation."""
    return (feats[:, :, None] * proj[None]).sum(axis=1)


def _color_frac(x: jax.Array, rgb) -> jax.Array:
    """CheapColorFilterOp's jitted body, verbatim."""
    x = x.astype(jnp.float32)
    norm = x.reshape(x.shape[0], -1).max(axis=1) <= 8.0
    x = jnp.where(norm[:, None, None, None],
                  (x * 0.25 + 0.5) * 255.0, x)
    d = jnp.linalg.norm(
        x.transpose(0, 2, 3, 1) - jnp.asarray(rgb, jnp.float32), axis=-1)
    near = (d < 70.0).astype(jnp.float32)
    return near.mean(axis=(1, 2))


def _signature(x: jax.Array, gy: int, gx: int, proj: jax.Array):
    """``TemporalSignature._fn``'s jitted body, verbatim."""
    c, h, w = x.shape[1], x.shape[2], x.shape[3]
    d = c * gy * gx
    x = x.astype(jnp.float32)
    raw = x.reshape(x.shape[0], -1).max(axis=1) > 8.0
    x = jnp.where(raw[:, None, None, None], (x / 255.0 - 0.5) / 0.25, x)
    p = x.reshape(x.shape[0], c, gy, h // gy, gx, w // gx)
    feats = p.mean(axis=(3, 5)).reshape(x.shape[0], d)
    emb = project_rowwise(feats, proj)
    return feats, emb


def fused_prefix_ref(frames: jax.Array, prevs=None, proj=None, *, spec):
    cur = frames
    d = None
    fracs = []
    feats = emb = None
    for stage in spec:
        kind = stage[0]
        if kind == "diff":
            d = frame_diff_ref(frames, prevs, regions=stage[1])
        elif kind == "color":
            roi = stage[2]
            x = cur
            if roi is not None:
                y0, x0, h, w = roi
                x = x[:, :, y0:y0 + h, x0:x0 + w]
            fracs.append(_color_frac(x, stage[1]))
        elif kind == "crop":
            y0, x0, h, w = stage[1]
            cur = cur[:, :, y0:y0 + h, x0:x0 + w]
        elif kind == "preprocess":
            _, crop, factor, grey = stage
            ch, cw = cur.shape[2], cur.shape[3]
            cur = fused_preprocess_ref(cur, crop=crop, factor=factor,
                                       grey=grey)
            if grey:
                # FusedPreprocessOp re-expands grey to 3 channels on the
                # host; downstream stages must see the same frames
                cur = jnp.repeat(cur, 3, axis=1)
        elif kind == "signature":
            gy, gx = stage[1]
            feats, emb = _signature(cur, gy, gx, proj)
        else:  # pragma: no cover - spec is validated by FusedPrefixOp
            raise ValueError(f"unknown fused-prefix stage {kind!r}")
    return d, tuple(fracs), cur, feats, emb
