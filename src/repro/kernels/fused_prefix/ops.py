"""Public fused-prefix op: one device pass for a plan's whole prefix.

Dispatch follows the package convention: the Pallas kernel on TPU (or in
``interpret`` mode for tests), the pure-jnp oracle as the CPU path.  The
CPU oracle is itself a single XLA program when called under an outer
``jax.jit`` (nested jits inline), so both backends give the streaming
tier one compiled dispatch per micro-batch; ``FusedPrefixOp``
(``repro.streaming.fused``) is the wrapper that composes this with the
detector forward and owns the host-side mask/state logic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.fused_prefix.kernel import fused_prefix_kernel
from repro.kernels.fused_prefix.ref import fused_prefix_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def fused_prefix(frames: jax.Array, prevs=None, proj=None, *, spec,
                 interpret: bool = False):
    """frames (B, C, H, W), prevs same shape (diff stage only), proj
    (D, EMB_DIM) f32 (signature stage only); ``spec`` is the static
    stage tuple documented in ``ref.fused_prefix_ref``.  Returns
    ``(d, fracs, x, feats, emb)``."""
    if _use_pallas() or interpret:
        return fused_prefix_kernel(
            frames, prevs, proj, spec=spec,
            interpret=interpret or not _use_pallas())
    return fused_prefix_ref(frames, prevs, proj, spec=spec)
