"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        cap: Optional[float] = None,
                        window: Optional[int] = None) -> jax.Array:
    """q (B, Hk, G, S, D); k, v (B, Hk, S, D) -> (B, Hk, G, S, D)."""
    b, hk, g, s, d = q.shape
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
