"""Public flash-attention op: model layout in, kernel dispatch by backend."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "cap", "window",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, cap: Optional[float] = None,
                    window: Optional[int] = None,
                    interpret: bool = False) -> jax.Array:
    """Model layout: q (B, S, H, D); k, v (B, S, Hk, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.transpose(0, 2, 1, 3).reshape(b, hk, g, s, d)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    if _use_pallas() or interpret:
        out = flash_attention_kernel(qg, kk, vv, causal=causal, cap=cap,
                                     window=window,
                                     interpret=interpret or not _use_pallas())
    else:
        out = flash_attention_ref(qg, kk, vv, causal=causal, cap=cap,
                                  window=window)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
