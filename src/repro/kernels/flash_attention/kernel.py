"""Flash attention (forward) Pallas TPU kernel.

Layout: q (B, Hk, G, S, D) — GQA q heads folded per kv head so one program
computes all G query heads that share a kv head.  k/v (B, Hk, S, D).

Grid: (B, Hk, nq, nk) with nk innermost — TPU executes the trailing grid
dimension sequentially, so the online-softmax state (m, l, acc) lives in VMEM
scratch across the nk steps of one (b, h, iq) cell.  Causal/local blocks that
cannot contribute are predicated off with ``pl.when`` (Mosaic skips the
compute; the BlockSpec copy of a skipped block is the only residual cost).

VMEM per program (defaults bq=bk=256, D=128, G≤8):
  q: G·bq·D·2B ≤ 512KiB   k,v: 2·bk·D·2B = 128KiB
  acc: G·bq·D·4B ≤ 1MiB   m,l: 2·G·bq·128·4B ≤ 1MiB      — well under 16MiB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, cap: Optional[float], causal: bool,
                  window: Optional[int], bq: int, bk: int, nk: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk

    run = True
    if causal:
        run = k_start <= q_start + bq - 1          # block intersects causal cone
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _step():
        g, _, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
        q = q_ref[0, 0].reshape(g * bq, d)          # (G·Bq, D)
        k = k_ref[0, 0]                              # (Bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G·Bq, Bk)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 0) % bq
        # rows are G blocks of Bq query positions: row r -> position r % bq
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = kpos <= qpos
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, :1]                   # (rows, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # (rows, Bk)
        l_new = l_scr[...][:, :1] * alpha + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # (rows, D)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        g, d = q_ref.shape[2], q_ref.shape[4]
        l = l_scr[...][:, :1]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = out.reshape(g, bq, d).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           cap: Optional[float] = None,
                           window: Optional[int] = None,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q (B, Hk, G, S, D); k, v (B, Hk, S, D) -> (B, Hk, G, S, D)."""
    b, hk, g, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / np.sqrt(d)

    grid = (b, hk, nq, nk)
    rows = g * bq

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, cap=cap, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, bq, d), lambda b_, h_, iq, ik: (b_, h_, 0, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, bq, d),
                               lambda b_, h_, iq, ik: (b_, h_, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, s, d), q.dtype),
        scratch_shapes=_scratch(rows, d),
        interpret=interpret,
    )(q, k, v)


def _scratch(rows: int, d: int):
    """VMEM scratch for (m, l, acc) online-softmax state."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        vmem = pltpu.VMEM
    except Exception:  # pragma: no cover - CPU-only interpret fallback
        vmem = functools.partial(pl.MemoryRef, memory_space=pl.ANY)

    return [
        vmem((rows, LANES), jnp.float32),
        vmem((rows, LANES), jnp.float32),
        vmem((rows, d), jnp.float32),
    ]
