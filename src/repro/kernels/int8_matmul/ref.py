"""Pure-jnp oracle for int8 matmul + quantization helpers."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_rowwise(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization. x (M,K) -> (q (M,K) i8, s (M,1))."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_colwise(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-column int8 quantization. w (K,N) -> (q i8, s (1,N))."""
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_matmul_ref(x: jax.Array, w: jax.Array, sx: jax.Array,
                    sw: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    return (acc.astype(jnp.float32) * sx * sw).astype(out_dtype)
