"""Public int8 matmul op with quantize-on-the-fly convenience wrapper."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.kernel import int8_matmul_kernel
from repro.kernels.int8_matmul.ref import (
    int8_matmul_ref,
    quantize_colwise,
    quantize_rowwise,
)


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_matmul(x_q: jax.Array, w_q: jax.Array, sx: jax.Array, sw: jax.Array,
                interpret: bool = False) -> jax.Array:
    if _use_pallas() or interpret:
        return int8_matmul_kernel(x_q, w_q, sx, sw,
                                  interpret=interpret or not _use_pallas())
    return int8_matmul_ref(x_q, w_q, sx, sw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_int8_dynamic(x: jax.Array, w_q: jax.Array, sw: jax.Array,
                        interpret: bool = False) -> jax.Array:
    """Dynamic activation quantization against pre-quantized weights."""
    x_q, sx = quantize_rowwise(x)
    return int8_matmul(x_q, w_q, sx, sw, interpret=interpret)
