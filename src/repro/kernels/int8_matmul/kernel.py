"""Int8×int8→int32 matmul with per-channel scales (physical-opt quantization).

Grid (M/bm, N/bn, K/bk), K innermost: int32 accumulation lives in VMEM
scratch across K steps; scales applied once at the final step.  MXU-friendly
tile defaults (bm=bn=256, bk=512 int8 => 128KiB per operand panel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int8_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(ik == nk - 1)
    def _finish():
        sx = sx_ref[...]                              # (bm, 1) f32
        sw = sw_ref[...]                              # (1, bn) f32
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sx * sw).astype(
            o_ref.dtype)


def int8_matmul_kernel(x: jax.Array, w: jax.Array, sx: jax.Array,
                       sw: jax.Array, *, bm: int = 256, bn: int = 256,
                       bk: int = 512, out_dtype=jnp.float32,
                       interpret: bool = False) -> jax.Array:
    """x (M,K) int8, w (K,N) int8, sx (M,1) f32, sw (1,N) f32 -> (M,N)."""
    m, k = x.shape
    n = w.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_int8_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_acc(bm, bn)],
        interpret=interpret,
    )(x, w, sx, sw)


def _acc(bm: int, bn: int):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM((bm, bn), jnp.int32)
    except Exception:  # pragma: no cover
        return jax.ShapeDtypeStruct((bm, bn), jnp.int32)
