"""Public SSD op: full chunked SSD using the kernel for within-chunk terms."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
        cmat: jax.Array, d_skip: jax.Array, *, chunk: int = 256,
        interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full SSD: kernel within-chunk + XLA inter-chunk recurrence.

    x (B,L,H,P); dt (B,L,H) fp32 (softplus'd); a (H,) fp32 (negative);
    bmat/cmat (B,L,G,N); d_skip (H,).
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    nc = l // chunk
    q = chunk

    da = dt * a                                            # (B,L,H)
    cs = jnp.cumsum(da.reshape(b, nc, q, h), axis=2)       # (B,NC,Q,H)
    total = cs[:, :, -1, :]                                # (B,NC,H)

    # kernel-layout reshapes
    xk = x.reshape(b, nc, q, h, p).transpose(0, 1, 3, 2, 4).reshape(
        b * nc, h, q, p)
    bk = bmat.reshape(b, nc, q, g, n).transpose(0, 1, 3, 2, 4).reshape(
        b * nc, g, q, n)
    ck = cmat.reshape(b, nc, q, g, n).transpose(0, 1, 3, 2, 4).reshape(
        b * nc, g, q, n)
    csk = cs.transpose(0, 1, 3, 2).reshape(b * nc, h, 1, q)
    dtk = dt.reshape(b, nc, q, h).transpose(0, 1, 3, 2).reshape(
        b * nc, h, 1, q)

    if _use_pallas() or interpret:
        y_diag, s_local = ssd_scan_kernel(
            xk, bk, ck, csk, dtk, n_groups=g,
            interpret=interpret or not _use_pallas())
    else:
        y_diag, s_local = ssd_scan_ref(xk, bk, ck, csk, dtk, n_groups=g)

    y_diag = y_diag.reshape(b, nc, h, q, p)
    s_local = s_local.reshape(b, nc, h, n, p)

    # ---- inter-chunk recurrence (XLA scan over nc) ----
    def scan_fn(s_prev, inp):
        tot_c, s_loc = inp
        s_out = jnp.exp(tot_c)[:, :, None, None] * s_prev + s_loc
        return s_out, s_prev

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    s_final, s_ins = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(s_local, 1, 0)))
    s_in = jnp.moveaxis(s_ins, 0, 1)                        # (B,NC,H,N,P)

    # ---- cross-chunk term ----
    rep = h // g
    ch_heads = jnp.repeat(cmat.reshape(b, nc, q, g, n), rep, axis=3)
    c_decay = ch_heads.astype(jnp.float32) * jnp.exp(cs)[..., None]
    y_off = jnp.einsum("bcqhn,bchnp->bchqp", c_decay, s_in)

    y = y_diag + y_off
    y = y.transpose(0, 1, 3, 2, 4).reshape(b, l, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x.astype(
        jnp.float32)
    final_state = jnp.swapaxes(s_final, -1, -2)             # (B,H,P,N)
    return y.astype(x.dtype), final_state.astype(x.dtype)
