"""Pure-jnp oracle for the within-chunk SSD kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x: jax.Array, bmat: jax.Array, cmat: jax.Array,
                 cs: jax.Array, dt: jax.Array, *, n_groups: int):
    """Same contract as ssd_scan_kernel.

    x (BN,H,Q,P); bmat/cmat (BN,G,Q,N); cs/dt (BN,H,1,Q).
    Returns (y_diag (BN,H,Q,P) f32, s_local (BN,H,N,P) f32).
    """
    bn, h, q, p = x.shape
    g = bmat.shape[1]
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=1).astype(jnp.float32)  # (BN,H,Q,N)
    ch = jnp.repeat(cmat, rep, axis=1).astype(jnp.float32)
    cs2 = cs[:, :, 0, :].astype(jnp.float32)                # (BN,H,Q)
    dt2 = dt[:, :, 0, :].astype(jnp.float32)

    seg = cs2[:, :, :, None] - cs2[:, :, None, :]           # (BN,H,i,j)
    causal = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(causal[None, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bhin,bhjn->bhij", ch, bh)
    w = cb * lmat * dt2[:, :, None, :]
    y = jnp.einsum("bhij,bhjp->bhip", w, x.astype(jnp.float32))

    total = cs2[:, :, -1]
    decay_end = jnp.exp(total[:, :, None] - cs2) * dt2      # (BN,H,Q)
    s_local = jnp.einsum("bhqn,bhq,bhqp->bhnp", bh, decay_end,
                         x.astype(jnp.float32))
    return y, s_local
