"""Mamba2 SSD within-chunk Pallas kernel.

Computes, per (batch·chunk, head) grid cell, the two MXU-heavy terms of the
chunked SSD recurrence:
  y_diag  = ((C Bᵀ) ∘ L) diag(dt) X        (Q,P)  — intra-chunk "attention"
  s_local = Bᵀ diag(decay_end · dt) X      (N,P)  — end-of-chunk local state
where L[i,j] = exp(cs_i − cs_j)·1[i≥j] and decay_end = exp(cs_Q − cs).

The O(nc) inter-chunk recurrence and the rank-1 y_off correction stay in XLA
(they are bandwidth-trivial).  cs (cumsum of dt·A) and dt are precomputed in
ops.py and fed as (…,1,Q) rows so every block is a 2D lane-aligned tile.

Grid: (B·NC, H).  VMEM per program (Q=256, P=128, N≤128):
  x (Q,P) 128KiB + b,c (Q,N) ≤128KiB + L/cb (Q,Q) 256KiB f32 — well in budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, b_ref, c_ref, cs_ref, dt_ref, y_ref, s_ref):
    q, p = x_ref.shape[2], x_ref.shape[3]
    n = b_ref.shape[3]

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (Q, N)
    cs = cs_ref[0, 0].astype(jnp.float32)        # (1, Q)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (1, Q)

    seg = cs.reshape(q, 1) - cs.reshape(1, q)    # (Q, Q): cs_i - cs_j
    causal = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(causal, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * lmat * dt                            # dt broadcast over rows (j)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    total = cs[0, q - 1]
    decay_end = jnp.exp(total - cs) * dt          # (1, Q)
    xw = x * decay_end.reshape(q, 1)              # (Q, P)
    s_local = jax.lax.dot_general(bmat, xw, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (N,P)
    s_ref[0, 0] = s_local.astype(s_ref.dtype)


def ssd_scan_kernel(x: jax.Array, bmat: jax.Array, cmat: jax.Array,
                    cs: jax.Array, dt: jax.Array, *,
                    n_groups: int, interpret: bool = False):
    """Within-chunk SSD terms.

    x (BN, H, Q, P); bmat/cmat (BN, G, Q, N); cs/dt (BN, H, 1, Q).
    Returns (y_diag (BN,H,Q,P) f32, s_local (BN,H,N,P) f32).
    """
    bn, h, q, p = x.shape
    g = bmat.shape[1]
    n = bmat.shape[3]
    rep = h // g

    y, s = pl.pallas_call(
        _ssd_kernel,
        grid=(bn, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j // rep, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j // rep, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bn, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bn, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, bmat, cmat, cs, dt)
    return y, s
