"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array, *,
                         cap: Optional[float] = None,
                         window: Optional[int] = None) -> jax.Array:
    """q (B,Hk,G,D), k/v (B,S,Hk,D), kv_len (B,1) -> (B,Hk,G,D)."""
    b, hk, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    kpos = jnp.arange(s)
    mask = kpos[None, :] < kv_len                       # (B, S)
    if window is not None:
        mask = jnp.logical_and(mask, kpos[None, :] > kv_len - 1 - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
