"""Flash-decoding Pallas kernel: single-token attention over a long KV cache.

The KV sequence is split into ``nsplit`` chunks processed by parallel grid
cells; each emits a partial (acc, m, l) triple.  The cheap logsumexp combine
across splits happens in the ops.py wrapper (O(nsplit·G·D) — negligible).

Layout: q (B, Hk, G, D), k/v (B, S, Hk, D), kv_len (B,) via scalar prefetch
is avoided — kv_len enters as a regular (B, 1) int32 array indexed per block.

Grid: (B, Hk, nsplit).  VMEM per program: one (bk, D) k/v panel + (G, D) q.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, acc_ref, m_ref, l_ref, *,
                   scale: float, cap: Optional[float], window: Optional[int],
                   bk: int, split: int):
    isp = pl.program_id(2)
    g, d = q_ref.shape[2], q_ref.shape[3]
    kv_len = len_ref[0, 0]

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc = jnp.zeros((g, d), jnp.float32)

    nk = split // bk

    def body(i, carry):
        m, l, acc = carry
        k_start = isp * split + i * bk
        k = k_ref[0, pl.dslice(i * bk, bk), 0, :]     # (bk, D)
        v = v_ref[0, pl.dslice(i * bk, bk), 0, :]
        s = jax.lax.dot_general(q, k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        mask = kpos < kv_len
        if window is not None:
            mask = jnp.logical_and(mask, kpos > kv_len - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, nk, body, (m, l, acc))
    acc_ref[0, 0, 0] = acc
    m_ref[0, 0, 0] = jnp.broadcast_to(m, m_ref.shape[3:])
    l_ref[0, 0, 0] = jnp.broadcast_to(l, l_ref.shape[3:])


def decode_attention_kernel(
    q: jax.Array, k: jax.Array, v: jax.Array, kv_len: jax.Array, *,
    cap: Optional[float] = None, window: Optional[int] = None,
    nsplit: int = 8, bk: int = 256, interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q (B,Hk,G,D), k/v (B,S,Hk,D), kv_len (B,1) int32.

    Returns partials: acc (B,Hk,nsplit,G,D), m/l (B,Hk,nsplit,G,1→LANES).
    """
    b, hk, g, d = q.shape
    s = k.shape[1]
    while s % (nsplit * bk) != 0 and nsplit > 1:
        nsplit //= 2
    bk = min(bk, s // nsplit)
    assert s % (nsplit * bk) == 0
    split = s // nsplit
    scale = 1.0 / np.sqrt(d)

    kern = functools.partial(_decode_kernel, scale=scale, cap=cap,
                             window=window, bk=bk, split=split)
    lanes = 128
    acc, m, l = pl.pallas_call(
        kern,
        grid=(b, hk, nsplit),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, split, 1, d), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, split, 1, d), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, i: (b_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, d), lambda b_, h_, i: (b_, h_, i, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, lanes),
                         lambda b_, h_, i: (b_, h_, i, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, lanes),
                         lambda b_, h_, i: (b_, h_, i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hk, nsplit, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hk, nsplit, g, lanes), jnp.float32),
            jax.ShapeDtypeStruct((b, hk, nsplit, g, lanes), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_len)
    return acc, m, l
