"""Public decode-attention op: split-KV kernel + logsumexp combine."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def combine_splits(acc: jax.Array, m: jax.Array, l: jax.Array) -> jax.Array:
    """Merge per-split partials. acc (B,Hk,ns,G,D), m/l (B,Hk,ns,G,LANES)."""
    m = m[..., :1]                                    # (B,Hk,ns,G,1)
    l = l[..., :1]
    m_glob = jnp.max(m, axis=2, keepdims=True)
    w = jnp.exp(m - m_glob)                           # (B,Hk,ns,G,1)
    l_glob = jnp.sum(l * w, axis=2)                   # (B,Hk,G,1)
    out = jnp.sum(acc * w, axis=2) / jnp.maximum(l_glob, 1e-30)
    return out                                        # (B,Hk,G,D)


@functools.partial(jax.jit, static_argnames=("cap", "window", "nsplit",
                                             "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, cap: Optional[float] = None,
                     window: Optional[int] = None, nsplit: int = 8,
                     interpret: bool = False) -> jax.Array:
    """Model layout: q (B,1,H,D), k/v (B,S,Hk,D), kv_len (B,1) -> (B,1,H,D)."""
    b, _, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q[:, 0].reshape(b, hk, g, d)
    if _use_pallas() or interpret:
        acc, m, l = decode_attention_kernel(
            qg, k, v, kv_len.astype(jnp.int32), cap=cap, window=window,
            nsplit=nsplit, interpret=interpret or not _use_pallas())
        out = combine_splits(acc, m, l).astype(q.dtype)
    else:
        out = decode_attention_ref(qg, k, v, kv_len, cap=cap, window=window)
    return out.reshape(b, 1, h, d)
