"""Pure-jnp oracle for frame differencing."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def frame_diff_ref(cur: jax.Array, prev: jax.Array, *,
                   regions=(4, 4)) -> jax.Array:
    b, c, h, w = cur.shape
    ry, rx = regions
    rh, rw = h // ry, w // rx
    d = jnp.abs(cur.astype(jnp.float32) - prev.astype(jnp.float32)) / 255.0
    d = d.reshape(b, c, ry, rh, rx, rw)
    return d.mean(axis=(1, 3, 5))
