"""Per-region frame differencing Pallas kernel (the Skip operator's signal).

Computes mean |frame_t − frame_{t−1}| over a (RY × RX) grid of regions —
the cheap "is anything happening here?" statistic the semantic optimizer's
Skip(N, condition) operator evaluates before invoking the MLLM.

Grid: (B, RY, RX); each program reduces one (C, rh, rw) region pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _diff_kernel(a_ref, b_ref, o_ref):
    a = a_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    o_ref[0, 0, 0] = jnp.mean(jnp.abs(a - b)) / 255.0


def frame_diff_kernel(cur: jax.Array, prev: jax.Array, *, regions=(4, 4),
                      interpret: bool = False) -> jax.Array:
    """cur/prev (B, C, H, W) uint8 -> (B, RY, RX) f32 mean abs diff in [0,1]."""
    b, c, h, w = cur.shape
    ry, rx = regions
    assert h % ry == 0 and w % rx == 0
    rh, rw = h // ry, w // rx

    return pl.pallas_call(
        _diff_kernel,
        grid=(b, ry, rx),
        in_specs=[
            pl.BlockSpec((1, c, rh, rw), lambda b_, i, j: (b_, 0, i, j)),
            pl.BlockSpec((1, c, rh, rw), lambda b_, i, j: (b_, 0, i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda b_, i, j: (b_, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, ry, rx), jnp.float32),
        interpret=interpret,
    )(cur, prev)
