"""Public frame-diff op."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.frame_diff.kernel import frame_diff_kernel
from repro.kernels.frame_diff.ref import frame_diff_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("regions", "interpret"))
def frame_diff(cur: jax.Array, prev: jax.Array, *, regions=(4, 4),
               interpret: bool = False) -> jax.Array:
    if _use_pallas() or interpret:
        return frame_diff_kernel(cur, prev, regions=regions,
                                 interpret=interpret or not _use_pallas())
    return frame_diff_ref(cur, prev, regions=regions)
