"""Public fused preprocessing op (the streaming pipeline's pixel hot path)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_preprocess.kernel import fused_preprocess_kernel
from repro.kernels.fused_preprocess.ref import fused_preprocess_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("crop", "factor", "mean", "std",
                                             "grey", "out_dtype", "interpret"))
def fused_preprocess(frames: jax.Array, *, crop: Tuple[int, int, int, int],
                     factor: int = 1,
                     mean: Tuple[float, ...] = (0.5, 0.5, 0.5),
                     std: Tuple[float, ...] = (0.25, 0.25, 0.25),
                     grey: bool = False, out_dtype=jnp.float32,
                     interpret: bool = False) -> jax.Array:
    if _use_pallas() or interpret:
        return fused_preprocess_kernel(
            frames, crop=crop, factor=factor, mean=mean, std=std, grey=grey,
            out_dtype=out_dtype, interpret=interpret or not _use_pallas())
    return fused_preprocess_ref(frames, crop=crop, factor=factor, mean=mean,
                                std=std, grey=grey, out_dtype=out_dtype)
