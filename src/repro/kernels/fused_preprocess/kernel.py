"""Fused Crop+Downscale+Normalize(+Greyscale) Pallas kernel.

This is the TPU-native realization of the Saṃsāra semantic-optimization
data-reduction operators: instead of separate Crop → Downscale → Normalize
passes (3× HBM round trips on the raw frame), a single kernel reads each raw
uint8 tile once and emits the reduced bf16/f32 tile.

Layout: frames are channels-first (B, C, H, W) uint8 (W lanes).  The crop is
expressed in the BlockSpec index_map — crop offsets must be multiples of the
input tile (the optimizer catalog quantizes crop regions accordingly).
Downscale is area-averaging by an integer factor f.

Grid: (B, H_out/Th, W_out/Tw).  VMEM per program:
  in (C, Th·f, Tw·f) uint8 ≤ 3·128f·128f B (f=4 => 786KiB) — in budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _preproc_kernel(x_ref, o_ref, *, factor: int, mean: Tuple[float, ...],
                    std: Tuple[float, ...], grey: bool):
    c, hf, wf = x_ref.shape[1], x_ref.shape[2], x_ref.shape[3]
    th, tw = hf // factor, wf // factor
    x = x_ref[0].astype(jnp.float32) / 255.0          # (C, Hf, Wf)
    # area downscale
    x = x.reshape(c, th, factor, tw, factor).mean(axis=(2, 4))
    # per-channel affine with Python-static constants (no captured arrays)
    chans = [(x[ci] - mean[ci]) / std[ci] for ci in range(c)]
    if grey:
        lum = (0.299, 0.587, 0.114)
        out = chans[0] * lum[0]
        for ci in range(1, c):
            out = out + chans[ci] * lum[ci]
        x = out[None]                                  # (1, Th, Tw)
    else:
        x = jnp.stack(chans, axis=0)
    o_ref[0] = x.astype(o_ref.dtype)


def fused_preprocess_kernel(
    frames: jax.Array, *, crop: Tuple[int, int, int, int], factor: int = 1,
    mean: Tuple[float, ...] = (0.5, 0.5, 0.5),
    std: Tuple[float, ...] = (0.25, 0.25, 0.25), grey: bool = False,
    tile: Tuple[int, int] = (32, 128), out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """frames (B, C, H, W) uint8; crop (y0, x0, h, w) -> (B, C', h/f, w/f)."""
    b, c, h, w = frames.shape
    y0, x0, ch, cw = crop
    assert y0 + ch <= h and x0 + cw <= w, "crop outside frame"

    def _fit_tile(want: int, offset: int, size: int, f: int) -> int:
        """Largest input-tile (multiple of f) dividing both offset and size."""
        import math

        align = math.gcd(offset, size) if offset else size
        d = min(want * f, align)
        while d > f and (align % d or d % f):
            d -= f
        assert d >= f and align % d == 0 and d % f == 0, (
            "crop not tileable; the catalog quantizes regions")
        return d

    th, tw = tile
    in_th = _fit_tile(th, y0, ch, factor)
    in_tw = _fit_tile(tw, x0, cw, factor)
    th, tw = in_th // factor, in_tw // factor
    h_out, w_out = ch // factor, cw // factor
    c_out = 1 if grey else c
    oy, ox = y0 // in_th, x0 // in_tw

    return pl.pallas_call(
        functools.partial(_preproc_kernel, factor=factor, mean=mean, std=std,
                          grey=grey),
        grid=(b, h_out // th, w_out // tw),
        in_specs=[
            pl.BlockSpec((1, c, in_th, in_tw),
                         lambda b_, i, j: (b_, 0, oy + i, ox + j)),
        ],
        out_specs=pl.BlockSpec((1, c_out, th, tw),
                               lambda b_, i, j: (b_, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c_out, h_out, w_out), out_dtype),
        interpret=interpret,
    )(frames)
