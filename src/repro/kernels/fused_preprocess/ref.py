"""Pure-jnp oracle for fused preprocessing."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def fused_preprocess_ref(
    frames: jax.Array, *, crop: Tuple[int, int, int, int], factor: int = 1,
    mean: Tuple[float, ...] = (0.5, 0.5, 0.5),
    std: Tuple[float, ...] = (0.25, 0.25, 0.25), grey: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    b, c, h, w = frames.shape
    y0, x0, ch, cw = crop
    x = frames[:, :, y0:y0 + ch, x0:x0 + cw].astype(jnp.float32) / 255.0
    x = x.reshape(b, c, ch // factor, factor, cw // factor, factor)
    x = x.mean(axis=(3, 5))
    mean_a = jnp.asarray(mean, jnp.float32).reshape(1, c, 1, 1)
    std_a = jnp.asarray(std, jnp.float32).reshape(1, c, 1, 1)
    x = (x - mean_a) / std_a
    if grey:
        wgt = jnp.asarray([0.299, 0.587, 0.114], jnp.float32).reshape(1, c, 1, 1)
        x = jnp.sum(x * wgt, axis=1, keepdims=True)
    return x.astype(out_dtype)
