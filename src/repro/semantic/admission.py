"""Accuracy-budgeted admission control for the semantic cache.

The cache's only knob with accuracy consequences is the similarity
threshold below which a frame is served from a keyframe's cached extract.
The right value differs per feed (an empty toll lane tolerates a loose
threshold; a volleyball rally does not) and drifts over time, so the
controller tunes it **online from measured evidence**: every revalidation
(a cache hit deliberately sent through the model anyway) yields one
boolean observation — did the cached answer still match the model?

The mismatch rate is tracked as an EMA per feed and steered toward the
configured accuracy budget with asymmetric multiplicative updates:

* mismatch EMA above the budget → *tighten sharply* (halve the
  threshold): the cache is lying at a rate the query set cannot absorb,
  so stop admitting aggressively and let novel frames refresh keyframes;
* mismatch EMA comfortably below the budget → *recover slowly*
  (+5% per clean revalidation), but never past the configured base
  threshold — the budget bounds risk, it is not a license to drift looser
  than the operator asked for.

Mismatches are rare events, so the EMA weight is high (each observation
is expensive — it cost a real forward) and the floor keeps the threshold
strictly positive: a fully-closed gate would stop producing revalidation
evidence and could never re-open.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class FeedAdmission:
    """Per-feed controller state (snapshot/restore round-trips it)."""

    threshold: float
    mismatch_ema: float = 0.0
    observations: int = 0


class AdmissionController:
    """Steers per-feed thresholds toward a target revalidation-mismatch
    rate (the accuracy budget)."""

    #: EMA weight per revalidation observation
    EMA = 0.25
    #: multiplicative tighten on budget violation / recover when clean
    TIGHTEN = 0.5
    RECOVER = 1.05
    #: the threshold never collapses to 0 (no evidence) nor exceeds base
    MIN_FRAC = 0.05

    def __init__(self, base_threshold: float, budget: float):
        assert base_threshold >= 0.0 and budget >= 0.0
        self.base_threshold = base_threshold
        self.budget = budget
        self._feeds: dict = {}

    # ------------------------------------------------------------------
    def feed(self, feed: str) -> FeedAdmission:
        st = self._feeds.get(feed)
        if st is None:
            st = self._feeds[feed] = FeedAdmission(
                threshold=self.base_threshold)
        return st

    def threshold(self, feed: str) -> float:
        return self.feed(feed).threshold

    def observe(self, feed: str, mismatch: bool) -> None:
        """Fold one revalidation outcome into the feed's threshold."""
        st = self.feed(feed)
        st.observations += 1
        st.mismatch_ema = (1 - self.EMA) * st.mismatch_ema \
            + self.EMA * float(mismatch)
        if st.mismatch_ema > self.budget:
            st.threshold = max(st.threshold * self.TIGHTEN,
                               self.base_threshold * self.MIN_FRAC)
        elif st.mismatch_ema < 0.5 * self.budget:
            st.threshold = min(st.threshold * self.RECOVER,
                               self.base_threshold)

    # ------------------------------------------------------------------
    def reset(self, feed=None) -> None:
        if feed is None:
            self._feeds.clear()
        else:
            self._feeds.pop(feed, None)

    def snapshot(self, feed: str) -> dict:
        return dataclasses.asdict(self.feed(feed))

    def restore(self, feed: str, st: dict) -> None:
        self._feeds[feed] = FeedAdmission(**st)
