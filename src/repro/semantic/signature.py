"""Temporal frame signatures: cheap, batched, jitted.

A signature is two views of a downsampled frame:

* ``feats`` — per-channel patch means on a coarse grid (≤ 8×16 cells).
  The mean absolute delta between two frames' grids is a spatially-aware
  activity measure (the same physics ``SkipOp``'s frame-diff exploits:
  a car cannot teleport between cells).
* ``emb`` — a fixed random projection of the grid to a small vector.
  L2 distance in this space is a *content* measure that is cheap to
  compare and to quantize: its coarse quantization is the cache's
  **signature bucket**, so re-visiting a previously-seen scene (the empty
  road between cars) lands on the keyframe that described it.

Both are computed in one jitted call per submitted batch — the signature
rides the existing prefix pass, it never adds a second sweep over the
frames.  Raw (uint8-range) vs already-normalized rows are decided **per
frame**, the ``make_extract_fn`` convention, so a gate in front of the
``SharedExtractServer`` scores mixed-stage coalesced traffic exactly like
uniform batches.  Inputs are padded to the power-of-two bucket before the
jitted call (compiled shapes stay logarithmic in batch size) and the pad
rows sliced off.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_prefix.ref import project_rowwise
from repro.streaming.operators import _bucket_pad

#: dimensionality of the random-projection embedding (bucket keys are
#: tuples of this many quantized ints)
EMB_DIM = 16

#: fixed seed for the projection — signatures must be stable across
#: processes, or a restored cache snapshot would never hit again
_PROJ_SEED = 7


def _grid(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (pooling needs exact
    tiling; frame dims here are crops/downscales of 128×256, so a good
    divisor always exists)."""
    for g in range(min(target, n), 0, -1):
        if n % g == 0:
            return g
    return 1


def signature_layout(shape: Tuple[int, int, int],
                     grid: Tuple[int, int] = (8, 16)
                     ) -> Tuple[int, int, int, np.ndarray]:
    """The pooling grid and projection matrix for one frame shape —
    ``(gy, gx, d, proj)``.  This is the single source of truth shared by
    ``TemporalSignature`` and the fused-prefix path
    (``kernels/fused_prefix``): both must produce bitwise-identical
    signatures for the gate's cache buckets to agree, so neither may
    derive the layout independently."""
    c, h, w = shape
    gy, gx = _grid(h, grid[0]), _grid(w, grid[1])
    d = c * gy * gx
    rng = np.random.RandomState(_PROJ_SEED)
    proj = rng.standard_normal((d, EMB_DIM)).astype(np.float32)
    proj /= np.sqrt(d)
    return gy, gx, d, proj


class TemporalSignature:
    """Batched signature extractor with one compiled program per
    (frame shape, dtype, padded batch size)."""

    def __init__(self, grid: Tuple[int, int] = (8, 16)):
        self.grid = grid
        self._fns: Dict[Tuple, object] = {}
        self._projs: Dict[Tuple, np.ndarray] = {}

    # ------------------------------------------------------------------
    def _fn(self, shape: Tuple[int, int, int], dtype_str: str):
        key = shape + (dtype_str,)
        if key in self._fns:
            return self._fns[key]
        c, h, w = shape
        gy, gx, d, proj = signature_layout(shape, self.grid)
        self._projs[key] = proj

        @jax.jit
        def fn(frames):
            x = frames.astype(jnp.float32)
            # per-frame raw detection (the make_extract_fn convention)
            raw = x.reshape(x.shape[0], -1).max(axis=1) > 8.0
            x = jnp.where(raw[:, None, None, None],
                          (x / 255.0 - 0.5) / 0.25, x)
            p = x.reshape(x.shape[0], c, gy, h // gy, gx, w // gx)
            feats = p.mean(axis=(3, 5)).reshape(x.shape[0], d)
            # row-deterministic projection shared with kernels/fused_prefix
            # — a gemm here would round differently per padded batch size,
            # breaking the fused path's bitwise signature hand-off
            emb = project_rowwise(feats, jnp.asarray(proj))
            return feats, emb

        self._fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    def features(self, frames: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """(n, C, H, W) frames -> (feats (n, D), emb (n, EMB_DIM))."""
        assert frames.ndim == 4 and frames.shape[0] > 0, frames.shape
        n = frames.shape[0]
        bucket = _bucket_pad(n)
        if bucket != n:
            pad = np.zeros((bucket - n,) + frames.shape[1:], frames.dtype)
            frames = np.concatenate([frames, pad], 0)
        fn = self._fn(tuple(frames.shape[1:]), frames.dtype.str)
        feats, emb = fn(frames)
        return np.asarray(feats)[:n], np.asarray(emb)[:n]

    # ------------------------------------------------------------------
    @staticmethod
    def distance(feats_a: np.ndarray, emb_a: np.ndarray,
                 feats_b: np.ndarray, emb_b: np.ndarray) -> float:
        """Scalar dissimilarity of two frames' signatures: patch-grid
        activity and embedding distance, equally weighted.  0.0 for
        identical frames; ~O(1) for unrelated scenes."""
        patch = float(np.abs(feats_a - feats_b).mean())
        emb = float(np.linalg.norm(emb_a - emb_b)) / np.sqrt(EMB_DIM)
        return 0.5 * patch + 0.5 * emb

    @staticmethod
    def bucket(emb: np.ndarray, width: float) -> Tuple[int, ...]:
        """Quantize one embedding to its cache bucket.  Coarse on purpose:
        a boundary straddle costs at worst an extra model forward (the
        cache's newest-keyframe fallback usually recovers it), never a
        wrong answer."""
        return tuple(int(q) for q in np.floor(emb / max(width, 1e-9)))
