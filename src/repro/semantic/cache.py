"""Semantic extract cache: keyframe outputs answering near-duplicates.

Entries are keyed by ``(variant, frame shape, signature bucket)`` inside a
per-feed LRU (a feed is one camera — temporal redundancy is a per-feed
phenomenon; the variant and shape keep physically different extracts from
ever answering each other).  A *novel* frame becomes a keyframe entry; a
*near-duplicate* is served the keyframe's cached per-task predictions.

The cache composes with pipelined serving: a keyframe's own forward may
still be in flight when a later micro-batch hits it, so an entry's
predictions are either concrete numpy rows or a ``_ModelRowRef`` — row
*j* of an earlier admission's model output, resolvable once that forward
retires.  ``Admission.ready`` folds those donors into the request's
``done`` contract, and per-feed FIFO resume order means a donor (submitted
strictly earlier) never blocks its dependents' progress.

``Admission`` is the unit the serving tier handles: the cache-consult
decision for one submitted batch (which rows go to the model, which are
answered from keyframes, which hits revalidate), plus ``assemble()`` —
the one-shot finalize that stitches model and cached rows back into the
batch's per-task prediction arrays, fills this admission's new keyframe
entries, performs the revalidation comparisons (counting mismatches,
feeding the admission controller, and refreshing drifted keyframes with
the fresh model answer).
"""
from __future__ import annotations

import copy
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


class _ModelRowRef:
    """Row ``j`` of ``adm``'s model output — resolvable once the backing
    forward (bound by the serving tier) completes."""

    __slots__ = ("adm", "j")

    def __init__(self, adm: "Admission", j: int):
        self.adm = adm
        self.j = j

    @property
    def done(self) -> bool:
        src = self.adm._src
        return src is not None and src.done

    def resolve(self) -> Dict[str, np.ndarray]:
        res = self.adm._src.result
        return {k: np.asarray(v)[self.j] for k, v in res.items()}


class _Ready:
    """Concrete model output masquerading as a completed request — the
    synchronous (solo ``MLLMExtractOp``) path binds one of these."""

    __slots__ = ("result",)
    done = True

    def __init__(self, preds: Dict[str, np.ndarray]):
        self.result = preds


class CacheEntry:
    """One keyframe: its signature, its extract output (possibly still in
    flight), and the hit/revalidation accounting the budget rides on."""

    __slots__ = ("feats", "emb", "preds", "pending", "hits", "since_reval",
                 "validations")

    def __init__(self, feats: np.ndarray, emb: np.ndarray,
                 preds: Optional[Dict[str, np.ndarray]] = None):
        self.feats = feats
        self.emb = emb
        self.preds = preds
        self.pending: Optional[_ModelRowRef] = None
        self.hits = 0
        self.since_reval = 0
        self.validations = 0

    def ref(self):
        """What a hit serves: concrete rows, or the in-flight donor."""
        return self.preds if self.preds is not None else self.pending


class SemanticExtractCache:
    """Per-feed LRU of keyframe entries."""

    def __init__(self, max_entries: int = 64):
        assert max_entries >= 1
        self.max_entries = max_entries
        self._feeds: Dict[str, OrderedDict] = {}
        #: feed -> (variant, shape) -> bucket key of the newest keyframe.
        #: Temporal-locality fallback: a slowly drifting scene (a car
        #: creeping through the lane) walks its embedding across bucket
        #: edges, so the bucket probe misses although the frame is within
        #: threshold of the *most recent* keyframe — probing that one
        #: keyframe recovers the straddle without a neighborhood search.
        self._last: Dict[str, Dict[Tuple, Tuple]] = {}

    # ------------------------------------------------------------------
    def lookup(self, feed: str, key: Tuple) -> Optional[CacheEntry]:
        entries = self._feeds.get(feed)
        if entries is None:
            return None
        e = entries.get(key)
        if e is not None:
            entries.move_to_end(key)
        return e

    def last_entry(self, feed: str, subkey: Tuple) -> Optional[CacheEntry]:
        """The newest keyframe of this (variant, shape), if still cached."""
        key = self._last.get(feed, {}).get(subkey)
        if key is None:
            return None
        return self._feeds.get(feed, {}).get(key)

    def insert(self, feed: str, key: Tuple, entry: CacheEntry) -> None:
        entries = self._feeds.setdefault(feed, OrderedDict())
        entries[key] = entry
        entries.move_to_end(key)
        self._last.setdefault(feed, {})[key[:2]] = key
        while len(entries) > self.max_entries:
            entries.popitem(last=False)

    def newest_preds(self, feed: str) -> Optional[Dict[str, np.ndarray]]:
        """The most recently touched keyframe's *concrete* extract
        output for ``feed`` (entries still awaiting their donor forward
        are skipped) — the degraded-mode fallback a quarantined feed
        serves, marked stale, while its circuit is open."""
        entries = self._feeds.get(feed)
        if not entries:
            return None
        for key in reversed(entries):       # LRU order: newest last
            preds = entries[key].preds
            if preds is not None:
                return preds
        return None

    def __len__(self) -> int:
        return sum(len(e) for e in self._feeds.values())

    # ------------------------------------------------------------------
    def reset(self, feed: Optional[str] = None) -> None:
        if feed is None:
            self._feeds.clear()
            self._last.clear()
        else:
            self._feeds.pop(feed, None)
            self._last.pop(feed, None)

    def snapshot(self, feed: str) -> Dict[str, Any]:
        """LRU-ordered entry list + newest-keyframe pointers; every entry
        must be concrete — the serving tier drains in-flight forwards
        before snapshotting."""
        out = []
        for key, e in self._feeds.get(feed, {}).items():
            if e.preds is None and e.pending is not None:
                assert e.pending.done, \
                    "snapshot with in-flight keyframe — drain() first"
                e.preds = e.pending.resolve()
                e.pending = None
            out.append((key, {
                "feats": np.copy(e.feats), "emb": np.copy(e.emb),
                "preds": copy.deepcopy(e.preds),
                "hits": e.hits, "since_reval": e.since_reval,
                "validations": e.validations}))
        return {"entries": out,
                "last": dict(self._last.get(feed, {}))}

    def restore(self, feed: str, st: Dict[str, Any]) -> None:
        entries: OrderedDict = OrderedDict()
        for key, d in st["entries"]:
            e = CacheEntry(np.copy(d["feats"]), np.copy(d["emb"]),
                           copy.deepcopy(d["preds"]))
            e.hits = d["hits"]
            e.since_reval = d["since_reval"]
            e.validations = d["validations"]
            entries[tuple(key)] = e
        self._feeds[feed] = entries
        self._last[feed] = dict(st.get("last", {}))


class Admission:
    """Cache-consult decision for one submitted batch of ``n`` frames.

    ``plan[i]`` says how batch row *i* is answered: ``("model", j)`` — row
    *j* of this admission's model forward (novel frames and revalidated
    hits), or ``("cache", ref)`` — a keyframe's output (concrete rows or a
    ``_ModelRowRef`` into an earlier, possibly in-flight forward).  The
    serving tier runs the model over ``model_frames(frames)`` only, binds
    the output (a request or a concrete prediction dict) with ``bind``,
    and calls ``assemble()`` once ``ready``."""

    def __init__(self, feed: str, variant: str, n: int, gate,
                 mismatch_min_tasks: int = 2):
        self.feed = feed
        self.variant = variant
        self.n = n
        self.gate = gate
        self.mismatch_min_tasks = mismatch_min_tasks
        self.model_rows: List[int] = []
        self.plan: List[Optional[Tuple[str, Any]]] = [None] * n
        #: (entry, model row j, cached ref) revalidation comparisons
        self.reval: List[Tuple[CacheEntry, int, Any]] = []
        #: keyframe entries this admission's forward will fill
        self.fills: List[Tuple[CacheEntry, int]] = []
        #: earlier admissions' refs this one depends on
        self.donors: List[_ModelRowRef] = []
        self._src = None
        self._assembled: Optional[Dict[str, np.ndarray]] = None

    # ------------------------------------------------------------------
    @property
    def n_model(self) -> int:
        return len(self.model_rows)

    def add_model_row(self, i: int) -> int:
        j = len(self.model_rows)
        self.model_rows.append(i)
        self.plan[i] = ("model", j)
        return j

    def add_cache_row(self, i: int, ref) -> None:
        self.plan[i] = ("cache", ref)
        if isinstance(ref, _ModelRowRef) and ref.adm is not self:
            self.donors.append(ref)

    def add_reval_row(self, i: int, entry: CacheEntry) -> int:
        """Escalate a hit: row ``i`` pays a forward whose output both
        answers the row and is compared against the keyframe's cached
        answer at assemble time."""
        j = self.add_model_row(i)
        cached = entry.ref()
        self.reval.append((entry, j, cached))
        if isinstance(cached, _ModelRowRef) and cached.adm is not self:
            self.donors.append(cached)
        return j

    def attach_fill(self, entry: CacheEntry, j: int) -> None:
        """Register a fresh keyframe whose predictions are model row j."""
        entry.pending = _ModelRowRef(self, j)
        self.fills.append((entry, j))

    def model_frames(self, frames: np.ndarray) -> np.ndarray:
        """The subset of ``frames`` that must pay a forward."""
        if self.n_model == self.n:
            return frames
        return frames[np.asarray(self.model_rows)]

    # ------------------------------------------------------------------
    def bind(self, src) -> None:
        """Attach the model output for ``model_rows``: an extract request
        (pipelined path), a concrete per-task dict (solo path), or None
        when every row was answered from cache."""
        if isinstance(src, dict):
            src = _Ready(src)
        assert src is not None or self.n_model == 0
        self._src = src

    @property
    def ready(self) -> bool:
        """The forward (if any) and every donor completed — ``assemble``
        will not block."""
        if self.n_model and (self._src is None or not self._src.done):
            return False
        return all(d.done for d in self.donors)

    def assemble(self) -> Dict[str, np.ndarray]:
        """Finalize (idempotent): stitch model + cached rows into per-task
        arrays, fill this admission's keyframes, run the revalidation
        comparisons, and feed the admission controller."""
        if self._assembled is not None:
            return self._assembled
        assert self.ready, "assemble() before the backing forward completed"
        model: Dict[str, np.ndarray] = {}
        if self.n_model:
            model = {k: np.asarray(v) for k, v in self._src.result.items()}
        rows: List[Dict[str, np.ndarray]] = [None] * self.n
        for i, (kind, x) in enumerate(self.plan):
            if kind == "model":
                rows[i] = {k: v[x] for k, v in model.items()}
            else:
                rows[i] = x.resolve() if isinstance(x, _ModelRowRef) else x
        with self.gate._lock:
            for entry, j in self.fills:
                # the entry may have been superseded by a later keyframe
                # of the same bucket — fill only if it still waits on us
                if entry.pending is not None and entry.pending.adm is self:
                    entry.preds = {k: v[j] for k, v in model.items()}
                    entry.pending = None
            for entry, j, cached in self.reval:
                fresh = {k: v[j] for k, v in model.items()}
                old = cached.resolve() if isinstance(cached, _ModelRowRef) \
                    else cached
                # drift vs churn: a real scene change flips several heads
                # at once; an isolated head flip is indistinguishable from
                # the model's own argmax tie-churn on unchanged frames
                n_diff = sum(not np.array_equal(fresh[k], old[k])
                             for k in fresh)
                mismatch = n_diff >= self.mismatch_min_tasks
                if mismatch:
                    self.gate._count(self.feed, "cache_mismatches")
                self.gate.controller.observe(self.feed, mismatch)
                # refresh the keyframe with the fresh answer regardless —
                # even sub-threshold drift self-corrects every Nth hit
                entry.preds = fresh
                entry.pending = None
        self._assembled = {k: np.stack([r[k] for r in rows])
                           for k in rows[0]}
        return self._assembled
