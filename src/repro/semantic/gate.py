"""The semantic gate: signature + cache + admission control, one facade.

``SemanticGate.admit(feed, variant, frames)`` is the cache-consult stage
the serving tier calls for every batch that reached an MLLM extract: it
computes the batch's temporal signatures (one jitted call), classifies
each row against the feed's keyframe cache under the feed's *current*
(controller-tuned) threshold, and returns an ``Admission`` describing
which rows pay a forward and which are answered from keyframes — with
every Nth hit per keyframe escalated to a revalidation (model + compare).

The gate is a runtime service shared by every consumer of one serving
tier (the solo ``MLLMExtractOp`` path keys state by op, the
``SharedExtractServer`` by feed name), and it is *inert* unless enabled
with a positive threshold: callers check ``gate.active`` and take their
original, bitwise-identical path when it is False.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

import numpy as np

from repro.obs import NULL_OBS
from repro.semantic.admission import AdmissionController
from repro.semantic.cache import Admission, CacheEntry, SemanticExtractCache
from repro.semantic.signature import TemporalSignature


@dataclasses.dataclass
class GateConfig:
    """Knobs of the semantic tier.

    ``threshold`` is the *base* signature-distance below which a frame is
    a near-duplicate (0 disables the gate entirely — every caller takes
    its pre-gate path).  ``revalidate_every`` bounds trust in any one
    keyframe: of every ``revalidate_every`` consecutive hits, one is sent
    through the model and compared.  ``accuracy_budget`` is the target
    revalidation-mismatch rate the admission controller steers each
    feed's threshold toward.

    ``mismatch_min_tasks`` separates drift from model churn: a
    revalidation counts as a mismatch only when at least this many task
    heads disagree with the cached answer.  Measured on the tollbooth
    stream, the plate head alone flips on ~10% of *identical-scene*
    consecutive frame pairs (argmax tie-churn on frames with no plate to
    read — the ungated pipeline exhibits the same churn), while a real
    scene change flips several heads at once; single-task disagreements
    still refresh the keyframe with the fresh answer, they just do not
    count against the accuracy budget.  Set to 1 for the strictest
    reading."""

    threshold: float = 0.08
    revalidate_every: int = 8
    accuracy_budget: float = 0.05
    max_entries: int = 64
    bucket_width: float = 0.5
    mismatch_min_tasks: int = 2

    def __post_init__(self):
        assert self.threshold >= 0.0
        assert self.revalidate_every >= 2, \
            "revalidate_every < 2 means every hit revalidates — disable " \
            "the gate instead"


class SemanticGate:
    """Temporal-redundancy gate in front of the (shared) MLLM."""

    COUNTER_KEYS = ("cache_hits", "cache_misses", "revalidations",
                    "cache_mismatches")

    def __init__(self, config: Optional[GateConfig] = None):
        self.config = config if config is not None else GateConfig()
        self.signature = TemporalSignature()
        self.cache = SemanticExtractCache(self.config.max_entries)
        self.controller = AdmissionController(self.config.threshold,
                                              self.config.accuracy_budget)
        self.counters: Dict[str, int] = {k: 0 for k in self.COUNTER_KEYS}
        #: observability handle; owners (server / solo op) overwrite it
        #: with the context's — the gate then emits per-consult ``gate``
        #: spans and hit/miss/revalidate instants on the feed's track
        self.obs = NULL_OBS
        #: per-feed view of the same counters — the measured hit rates the
        #: cost model prices gated plans by
        self.feed_counters: Dict[str, Dict[str, int]] = {}
        #: serializes classification and finalize against each other —
        #: today's callers admit/assemble from one scheduling thread, but
        #: a gated extract inside a fan-out *tail* would run on the tail
        #: pool, and lost counter increments there would silently skew
        #: every measured rate (uncontended, so effectively free)
        self._lock = threading.Lock()

    def _count(self, feed: str, key: str) -> None:
        self.counters[key] += 1
        fc = self.feed_counters.setdefault(
            feed, {k: 0 for k in self.COUNTER_KEYS})
        fc[key] += 1

    # ------------------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.config.threshold > 0.0

    def hit_rate(self, feed: Optional[str] = None) -> float:
        """Fraction of admitted frames answered without a forward —
        workload-wide, or for one feed."""
        c = self.counters if feed is None \
            else self.feed_counters.get(feed, {})
        served = sum(c.get(k, 0) for k in
                     ("cache_hits", "cache_misses", "revalidations"))
        return c.get("cache_hits", 0) / max(served, 1)

    def served(self, feed: Optional[str] = None) -> int:
        """Frames classified by the gate (hit + miss + revalidation)."""
        c = self.counters if feed is None \
            else self.feed_counters.get(feed, {})
        return sum(c.get(k, 0) for k in
                   ("cache_hits", "cache_misses", "revalidations"))

    # ------------------------------------------------------------------
    def admit(self, feed: str, variant: str,
              frames: np.ndarray, sig=None) -> Admission:
        """Classify one batch; the caller runs the model only over
        ``admission.model_frames(frames)`` and binds the output.

        ``sig``, when given, is a precomputed ``(feats, emb)`` pair for
        exactly these frames — the fused prefix path
        (``FusedPrefixOp``) produces the signature in the same device
        pass as the rest of the chain, so the gate skips its own jitted
        call.  The fused signature is bitwise-identical to
        ``self.signature.features(frames)`` (both derive from
        ``signature_layout``), so cache buckets and distances agree
        regardless of which path computed it."""
        assert self.active
        obs = self.obs
        t0 = obs.now() if obs.enabled else 0
        n = int(frames.shape[0])
        adm = Admission(feed=feed, variant=variant, n=n, gate=self,
                        mismatch_min_tasks=self.config.mismatch_min_tasks)
        feats, emb = sig if sig is not None \
            else self.signature.features(frames)
        shape = tuple(frames.shape[1:])
        every = self.config.revalidate_every
        with self._lock:
            thr = self.controller.threshold(feed)
            for i in range(n):
                key = (variant, shape,
                       TemporalSignature.bucket(emb[i],
                                                self.config.bucket_width))
                entry = self.cache.lookup(feed, key)
                if entry is not None and TemporalSignature.distance(
                        feats[i], emb[i], entry.feats, entry.emb) >= thr:
                    entry = None
                if entry is None:
                    # temporal-locality fallback: a drifting scene walks
                    # its embedding across bucket edges — probe the feed's
                    # newest keyframe before declaring the frame novel
                    last = self.cache.last_entry(feed, key[:2])
                    if last is not None and TemporalSignature.distance(
                            feats[i], emb[i], last.feats, last.emb) < thr:
                        entry = last
                if entry is not None:
                    entry.hits += 1
                    if entry.since_reval + 1 >= every:
                        # the Nth hit pays a forward anyway: drift check
                        entry.since_reval = 0
                        entry.validations += 1
                        adm.add_reval_row(i, entry)
                        self._count(feed, "revalidations")
                    else:
                        entry.since_reval += 1
                        adm.add_cache_row(i, entry.ref())
                        self._count(feed, "cache_hits")
                else:
                    # novel: pays a forward, becomes the bucket's keyframe
                    j = adm.add_model_row(i)
                    new = CacheEntry(feats[i], emb[i])
                    self.cache.insert(feed, key, new)
                    adm.attach_fill(new, j)
                    self._count(feed, "cache_misses")
        if obs.enabled:
            track = f"feed:{feed}"
            tr = obs.tracer
            tr.span("gate", "gate", t0, obs.now(), track=track, n=n)
            revals = len(adm.reval)
            hits = n - adm.n_model
            misses = adm.n_model - revals
            if hits:
                tr.instant("gate:hit", "gate", track=track, n=hits)
            if misses:
                tr.instant("gate:miss", "gate", track=track, n=misses)
            if revals:
                tr.instant("gate:revalidate", "gate", track=track,
                           n=revals)
        return adm

    # ------------------------------------------------------------------
    def reset(self, feed: Optional[str] = None) -> None:
        """Drop gating state (keyframes + tuned thresholds) for one feed,
        or for every feed — the warmup/reset analogue of ``Op.reset``.
        Counters are accounting and reset separately
        (``reset_counters``)."""
        self.cache.reset(feed)
        self.controller.reset(feed)

    def reset_counters(self) -> None:
        for k in self.COUNTER_KEYS:
            self.counters[k] = 0
        self.feed_counters.clear()

    # ------------------------------------------------------------------
    def stale_answer(self, feed: str) -> Optional[dict]:
        """The newest concrete keyframe extract output for ``feed``,
        summarized to plain Python values — what degraded-mode serving
        reports (marked ``stale``) while the feed's circuit is open.
        None when the feed has no usable keyframe yet (the runtime then
        *drops* with exact accounting instead of degrading)."""
        preds = self.cache.newest_preds(feed)
        if preds is None:
            return None
        return {k: np.asarray(v).tolist() for k, v in preds.items()}

    # ------------------------------------------------------------------
    def snapshot_feed(self, feed: str) -> dict:
        return {"admission": self.controller.snapshot(feed),
                "cache": self.cache.snapshot(feed)}

    def restore_feed(self, feed: str, st: dict) -> None:
        self.controller.restore(feed, st["admission"])
        self.cache.restore(feed, st["cache"])

    def snapshot(self) -> dict:
        feeds = set(self.cache._feeds) | set(self.controller._feeds)
        return {"feeds": {f: self.snapshot_feed(f) for f in sorted(feeds)},
                "counters": dict(self.counters),
                "feed_counters": {f: dict(c)
                                  for f, c in self.feed_counters.items()}}

    def restore(self, st: dict) -> None:
        self.reset()
        for feed, fs in st["feeds"].items():
            self.restore_feed(feed, fs)
        self.counters.update(st["counters"])
        self.feed_counters = {f: dict(c)
                              for f, c in st.get("feed_counters",
                                                 {}).items()}
