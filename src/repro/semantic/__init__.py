"""Semantic gating tier: temporal-redundancy extract cache in front of the
shared MLLM.

The paper's semantic transformations cut MLLM load by exploiting what the
*data* means, not just what the query asks: consecutive frames of a fixed
camera are overwhelmingly near-duplicates, and a near-duplicate of a frame
the model already described does not need another forward.  This package
is that tier, sitting between the prefix operators and the model:

* ``TemporalSignature`` (``signature``) — a jitted, batched per-frame
  signature: downsampled patch means plus a cheap random-projection
  embedding, computed once per micro-batch alongside the existing prefix
  pass.  Distances between signatures classify each surviving frame as
  *novel* or a *near-duplicate* of a recent keyframe.

* ``SemanticExtractCache`` (``cache``) — keyed by (feed, variant,
  signature bucket): novel frames become keyframe entries whose extract
  outputs answer subsequent near-duplicates without a forward.  A
  configurable **revalidation budget** sends every Nth hit through the
  model anyway and *compares*: hit/miss/revalidation/mismatch rates are
  measured, never assumed, so semantic drift (the scene changed but the
  signature did not) is detected instead of silently corrupting answers.

* ``AdmissionController`` (``admission``) — tunes the similarity
  threshold per feed online: when the revalidation mismatch rate crosses
  the configured accuracy budget the threshold tightens sharply (fewer
  frames admitted to the cache path), and it recovers slowly — never past
  the configured base — while revalidations keep coming back clean.

* ``SemanticGate`` (``gate``) — the facade the serving tier talks to:
  ``admit(feed, variant, frames)`` returns an ``Admission`` that splits a
  batch into model rows and cache rows, and later assembles the combined
  per-task predictions once the model rows' forward completes (results may
  still be in flight — the gate composes with the pipelined
  dispatch/poll/resume serving protocol).

Gating is *off* by default everywhere (``OpContext.gate is None``), and a
gate configured with ``threshold=0`` is inert: every frame takes the
exact pre-gate path, bitwise.
"""
from repro.semantic.admission import AdmissionController
from repro.semantic.cache import Admission, SemanticExtractCache
from repro.semantic.gate import GateConfig, SemanticGate
from repro.semantic.signature import TemporalSignature
