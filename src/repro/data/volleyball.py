"""Synthetic Volleyball stream (Ibrahim et al. group-activity stand-in).

A *moving* camera (global jitter + slow pan) watches a court with two teams
of colored players and a ball.  Per-frame ground truth: the group action
(idle / pass / set / spike), per-player jumping flags, and which team is
attacking — enough to evaluate Q10–Q13.

Dynamics: the ball follows scripted rallies; a player under a descending
high ball "jumps" (y offset); a fast downward ball over the net line is a
spike.  Moving background texture makes frame-differencing much less
informative than in Toll Booth — which is exactly why the paper's semantic
gains are smaller on this stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

ACTIONS = ["idle", "pass", "set", "spike"]
TEAM_RGB = {0: (220, 60, 60), 1: (60, 90, 220)}
N_PER_TEAM = 6


class VolleyballStream:
    def __init__(self, height: int = 128, width: int = 256, fps: int = 25,
                 seed: int = 0):
        self.h, self.w, self.fps = height, width, fps
        self.seed = seed
        self.metadata = {
            "fps": fps,
            "scene": "moving camera, volleyball court, two teams",
        }
        self.reset()

    def reset(self) -> None:
        rs = np.random.RandomState(self.seed)
        self._rs = rs
        self._index = 0
        self._cam = 0.0
        # players: (team, base_x, base_y)
        self._players = []
        for team in (0, 1):
            for i in range(N_PER_TEAM):
                bx = 24 + i * 32 + (8 if team else -8)
                by = 70 + 22 * team + rs.randint(-4, 5)
                self._players.append([team, float(bx), float(by)])
        self._ball = [self.w / 2, 40.0, 2.0, 0.0]  # x, y, vx, vy
        self._phase = "idle"
        self._phase_t = 0

    # ------------------------------------------------------------------
    def _step_dynamics(self) -> Tuple[str, List[bool], int]:
        rs = self._rs
        bx, by, vx, vy = self._ball
        self._phase_t += 1
        action = "idle"
        jumping = [False] * len(self._players)
        attack_team = 0

        if self._phase == "idle" and rs.rand() < 0.08:
            self._phase = "pass"
            self._phase_t = 0
            vy = -3.0
            vx = 2.0 * (1 if rs.rand() < 0.5 else -1)
        elif self._phase == "pass" and self._phase_t > 8:
            self._phase = "set"
            self._phase_t = 0
            vy = -4.0
        elif self._phase == "set" and self._phase_t > 10:
            self._phase = "spike"
            self._phase_t = 0
            vy = 6.0
            vx = 3.0 * (1 if vx > 0 else -1)
        elif self._phase == "spike" and self._phase_t > 6:
            self._phase = "idle"
            self._phase_t = 0
            vy = 0.0
            vx = 1.0

        action = self._phase
        # gravity-ish
        if self._phase in ("pass", "set"):
            vy += 0.3
        bx += vx
        by += vy
        if bx < 10 or bx > self.w - 10:
            vx = -vx
        by = float(np.clip(by, 16, 100))
        self._ball = [bx, by, vx, vy]

        attack_team = 0 if vx > 0 else 1
        # players near a high ball jump during set/spike
        for idx, (team, px, py) in enumerate(self._players):
            if self._phase in ("set", "spike") and abs(px - bx) < 24 \
                    and team == attack_team:
                jumping[idx] = True
        return action, jumping, attack_team

    def _render(self, jumping: List[bool]) -> np.ndarray:
        rs = self._rs
        self._cam += rs.randn() * 1.5 + 0.2          # pan + jitter
        cam = int(round(self._cam)) % 32
        frame = np.zeros((3, self.h, self.w), np.uint8)
        # moving textured background (stands)
        xs = (np.arange(self.w) + cam)
        tex = (40 + 30 * ((xs // 16) % 2)).astype(np.uint8)
        frame[:, : self.h // 3, :] = tex[None, None, :]
        frame[:, self.h // 3:, :] = 120                      # court
        net_x = self.w // 2 + (cam % 5) - 2
        frame[:, 40:100, net_x:net_x + 2] = 220              # net
        for idx, (team, px, py) in enumerate(self._players):
            x = int(px) + cam // 2
            y = int(py) - (8 if jumping[idx] else 0)
            rgb = TEAM_RGB[team]
            x0, x1 = max(0, x - 4), min(self.w, x + 4)
            y0, y1 = max(0, y - 8), min(self.h, y + 8)
            for c in range(3):
                frame[c, y0:y1, x0:x1] = rgb[c]
        bx, by = int(self._ball[0]), int(self._ball[1])
        frame[:, max(0, by - 3):by + 3, max(0, bx - 3):bx + 3] = 250
        noise = rs.randint(0, 8, frame.shape).astype(np.uint8)
        return frame + noise

    # ------------------------------------------------------------------
    def next_frame(self) -> Tuple[np.ndarray, Dict]:
        action, jumping, attack_team = self._step_dynamics()
        frame = self._render(jumping)
        label = {
            "index": self._index,
            "action": action,
            "n_jumping": int(sum(jumping)),
            "attack_team": attack_team,
            "car_present": True,  # uniform key so shared code paths work
        }
        self._index += 1
        return frame, label

    def batch(self, n: int) -> Tuple[np.ndarray, List[Dict]]:
        frames, labels = [], []
        for _ in range(n):
            f, l = self.next_frame()
            frames.append(f)
            labels.append(l)
        return np.stack(frames), labels
