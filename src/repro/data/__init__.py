from repro.data.tollbooth import TollBoothStream, COLORS, BRANDS, PLATE_CHARS
from repro.data.volleyball import VolleyballStream, ACTIONS
