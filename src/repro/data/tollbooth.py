"""Synthetic Toll Booth stream (Linear-Road-inspired, Rodosol-ALPR stand-in).

A fixed camera watches a toll lane.  Cars (colored rectangles with a brand
stripe pattern and a rendered license plate) enter from the left, drive
through the lower half of the frame, and exit right.  Every frame carries
full ground-truth labels, which is what lets us measure the paper's
query-level accuracy offline (the real paper uses an annotated dataset).

Frame layout (channels-first uint8, default 128×256):
  rows   0- 63 : background (sky/booth) — irrelevant to all queries
  rows  64-127 : road; cars occupy rows ~72-120
The car body carries `n_stripes(brand)` vertical dark stripes; the plate is
a white 14×66 box at the car's rear with 6 glyphs from a 3×5 bitmap font.

Stream metadata mirrors the paper's reasoning inputs: fps, v_max, lane
geometry — the semantic optimizer's "world knowledge" measurements have
ground truth to be checked against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

COLORS = ["red", "blue", "green", "white", "black", "yellow"]
COLOR_RGB = {
    "red": (200, 30, 30),
    "blue": (30, 60, 200),
    "green": (30, 170, 60),
    "white": (230, 230, 230),
    "black": (25, 25, 25),
    "yellow": (220, 210, 40),
}
BRANDS = ["astra", "bolt", "cresta", "dyno", "evora", "falcon"]
PLATE_CHARS = "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789"

# 3x5 bitmap font (per char: 5 rows of 3 bits)
_FONT = {
    "A": "010101111101101", "B": "110101110101110", "C": "011100100100011",
    "D": "110101101101110", "E": "111100110100111", "F": "111100110100100",
    "G": "011100101101011", "H": "101101111101101", "I": "111010010010111",
    "J": "001001001101010", "K": "101110100110101", "L": "100100100100111",
    "M": "101111111101101", "N": "101111111111101", "O": "010101101101010",
    "P": "110101110100100", "Q": "010101101011001", "R": "110101110110101",
    "S": "011100010001110", "T": "111010010010010", "U": "101101101101111",
    "V": "101101101101010", "W": "101101111111101", "X": "101010010010101",
    "Y": "101101010010010", "Z": "111001010100111",
    "0": "010101101101010", "1": "010110010010111", "2": "110001010100111",
    "3": "110001010001110", "4": "101101111001001", "5": "111100110001110",
    "6": "011100110101010", "7": "111001010010010", "8": "010101010101010",
    "9": "010101011001110",
}

CAR_H, CAR_W = 44, 88
CAR_Y = 72                     # top row of every car (fixed lane)
PLATE_H, PLATE_W = 19, 84
GLYPH_SCALE = 3                # glyph stroke width in px
# cars brake at the booth; plates are "readable" only in this x-band
# (real ALPR trigger-line semantics — also what makes a fixed-position
# readout learnable by the small stream MLLM)
READ_ZONE = (78.0, 98.0)
ZONE_SLOWDOWN = 0.35


@dataclasses.dataclass
class Car:
    x: float                   # left edge (can be negative / beyond W)
    speed: float               # px / frame
    color: str
    brand: str
    plate: str


class TollBoothStream:
    """Deterministic, seekable frame stream with labels."""

    def __init__(self, height: int = 128, width: int = 256, fps: int = 30,
                 car_rate: float = 0.009, seed: int = 0,
                 v_max_kmh: float = 30.0, stolen_plate_prefix: str = "MTT",
                 stolen_rate: float = 0.15, repeat_rate: float = 0.25):
        self.h, self.w, self.fps = height, width, fps
        self.seed = seed
        self.car_rate = car_rate
        self.v_max_kmh = v_max_kmh
        self.stolen_prefix = stolen_plate_prefix
        self.stolen_rate = stolen_rate
        self.repeat_rate = repeat_rate
        self._past_cars: List[Tuple[str, str, str]] = []
        self.metadata = {
            "fps": fps, "v_max_kmh": v_max_kmh,
            "scene": "fixed camera, toll lane, cars left-to-right",
        }
        self._cars: List[Car] = []
        self._rs = np.random.RandomState(seed)
        self._index = 0

    # ------------------------------------------------------------------
    def reset(self, seed: Optional[int] = None) -> None:
        self._cars = []
        self._past_cars = []
        self._rs = np.random.RandomState(self.seed if seed is None else seed)
        self._index = 0

    def _new_car(self) -> Car:
        rs = self._rs
        # a known car returns (enables Q7 repeated-car detection)
        if self._past_cars and rs.rand() < self.repeat_rate:
            color, brand, plate = self._past_cars[
                rs.randint(len(self._past_cars))]
            speed = 4.0 + 3.0 * rs.rand()
            return Car(x=-CAR_W - 1.0, speed=speed, color=color, brand=brand,
                       plate=plate)
        color = COLORS[rs.randint(len(COLORS))]
        brand = BRANDS[rs.randint(len(BRANDS))]
        if rs.rand() < self.stolen_rate:
            prefix = self.stolen_prefix
            color = "red"
        else:
            prefix = "".join(PLATE_CHARS[rs.randint(26)] for _ in range(3))
            # avoid accidental stolen prefix
            if prefix == self.stolen_prefix:
                prefix = "AAA"
        digits = "".join(str(rs.randint(10)) for _ in range(3))
        plate = prefix + digits
        speed = 4.0 + 3.0 * rs.rand()          # px/frame
        self._past_cars.append((color, brand, plate))
        return Car(x=-CAR_W - 1.0, speed=speed, color=color, brand=brand,
                   plate=plate)

    # ------------------------------------------------------------------
    def _render_car(self, frame: np.ndarray, car: Car) -> None:
        x0 = int(round(car.x))
        x1 = x0 + CAR_W
        vx0, vx1 = max(0, x0), min(self.w, x1)
        if vx1 <= vx0:
            return
        y0, y1 = CAR_Y, CAR_Y + CAR_H
        rgb = COLOR_RGB[car.color]
        for c in range(3):
            frame[c, y0:y1, vx0:vx1] = rgb[c]
        # brand stripes: n+1 dark vertical stripes on the roof
        n_stripes = BRANDS.index(car.brand) + 1
        stripe_w = 4
        gap = (CAR_W - 16) // max(n_stripes, 1)
        for s in range(n_stripes):
            sx0 = x0 + 8 + s * gap
            sx1 = sx0 + stripe_w
            svx0, svx1 = max(0, sx0), min(self.w, sx1)
            if svx1 > svx0:
                frame[:, y0 + 4:y0 + 12, svx0:svx1] = 10
        # plate: white box with black glyphs at the rear (left) of the car
        px0 = x0 + 2
        py0 = y0 + CAR_H - PLATE_H - 2
        pvx0, pvx1 = max(0, px0), min(self.w, px0 + PLATE_W)
        if pvx1 > pvx0:
            frame[:, py0:py0 + PLATE_H, pvx0:pvx1] = 245
        # glyphs: 3x5 font at GLYPH_SCALE => 9x15 per char, 14px pitch
        g = GLYPH_SCALE
        for ci, ch in enumerate(car.plate):
            bits = _FONT[ch]
            gx0 = px0 + 2 + ci * (3 * g + 5)
            gy0 = py0 + 2
            for r in range(5):
                for cc in range(3):
                    if bits[r * 3 + cc] == "1":
                        yy0, yy1 = gy0 + r * g, gy0 + (r + 1) * g
                        xx0, xx1 = gx0 + cc * g, gx0 + (cc + 1) * g
                        xx0c, xx1c = max(0, xx0), min(self.w, xx1)
                        if xx1c > xx0c:
                            frame[:, yy0:yy1, xx0c:xx1c] = 5

    def _background(self) -> np.ndarray:
        frame = np.zeros((3, self.h, self.w), np.uint8)
        frame[:, : self.h // 2] = 150                     # sky
        frame[0, : self.h // 2] = 140
        frame[2, : self.h // 2] = 170
        frame[:, self.h // 2:] = 90                       # road
        # lane markings
        frame[:, self.h - 8: self.h - 6, :] = 180
        # per-frame sensor noise
        noise = self._rs.randint(0, 6, frame.shape).astype(np.uint8)
        return frame + noise

    # ------------------------------------------------------------------
    def next_frame(self) -> Tuple[np.ndarray, Dict]:
        rs = self._rs
        # spawn
        if rs.rand() < self.car_rate and (
                not self._cars or self._cars[-1].x > 60):
            self._cars.append(self._new_car())
        # move (cars brake inside the booth read zone)
        for car in self._cars:
            in_zone = READ_ZONE[0] - 10 <= car.x <= READ_ZONE[1] + 4
            car.x += car.speed * (ZONE_SLOWDOWN if in_zone else 1.0)
        self._cars = [c for c in self._cars if c.x < self.w + 2]

        frame = self._background()
        visible = []
        for car in self._cars:
            if car.x + CAR_W > 0 and car.x < self.w:
                self._render_car(frame, car)
                visible.append(car)
        readable = [c for c in visible
                    if READ_ZONE[0] <= c.x <= READ_ZONE[1]]
        main = readable[0] if readable else None
        label = {
            "index": self._index,
            "car_present": bool(visible),
            "car_readable": main is not None,
            "color": main.color if main else None,
            "brand": main.brand if main else None,
            "plate": main.plate if main else None,
            "stolen": bool(main and main.color == "red"
                           and main.plate.startswith(self.stolen_prefix)),
            "n_cars": len(visible),
        }
        self._index += 1
        return frame, label

    def batch(self, n: int) -> Tuple[np.ndarray, List[Dict]]:
        frames, labels = [], []
        for _ in range(n):
            f, l = self.next_frame()
            frames.append(f)
            labels.append(l)
        return np.stack(frames), labels

    def booth_batch(self, n: int) -> Tuple[np.ndarray, List[Dict]]:
        """Dense training batch: every frame has one car inside the read
        zone (the supervised 'booth shot' distribution — used only for
        operator-model training, never for query evaluation)."""
        rs = self._rs
        frames, labels = [], []
        for _ in range(n):
            car = self._new_car()
            car.x = READ_ZONE[0] + rs.rand() * (READ_ZONE[1] - READ_ZONE[0])
            frame = self._background()
            self._render_car(frame, car)
            frames.append(frame)
            labels.append({
                "index": -1, "car_present": True, "car_readable": True,
                "color": car.color, "brand": car.brand, "plate": car.plate,
                "stolen": car.color == "red"
                and car.plate.startswith(self.stolen_prefix),
                "n_cars": 1,
            })
        return np.stack(frames), labels
