"""Deterministic, schedule-driven fault injection.

A ``FaultInjector`` is a *pure schedule*, not a chaos monkey: every fault
it fires is a function of (site, feed, event index, attempt index) plus
the injector's seed — never the wall clock — so a faulted run is exactly
reproducible, and the contract tests can assert bitwise properties of
what survives the faults.

Sites and kinds
---------------
``source`` — the feed's ingest path, one *event* per attempted pull:

  * ``stall``   — the feed produces nothing this scheduling round (pure
    delay; no frames are lost).  A stall consumes its event: the round
    is skipped and the feed's next turn draws the next event.
  * ``corrupt`` — the pulled frames arrive damaged on the transport
    (NaN-poisoned copy; the stream itself stays pristine).  ``param`` is
    the number of consecutive delivery *attempts* that fail — a value
    larger than the runtime's ingest retry budget models a dead link.

``forward`` — the shared extract server's device forwards, one event per
extract request (assigned at enqueue, so retries of one request replay
the same event):

  * ``error``   — the forward raises.  ``param`` = consecutive failing
    attempts (``param=1``: the first launch fails, the retry succeeds;
    a large ``param`` models a poisoned input that never succeeds).
  * ``latency`` — the forward completes but its completion is observed
    ``param`` polls late (clock-free artificial device latency).

Event indices are per ``(site, feed)`` and assigned by the serving
runtime via ``next_event`` exactly once per pull / per request, so the
schedule is stable under retries, coalescing and scheduling jitter.
``fault_at`` is side-effect free — probes may *peek* at a future event
without consuming it.  Probabilistic rules (``p < 1``) draw from a hash
of (seed, rule index, event index), not from a shared RNG stream, so
they too are independent of feed interleaving.

``NULL_FAULTS`` is the inert default: ``enabled`` is False and every
call site guards with ``if faults.enabled:`` (the ``NULL_OBS`` idiom),
so the un-faulted stack stays bitwise identical to a build without this
package.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

import numpy as np

SITES = ("source", "forward")
KINDS = ("stall", "corrupt", "error", "latency")

_SITE_KINDS = {
    "source": ("stall", "corrupt"),
    "forward": ("error", "latency"),
}


@dataclasses.dataclass
class FaultRule:
    """One line of a fault schedule.

    The rule fires on events ``start, start+every, start+2*every, ...``
    of its site, at most ``count`` times (``count=-1``: forever),
    filtered to one ``feed`` / ``variant`` ("" matches all), each firing
    gated by probability ``p`` (deterministic per event, see module
    docs).  ``param`` is kind-specific: consecutive failing attempts for
    ``corrupt``/``error``, delay polls for ``latency``; ignored for
    ``stall``."""

    site: str
    kind: str
    feed: str = ""
    variant: str = ""
    start: int = 0
    every: int = 1
    count: int = -1
    p: float = 1.0
    param: int = 1

    def __post_init__(self):
        assert self.site in SITES, self.site
        assert self.kind in _SITE_KINDS[self.site], \
            f"kind {self.kind!r} invalid for site {self.site!r}"
        assert self.every >= 1 and self.start >= 0
        assert 0.0 <= self.p <= 1.0
        assert self.param >= 1

    def matches(self, site: str, feed: str, variant: str,
                event: int) -> bool:
        if site != self.site:
            return False
        if self.feed and feed != self.feed:
            return False
        if self.variant and variant and variant != self.variant:
            return False
        if event < self.start or (event - self.start) % self.every:
            return False
        if self.count >= 0 and \
                (event - self.start) // self.every >= self.count:
            return False
        return True


class FaultInjector:
    """A seeded fault schedule (see module docs).  Thread the instance
    through ``OpContext.faults`` / ``SharedExtractServer(faults=...)`` /
    ``MultiStreamRuntime(faults=...)``; the inert ``NULL_FAULTS`` is the
    default everywhere."""

    enabled = True

    def __init__(self, rules: Optional[List[FaultRule]] = None,
                 seed: int = 0):
        self.rules = list(rules or [])
        self.seed = seed
        #: monotonic event counters per (site, feed) — the runtime draws
        #: one per pull attempt (source) / per extract request (forward)
        self._events: Dict[Tuple[str, str], int] = {}
        #: every fault actually fired, for determinism tests and the
        #: fault-timeline trace: dicts with site/kind/feed/event/attempt
        self.log: List[Dict] = []

    # ------------------------------------------------------------------
    def next_event(self, site: str, feed: str) -> int:
        """Consume and return the next event index for (site, feed)."""
        key = (site, feed)
        e = self._events.get(key, 0)
        self._events[key] = e + 1
        return e

    def peek_event(self, site: str, feed: str) -> int:
        """The event index ``next_event`` would return — side-effect
        free (circuit-breaker probes peek at the schedule the feed's
        next real pull will face)."""
        return self._events.get((site, feed), 0)

    def _roll(self, rule_idx: int, event: int, p: float) -> bool:
        if p >= 1.0:
            return True
        # hash-seeded draw: independent of feed interleaving / retries
        return random.Random(
            f"{self.seed}:{rule_idx}:{event}").random() < p

    def fault_at(self, site: str, feed: str, variant: str, event: int,
                 attempt: int = 0) -> Optional[Tuple[str, int]]:
        """The fault (kind, param) active for this event/attempt, or
        None.  Pure function of the schedule — calling it never advances
        state; pass ``record=True`` work to ``fire`` instead."""
        for i, rule in enumerate(self.rules):
            if not rule.matches(site, feed, variant, event):
                continue
            if not self._roll(i, event, rule.p):
                continue
            if rule.kind in ("corrupt", "error") and \
                    attempt >= rule.param:
                continue          # this attempt survives: fault cleared
            return rule.kind, rule.param
        return None

    def fire(self, site: str, feed: str, variant: str, event: int,
             attempt: int = 0) -> Optional[Tuple[str, int]]:
        """``fault_at`` + append to the fault log when a fault fires."""
        f = self.fault_at(site, feed, variant, event, attempt)
        if f is not None:
            self.log.append({"site": site, "kind": f[0], "feed": feed,
                             "variant": variant, "event": event,
                             "attempt": attempt})
        return f

    # ------------------------------------------------------------------
    def transport(self, feed: str, frames: np.ndarray, event: int,
                  attempt: int = 0) -> np.ndarray:
        """One delivery attempt of a pulled batch over the (simulated)
        transport: returns the frames, NaN-poisoned in a *copy* when the
        schedule corrupts this attempt — the stream's own data is never
        touched, so a later attempt (or a replay) sees pristine frames."""
        f = self.fire("source", feed, "", event, attempt)
        if f is None or f[0] != "corrupt":
            return frames
        # integer frame buffers can't hold NaN — the corrupted delivery
        # is promoted to float32 (harmless: validation rejects it and a
        # cleared attempt returns the original array, bitwise)
        bad = np.array(frames, copy=True, dtype=np.float32) \
            if not np.issubdtype(frames.dtype, np.floating) \
            else np.array(frames, copy=True)
        bad.reshape(-1)[:: max(1, bad.size // 16)] = np.nan
        return bad

    @staticmethod
    def delivered_ok(frames: np.ndarray) -> bool:
        """Ingest validation: a corrupt delivery is always detectable
        (NaN-poisoned, float dtype), so validation is a finite-ness
        check — trivially true for integer payloads."""
        if not np.issubdtype(frames.dtype, np.floating):
            return True
        return bool(np.isfinite(frames).all())


class _NullFaultInjector(FaultInjector):
    """Inert default: no schedule, no state, no log — ``enabled`` False
    lets every call site skip fault logic entirely."""

    enabled = False

    def __init__(self) -> None:
        super().__init__([], 0)

    def next_event(self, site: str, feed: str) -> int:
        return 0

    def fault_at(self, site: str, feed: str, variant: str, event: int,
                 attempt: int = 0) -> Optional[Tuple[str, int]]:
        return None


NULL_FAULTS = _NullFaultInjector()


def resolve_faults(*candidates) -> FaultInjector:
    """First non-None injector among ``candidates``, else NULL_FAULTS —
    the lookup rule every component uses (explicit arg outranks context,
    context outranks the inert default)."""
    for c in candidates:
        if c is not None:
            return c
    return NULL_FAULTS
