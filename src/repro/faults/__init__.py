"""Fault-tolerant serving: deterministic injection + resilience machinery.

Production streams fail — cameras stall, frames arrive corrupt, device
forwards hang or error — and before this package a single exception
anywhere in the serving tier killed every feed in the fleet.  The pieces:

* ``FaultInjector`` / ``FaultRule`` (``injector``) — a seeded,
  schedule-driven, clock-free fault source: source stalls, corrupt
  deliveries, extract-forward errors and artificial forward latency at
  named sites, reproducible event-for-event.  ``NULL_FAULTS`` is the
  inert default threaded through ``OpContext.faults``.

* ``CircuitBreaker`` (``breaker``) — the per-feed open → half-open →
  closed quarantine state machine ``MultiStreamRuntime`` drives, with
  round-counted, exponentially-doubling cooldowns.

* ``RetryPolicy`` + the error types — bounded retry with exponential
  backoff on extract forwards (``SharedExtractServer``), the
  ``ExtractStallError`` watchdog for ``wait()``/``drain()``, and
  ``SourceFaultError`` for ingest retry exhaustion.

* ``guard_stream`` — transport validation + bounded redelivery retries
  for the solo ``StreamRuntime`` ingest path.

The serving contract the tests enforce: frames reported *served* are
bitwise identical to a fault-free run, no frame is served twice,
served + degraded + dropped exactly partitions ingested frames, and
with ``NULL_FAULTS`` the stack is bitwise identical to a build without
this package.
"""
from __future__ import annotations

import dataclasses

from repro.faults.breaker import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.faults.injector import (
    FaultInjector,
    FaultRule,
    NULL_FAULTS,
    resolve_faults,
)


class FaultError(RuntimeError):
    """Base of every error the fault-tolerance tier raises."""


class SourceFaultError(FaultError):
    """Ingest retries exhausted: a feed's transport kept delivering
    corrupt frames past the retry budget."""


class ExtractFaultError(FaultError):
    """An extract request failed past its retry budget (its ``failed``
    flag is set; accessing its result raises this)."""


class ExtractStallError(FaultError):
    """The ``wait()``/``drain()`` watchdog: no progress (no launch, no
    retirement) for ``drain_timeout_s`` — names the stuck chunk/bucket
    instead of spinning forever."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff for extract forwards.

    A failed in-flight chunk's requests stay queued and relaunch
    *isolated* (one request per chunk, so a poisoned feed's frames never
    exhaust chunk-mates' budgets).  Backoff is counted in dispatch
    rounds — ``backoff_base * 2**(attempt-1)`` rounds before a request
    is eligible again — keeping retry timing as deterministic as the
    fault schedule.  After ``max_attempts`` total attempts the request
    is terminally ``failed`` (the runtime's circuit breaker takes over).
    """

    max_attempts: int = 3
    backoff_base: int = 1

    def __post_init__(self):
        assert self.max_attempts >= 1 and self.backoff_base >= 0

    def backoff_rounds(self, attempt: int) -> int:
        return self.backoff_base * (2 ** max(attempt - 1, 0))


class _GuardedStream:
    """A stream wrapped in transport validation + bounded redelivery
    (the solo ``StreamRuntime`` ingest path; the multi-stream runtime
    inlines the same protocol per feed).  Stalls are meaningless without
    a scheduler to skip rounds, so only ``corrupt`` rules apply here."""

    def __init__(self, stream, faults: FaultInjector, feed: str,
                 retries: int = 2):
        self._stream = stream
        self._faults = faults
        self._feed = feed
        self._retries = retries

    def batch(self, n: int):
        frames, labels = self._stream.batch(n)
        fi = self._faults
        event = fi.next_event("source", self._feed)
        for attempt in range(self._retries + 1):
            got = fi.transport(self._feed, frames, event, attempt)
            if fi.delivered_ok(got):
                return got, labels
        raise SourceFaultError(
            f"feed {self._feed!r}: corrupt delivery survived "
            f"{self._retries + 1} attempts (source event {event})")

    def __getattr__(self, name):
        return getattr(self._stream, name)


def guard_stream(stream, faults, feed: str = "stream", retries: int = 2):
    """Wrap ``stream`` with transport-fault validation and bounded
    redelivery when ``faults`` is enabled; returns the stream unchanged
    otherwise (zero overhead on the fault-free path)."""
    faults = resolve_faults(faults)
    if not faults.enabled:
        return stream
    return _GuardedStream(stream, faults, feed, retries)


__all__ = [
    "CLOSED", "CircuitBreaker", "ExtractFaultError", "ExtractStallError",
    "FaultError", "FaultInjector", "FaultRule", "HALF_OPEN", "NULL_FAULTS",
    "OPEN", "RetryPolicy", "SourceFaultError", "guard_stream",
    "resolve_faults",
]
