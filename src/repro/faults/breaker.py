"""Per-feed circuit breaker: closed → open → half-open → closed.

The breaker quarantines one failing feed so the rest of the fleet keeps
serving: on a trip (ingest retries exhausted, or an extract request that
failed past its retry budget) the feed stops submitting work for
``cooldown`` scheduling rounds — frames it ingests meanwhile are
degraded or dropped with exact accounting, never served.  After the
cooldown the breaker goes *half-open*: the runtime sends one probe
(transport peek + an isolated canary extract); success closes the
breaker (the feed re-admits by replaying from its last snapshot),
failure re-opens it with the cooldown doubled up to ``max_cooldown``.

Cooldowns are counted in the feed's own scheduling *rounds*, not wall
time, so breaker behavior is as deterministic as the fault schedule
driving it.
"""
from __future__ import annotations

from typing import Dict

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    """State machine for one feed (see module docs)."""

    def __init__(self, cooldown: int = 4, max_cooldown: int = 64):
        assert cooldown >= 1
        self.base_cooldown = cooldown
        self.max_cooldown = max(max_cooldown, cooldown)
        self.cooldown = cooldown
        self.state = CLOSED
        self.rounds_left = 0
        self.counters: Dict[str, int] = {
            "trips": 0, "probes": 0, "probe_failures": 0, "recoveries": 0}

    @property
    def closed(self) -> bool:
        return self.state == CLOSED

    def trip(self, reason: str = "") -> None:
        """Open the circuit (idempotent while already open)."""
        if self.state != OPEN:
            self.counters["trips"] += 1
        self.state = OPEN
        self.rounds_left = self.cooldown
        self.last_reason = reason

    def tick(self) -> None:
        """One quarantined scheduling round; transitions open →
        half-open when the cooldown expires."""
        if self.state == OPEN:
            self.rounds_left -= 1
            if self.rounds_left <= 0:
                self.state = HALF_OPEN

    @property
    def should_probe(self) -> bool:
        return self.state == HALF_OPEN

    def probe_failed(self) -> None:
        """Back to open, cooldown doubled (capped)."""
        self.counters["probes"] += 1
        self.counters["probe_failures"] += 1
        self.cooldown = min(self.cooldown * 2, self.max_cooldown)
        self.state = OPEN
        self.rounds_left = self.cooldown

    def close(self) -> None:
        """Probe succeeded: resume serving, cooldown reset to base."""
        self.counters["probes"] += 1
        self.counters["recoveries"] += 1
        self.cooldown = self.base_cooldown
        self.state = CLOSED
        self.rounds_left = 0
