"""Mamba2 (SSD — state-space duality) mixer, TPU-adapted.

Chunked SSD: within-chunk terms are batched matmuls (MXU-friendly); the
inter-chunk state recurrence is a short ``lax.scan`` over chunks.  Decode is
a single recurrent step on an O(1) state — which is why SSM/hybrid archs are
the ones that run the ``long_500k`` cell.

Heads are padded to a multiple of the TP degree; padded heads are zeroed at
the x-projection, which makes them exact no-ops end-to-end (state stays 0,
y stays 0, gradients to padded params stay 0).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import SSMConfig
from repro.common.sharding import shard_constraint
from repro.common.utils import pad_to_multiple, scan_unroll
from repro.models.layers import rms_norm_simple
from repro.models.param import ParamSpec


def ssm_dims(d_model: int, ssm: SSMConfig, tp: int = 1) -> Tuple[int, int]:
    """(true head count, tp-padded head count)."""
    d_inner = d_model * ssm.expand
    h = d_inner // ssm.head_dim
    return h, pad_to_multiple(h, tp)


def mamba_spec(d_model: int, ssm: SSMConfig, tp: int) -> Dict[str, ParamSpec]:
    h, h_p = ssm_dims(d_model, ssm, tp)
    p, n, g, k = ssm.head_dim, ssm.d_state, ssm.n_groups, ssm.d_conv
    return {
        "z_proj": ParamSpec((d_model, h_p, p), ("fsdp", "ssm_heads", None)),
        "x_proj": ParamSpec((d_model, h_p, p), ("fsdp", "ssm_heads", None)),
        "B_proj": ParamSpec((d_model, g, n), ("fsdp", None, "ssm_state")),
        "C_proj": ParamSpec((d_model, g, n), ("fsdp", None, "ssm_state")),
        "dt_proj": ParamSpec((d_model, h_p), ("fsdp", "ssm_heads"), "small"),
        "dt_bias": ParamSpec((h_p,), ("ssm_heads",), "zeros"),
        "A_log": ParamSpec((h_p,), ("ssm_heads",), "zeros"),
        "D": ParamSpec((h_p,), ("ssm_heads",), "ones"),
        "conv_w_x": ParamSpec((h_p, p, k), ("ssm_heads", None, "conv"), "small"),
        "conv_b_x": ParamSpec((h_p, p), ("ssm_heads", None), "zeros"),
        "conv_w_B": ParamSpec((g, n, k), (None, "ssm_state", "conv"), "small"),
        "conv_b_B": ParamSpec((g, n), (None, "ssm_state"), "zeros"),
        "conv_w_C": ParamSpec((g, n, k), (None, "ssm_state", "conv"), "small"),
        "conv_b_C": ParamSpec((g, n), (None, "ssm_state"), "zeros"),
        "norm_scale": ParamSpec((h_p, p), ("ssm_heads", None), "ones"),
        "out_proj": ParamSpec((h_p, p, d_model), ("ssm_heads", None, "fsdp")),
    }


def _head_mask(h: int, h_p: int, dtype) -> Optional[jax.Array]:
    if h == h_p:
        return None
    m = np.zeros((h_p,), np.float32)
    m[:h] = 1.0
    return jnp.asarray(m, dtype)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv as K shifted adds.

    x (B, L, C1, C2), w (C1, C2, K), b (C1, C2).
    """
    k = w.shape[-1]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        if shift == 0:
            xi = x
        else:
            xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[..., i]
    return out + b


def _ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int,
                 init_state: Optional[jax.Array] = None):
    """Exact chunked SSD.

    x (b,l,h,p)  dt (b,l,h) fp32  A (h,) fp32  Bm/Cm (b,l,g,n)  D (h,)
    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    nc = l // chunk
    q = chunk
    rep = h // g

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bh = jnp.repeat(Bm.reshape(b, nc, q, g, n), rep, axis=3)  # (b,nc,q,h,n)
    Ch = jnp.repeat(Cm.reshape(b, nc, q, g, n), rep, axis=3)

    dA = dtc * A                                     # (b,nc,q,h) — negative
    cs = jnp.cumsum(dA, axis=2)                      # inclusive cumsum
    total = cs[:, :, -1, :]                          # (b,nc,h)

    # ---- within-chunk (quadratic in q, MXU matmuls) ----
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (b,nc,i,j,h)
    causal = jnp.tril(jnp.ones((q, q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Ch.astype(jnp.float32),
                    Bh.astype(jnp.float32))
    w_mat = cb * Lmat * dtc[:, :, None, :, :]            # (b,nc,i,j,h)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w_mat, xc.astype(jnp.float32))

    # ---- end-of-chunk local states ----
    decay_end = jnp.exp(total[:, :, None, :] - cs)        # (b,nc,q,h)
    s_local = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp",
                         decay_end * dtc, Bh.astype(jnp.float32),
                         xc.astype(jnp.float32))          # (b,nc,h,n,p)

    # ---- inter-chunk recurrence ----
    if init_state is None:
        s0 = jnp.zeros((b, h, n, p), jnp.float32)
    else:
        s0 = jnp.swapaxes(init_state.astype(jnp.float32), -1, -2)

    def scan_fn(s_prev, inp):
        tot_c, s_loc = inp  # (b,h), (b,h,n,p)
        s_out = jnp.exp(tot_c)[:, :, None, None] * s_prev + s_loc
        return s_out, s_prev

    # NOTE: deliberately not unrolled under REPRO_UNROLL_SCANS — the state
    # recurrence body is O(b·h·n·p) (negligible vs the batched within-chunk
    # einsums outside this scan), and unrolling nc=2048 bodies would explode
    # compile time for a <0.1% FLOP correction.
    s_final, s_ins = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(s_local, 1, 0)))
    s_in = jnp.moveaxis(s_ins, 0, 1)                      # (b,nc,h,n,p)

    # ---- cross-chunk contribution ----
    c_decay = Ch.astype(jnp.float32) * jnp.exp(cs)[..., None]  # (b,nc,q,h,n)
    y_off = jnp.einsum("bcqhn,bchnp->bcqhp", c_decay, s_in)

    y = (y_diag + y_off).reshape(b, l, h, p)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    final_state = jnp.swapaxes(s_final, -1, -2)           # (b,h,p,n)
    return y.astype(x.dtype), final_state.astype(x.dtype)


def _project_and_conv(params, ssm: SSMConfig, x: jax.Array):
    """Shared projection+conv for prefill paths. x (B,L,d)."""
    dtype = x.dtype
    d = x.shape[-1]
    h_p = params["A_log"].shape[0]
    h_true, _ = ssm_dims(d, ssm)

    z = jnp.einsum("bld,dhp->blhp", x, params["z_proj"].astype(dtype))
    xs0 = jnp.einsum("bld,dhp->blhp", x, params["x_proj"].astype(dtype))
    Bm0 = jnp.einsum("bld,dgn->blgn", x, params["B_proj"].astype(dtype))
    Cm0 = jnp.einsum("bld,dgn->blgn", x, params["C_proj"].astype(dtype))
    dt = jnp.einsum("bld,dh->blh", x, params["dt_proj"].astype(dtype))

    hm = _head_mask(h_true, h_p, dtype)
    if hm is not None:
        xs0 = xs0 * hm[None, None, :, None]
    xs0 = shard_constraint(xs0, "batch", "seq", "ssm_heads", None)

    xs = jax.nn.silu(_causal_conv(xs0, params["conv_w_x"].astype(dtype),
                                  params["conv_b_x"].astype(dtype)))
    Bm = jax.nn.silu(_causal_conv(Bm0, params["conv_w_B"].astype(dtype),
                                  params["conv_b_B"].astype(dtype)))
    Cm = jax.nn.silu(_causal_conv(Cm0, params["conv_w_C"].astype(dtype),
                                  params["conv_b_C"].astype(dtype)))
    if hm is not None:
        xs = xs * hm[None, None, :, None]  # re-zero after conv bias

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    return z, xs, Bm, Cm, dt, (xs0, Bm0, Cm0)


def mamba_prefill(params: Dict[str, Any], ssm: SSMConfig, tp: int,
                  x: jax.Array) -> jax.Array:
    """x (B,L,d) -> y (B,L,d). Train / prefill without cache."""
    b, l, d = x.shape
    dtype = x.dtype
    z, xs, Bm, Cm, dt, _ = _project_and_conv(params, ssm, x)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, _ = _ssd_chunked(xs, dt, A, Bm, Cm, params["D"], min(ssm.chunk, l))
    y = rms_norm_simple(y * jax.nn.silu(z), params["norm_scale"])
    out = jnp.einsum("blhp,hpd->bld", y, params["out_proj"].astype(dtype))
    return shard_constraint(out, "batch", "seq", "embed")


def mamba_prefill_with_cache(params, ssm: SSMConfig, tp: int, x: jax.Array):
    """Prefill that also returns a decode-ready cache."""
    b, l, d = x.shape
    dtype = x.dtype
    k = ssm.d_conv
    z, xs, Bm, Cm, dt, (xs0, Bm0, Cm0) = _project_and_conv(params, ssm, x)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, final_state = _ssd_chunked(xs, dt, A, Bm, Cm, params["D"],
                                  min(ssm.chunk, l))
    y = rms_norm_simple(y * jax.nn.silu(z), params["norm_scale"])
    out = jnp.einsum("blhp,hpd->bld", y, params["out_proj"].astype(dtype))
    cache = {
        "ssm": final_state,                     # (B,H,P,N)
        "conv_x": xs0[:, -(k - 1):],            # pre-activation tails
        "conv_B": Bm0[:, -(k - 1):],
        "conv_C": Cm0[:, -(k - 1):],
    }
    return shard_constraint(out, "batch", "seq", "embed"), cache


def mamba_decode_cache_spec(d_model: int, ssm: SSMConfig, tp: int,
                            batch: int) -> Dict[str, Tuple]:
    """Shapes + logical axes of the decode cache (for input_specs)."""
    _, h_p = ssm_dims(d_model, ssm, tp)
    p, n, g, k = ssm.head_dim, ssm.d_state, ssm.n_groups, ssm.d_conv
    return {
        "ssm": ((batch, h_p, p, n), ("batch", "ssm_heads", None, "ssm_state")),
        "conv_x": ((batch, k - 1, h_p, p), ("batch", "conv", "ssm_heads", None)),
        "conv_B": ((batch, k - 1, g, n), ("batch", "conv", None, "ssm_state")),
        "conv_C": ((batch, k - 1, g, n), ("batch", "conv", None, "ssm_state")),
    }


def mamba_decode(params: Dict[str, Any], ssm: SSMConfig, tp: int,
                 x: jax.Array, cache: Dict[str, jax.Array]):
    """Single-step decode. x (B,1,d) -> (y (B,1,d), new cache)."""
    b, _, d = x.shape
    dtype = x.dtype
    h_p = params["A_log"].shape[0]
    h_true, _ = ssm_dims(d, ssm)
    k = ssm.d_conv
    xt = x[:, 0]  # (B,d)

    z = jnp.einsum("bd,dhp->bhp", xt, params["z_proj"].astype(dtype))
    xs0 = jnp.einsum("bd,dhp->bhp", xt, params["x_proj"].astype(dtype))
    Bm0 = jnp.einsum("bd,dgn->bgn", xt, params["B_proj"].astype(dtype))
    Cm0 = jnp.einsum("bd,dgn->bgn", xt, params["C_proj"].astype(dtype))
    dt = jnp.einsum("bd,dh->bh", xt, params["dt_proj"].astype(dtype))

    hm = _head_mask(h_true, h_p, dtype)
    if hm is not None:
        xs0 = xs0 * hm[None, :, None]

    def conv_step(tail, cur, w, bias):
        """tail (B,k-1,...), cur (B,...) -> (conv output, new tail)."""
        full = jnp.concatenate([tail, cur[:, None]], axis=1)  # (B,k,...)
        acc = bias
        for i in range(k):
            acc = acc + full[:, i] * w[..., i]
        return acc, full[:, 1:]

    xs, _ = conv_step(cache["conv_x"], xs0,
                      params["conv_w_x"].astype(dtype),
                      params["conv_b_x"].astype(dtype))
    Bm, _ = conv_step(cache["conv_B"], Bm0,
                      params["conv_w_B"].astype(dtype),
                      params["conv_b_B"].astype(dtype))
    Cm, _ = conv_step(cache["conv_C"], Cm0,
                      params["conv_w_C"].astype(dtype),
                      params["conv_b_C"].astype(dtype))
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    if hm is not None:
        xs = xs * hm[None, :, None]

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)  # (B,H)

    g = ssm.n_groups
    rep = h_p // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)

    state = cache["ssm"].astype(jnp.float32)               # (B,H,P,N)
    state = dA[:, :, None, None] * state + (
        dt[:, :, None, None] * xs.astype(jnp.float32)[..., None]
        * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs.astype(
        jnp.float32)
    y = rms_norm_simple(y.astype(dtype) * jax.nn.silu(z), params["norm_scale"])
    out = jnp.einsum("bhp,hpd->bd", y, params["out_proj"].astype(dtype))
    new_cache = {
        "ssm": state.astype(cache["ssm"].dtype),
        "conv_x": jnp.concatenate([cache["conv_x"][:, 1:], xs0[:, None]], 1),
        "conv_B": jnp.concatenate([cache["conv_B"][:, 1:], Bm0[:, None]], 1),
        "conv_C": jnp.concatenate([cache["conv_C"][:, 1:], Cm0[:, None]], 1),
    }
    return out[:, None, :], new_cache
