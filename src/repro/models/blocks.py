"""Block assembly and scan-over-periods stacks.

A *period* is one repetition of ``cfg.block_pattern`` (e.g. gemma2's
(local, global) pair, jamba's 8-layer mamba/attn/MoE interleave).  Parameters
for all periods are stacked on a leading "layers" axis and the stack is
executed with ``lax.scan`` so compile time and HLO size are O(one period).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, BlockSpecEntry
from repro.common.utils import scan_unroll
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, mlp_spec, norm_spec
from repro.models.param import ParamSpec, stack as stack_specs


# --------------------------------------------------------------------------
# Single block
# --------------------------------------------------------------------------

def block_spec(cfg: ArchConfig, kind: str, tp: int,
               cross_attention: bool = False) -> Dict[str, Any]:
    ent = BlockSpecEntry.parse(kind)
    d = cfg.d_model
    spec: Dict[str, Any] = {"pre_norm": norm_spec(d, cfg.norm)}
    if ent.mixer == "mamba":
        spec["mixer"] = ssm_mod.mamba_spec(d, cfg.ssm, tp)
    else:
        spec["mixer"] = attn.attention_spec(d, cfg.attention, tp)
    if cfg.post_block_norm:
        spec["post_mixer_norm"] = norm_spec(d, cfg.norm)
    if cross_attention:
        spec["cross_norm"] = norm_spec(d, cfg.norm)
        spec["cross"] = attn.attention_spec(d, cfg.attention, tp, cross=True)
    if ent.mlp != "none":
        spec["pre_mlp_norm"] = norm_spec(d, cfg.norm)
        if ent.mlp == "moe":
            spec["mlp"] = moe_mod.moe_spec(d, cfg.moe)
        else:
            spec["mlp"] = mlp_spec(d, cfg.d_ff, cfg.mlp_gated)
        if cfg.post_block_norm:
            spec["post_mlp_norm"] = norm_spec(d, cfg.norm)
    return spec


def block_cache_shapes(cfg: ArchConfig, kind: str, tp: int, batch: int,
                       s_max: int) -> Dict[str, Tuple]:
    """(shape, logical axes) per cache leaf for one block."""
    ent = BlockSpecEntry.parse(kind)
    if ent.mixer == "mamba":
        return ssm_mod.mamba_decode_cache_spec(cfg.d_model, cfg.ssm, tp, batch)
    _, hkv_e, _ = attn.head_layout(cfg.attention, tp)
    d = cfg.attention.head_dim
    return {
        "k": ((batch, s_max, hkv_e, d), ("batch", "kv_seq", "kv_heads", "head_dim")),
        "v": ((batch, s_max, hkv_e, d), ("batch", "kv_seq", "kv_heads", "head_dim")),
    }


def apply_block(cfg: ArchConfig, kind: str, tp: int, params: Dict[str, Any],
                x: jax.Array, *, mode: str, positions: jax.Array,
                cache: Optional[Dict[str, jax.Array]] = None,
                cur_len: Optional[jax.Array] = None,
                cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                q_block: int = 1024):
    """Apply one block.

    mode: "causal" (train/prefill, no cache out) | "prefill_cache"
          | "encode" (bidirectional) | "decode".
    Returns (x, new_cache_or_None, moe_aux).
    """
    ent = BlockSpecEntry.parse(kind)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, jax.Array] = {}

    h = apply_norm(params["pre_norm"], x, cfg.norm)
    if ent.mixer == "mamba":
        if mode == "decode":
            y, mcache = ssm_mod.mamba_decode(params["mixer"], cfg.ssm, tp, h,
                                             cache)
            new_cache = mcache
        elif mode == "prefill_cache":
            y, mcache = ssm_mod.mamba_prefill_with_cache(params["mixer"],
                                                         cfg.ssm, tp, h)
            new_cache = mcache
        else:
            y = ssm_mod.mamba_prefill(params["mixer"], cfg.ssm, tp, h)
    else:
        local = ent.mixer == "attn_local"
        if mode == "decode":
            y, ck, cv = attn.attend_decode(params["mixer"], cfg.attention, tp,
                                           h, cache["k"], cache["v"], cur_len,
                                           local=local)
            new_cache = {"k": ck, "v": cv}
        elif mode == "encode":
            y = attn.attend_encoder(params["mixer"], cfg.attention, tp, h,
                                    positions, q_block=q_block)
        elif mode == "prefill_cache":
            y, (k, v) = attn.attend_prefill(params["mixer"], cfg.attention,
                                            tp, h, positions, local=local,
                                            q_block=q_block, return_kv=True)
            # place prefix into a fresh max-length cache
            s_max = cache["k"].shape[1]
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": ck, "v": cv}
        else:
            y = attn.attend_prefill(params["mixer"], cfg.attention, tp, h,
                                    positions, local=local, q_block=q_block)
    if cfg.post_block_norm:
        y = apply_norm(params["post_mixer_norm"], y, cfg.norm)
    x = x + y

    if "cross" in params:
        h = apply_norm(params["cross_norm"], x, cfg.norm)
        y = attn.attend_cross(params["cross"], cfg.attention, tp, h, cross_kv,
                              q_block=q_block)
        x = x + y

    if ent.mlp != "none":
        h = apply_norm(params["pre_mlp_norm"], x, cfg.norm)
        if ent.mlp == "moe":
            y, aux = moe_mod.apply_moe(params["mlp"], h, cfg.moe,
                                       batch_sharded=x.shape[0] > 1)
        else:
            act = "gelu" if cfg.name.startswith("gemma") else "silu"
            y = apply_mlp(params["mlp"], h, cfg.mlp_gated, act)
        if cfg.post_block_norm:
            y = apply_norm(params["post_mlp_norm"], y, cfg.norm)
        x = x + y
    return x, new_cache, aux


# --------------------------------------------------------------------------
# Period (one repetition of the pattern) and stacks
# --------------------------------------------------------------------------

def period_spec(cfg: ArchConfig, tp: int,
                cross_attention: bool = False) -> Dict[str, Any]:
    return {
        f"i{j}": block_spec(cfg, kind, tp, cross_attention)
        for j, kind in enumerate(cfg.block_pattern)
    }


def stack_spec(cfg: ArchConfig, tp: int, n_periods: Optional[int] = None,
               cross_attention: bool = False) -> Dict[str, Any]:
    n = n_periods if n_periods is not None else cfg.n_periods
    return stack_specs(period_spec(cfg, tp, cross_attention), n)


def period_cache_shapes(cfg: ArchConfig, tp: int, batch: int,
                        s_max: int) -> Dict[str, Any]:
    return {
        f"i{j}": block_cache_shapes(cfg, kind, tp, batch, s_max)
        for j, kind in enumerate(cfg.block_pattern)
    }


def apply_period(cfg: ArchConfig, tp: int, params: Dict[str, Any],
                 x: jax.Array, *, mode: str, positions: jax.Array,
                 cache: Optional[Dict[str, Any]] = None,
                 cur_len: Optional[jax.Array] = None,
                 cross_kv: Optional[Tuple] = None,
                 q_block: int = 1024):
    new_cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    for j, kind in enumerate(cfg.block_pattern):
        key = f"i{j}"
        ck = cross_kv[key] if isinstance(cross_kv, dict) else cross_kv
        x, nc, a = apply_block(
            cfg, kind, tp, params[key], x, mode=mode, positions=positions,
            cache=None if cache is None else cache[key], cur_len=cur_len,
            cross_kv=ck, q_block=q_block)
        new_cache[key] = nc
        aux = aux + a
    return x, new_cache, aux


def apply_stack(cfg: ArchConfig, tp: int, stacked_params: Dict[str, Any],
                x: jax.Array, *, mode: str, positions: jax.Array,
                cache: Optional[Dict[str, Any]] = None,
                cur_len: Optional[jax.Array] = None,
                cross_kv: Optional[Any] = None,
                q_block: int = 1024,
                remat: bool = True):
    """Scan the stacked periods. cache (if given) has leading n_periods dim.

    Returns (x, new_cache (stacked) or None, total moe aux).
    """
    use_cache = cache is not None

    def body(carry, xs):
        xc, aux = carry
        if use_cache:
            p_params, p_cache, p_ckv = xs
        else:
            p_params, p_ckv = xs
            p_cache = None
        xc, new_cache, a = apply_period(
            cfg, tp, p_params, xc, mode=mode, positions=positions,
            cache=p_cache, cur_len=cur_len, cross_kv=p_ckv, q_block=q_block)
        return (xc, aux + a), (new_cache if use_cache or mode == "prefill_cache"
                               else 0)

    if remat:
        import os

        if os.environ.get("REPRO_REMAT_DOTS") == "1":
            # §Perf H4: save matmul outputs inside each period instead of
            # recomputing the whole period in the backward pass — trades
            # HBM headroom for the ~2ND recompute FLOPs.
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)

    xs: Tuple = (stacked_params,)
    if use_cache:
        xs = xs + (cache,)
    # cross_kv stacked per-period (enc-dec) or None broadcast
    if cross_kv is not None:
        xs = xs + (cross_kv,)
    else:
        xs = xs + (jnp.zeros((cfg.n_periods,)),)  # dummy scanned leaf

    (x, aux), out_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        xs, unroll=scan_unroll(cfg.n_periods))
    if use_cache or mode == "prefill_cache":
        return x, out_caches, aux
    return x, None, aux
