from repro.models.model import LM, param_count_estimate, is_shape_leaf
from repro.models.param import (
    ParamSpec,
    abstract,
    axes_tree,
    count_params,
    materialize,
)
