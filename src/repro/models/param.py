"""Parameter specs: one tree describes shapes, logical axes, and init.

``spec`` trees are nested dicts whose leaves are ``ParamSpec``.  From one spec
tree we derive:
  * ``materialize(spec, key, dtype)``  -> real arrays (smoke tests, streaming)
  * ``abstract(spec, dtype)``          -> ShapeDtypeStructs (dry-run, no alloc)
  * ``axes_tree(spec)``                -> logical-axes tuples (for shardings)
  * ``stack(spec, n)``                 -> add leading "layers" dim (scan stacks)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"  # fan_in | normal | zeros | ones | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map_specs(fn: Callable[[ParamSpec], Any], spec: Any) -> Any:
    return jax.tree_util.tree_map(fn, spec, is_leaf=is_spec)


def stack(spec: Any, n: int) -> Any:
    """Add a leading scanned 'layers' dimension of size n to every param."""

    def _stack(p: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale)

    return _tree_map_specs(_stack, spec)


def abstract(spec: Any, dtype: Any = jnp.float32) -> Any:
    return _tree_map_specs(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(dtype)), spec
    )


def axes_tree(spec: Any) -> Any:
    return _tree_map_specs(lambda p: p.axes, spec)


def _init_one(p: ParamSpec, key: jax.Array, dtype: Any) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "small":
        return (0.02 * p.scale) * jax.random.normal(key, p.shape, dtype)
    if p.init == "normal":
        return p.scale * jax.random.normal(key, p.shape, dtype)
    # fan_in: scaled by 1/sqrt(fan_in) where fan_in = second-to-last dim
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale / np.sqrt(max(fan_in, 1))
    return std * jax.random.normal(key, p.shape, dtype)


def materialize(spec: Any, key: jax.Array, dtype: Any = jnp.float32) -> Any:
    """Create real parameter arrays (deterministic per tree path)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def cast_tree(tree: Any, dtype: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree
    )


def count_params(spec: Any) -> int:
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=is_spec)
    return sum(int(np.prod(p.shape)) for p in leaves if is_spec(p))
