"""LM: the unified model API over every assigned architecture.

One class covers decoder-only (dense / MoE / hybrid / SSM), encoder-decoder
(seamless-m4t) and stub-frontend multimodal (pixtral patches, seamless audio
frames).  All entry points are pure functions of (params, inputs) so they
jit/pjit directly:

    lm = LM(cfg, tp)
    spec   = lm.spec()                       # ParamSpec tree
    loss   = lm.loss(params, batch)          # train
    logits, cache = lm.prefill(params, batch, cache)
    logits, cache = lm.decode(params, tokens, cache, cur_len)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, BlockSpecEntry
from repro.common.sharding import shard_constraint
from repro.models import blocks as blk
from repro.models import attention as attn
from repro.models.layers import (
    apply_norm,
    cross_entropy,
    embed_spec,
    embed_tokens,
    norm_spec,
    unembed,
)
from repro.models.param import ParamSpec, count_params, stack as stack_specs


def is_shape_leaf(x: Any) -> bool:
    """A (shape, logical_axes) pair: shape is a tuple of ints."""
    return (
        isinstance(x, tuple)
        and len(x) == 2
        and isinstance(x[0], tuple)
        and all(isinstance(i, int) for i in x[0])
    )


class LM:
    def __init__(self, cfg: ArchConfig, tp: int = 1, q_block: int = 1024):
        self.cfg = cfg
        self.tp = tp
        self.q_block = q_block

    # ------------------------------------------------------------------
    # Parameter spec
    # ------------------------------------------------------------------
    def spec(self) -> Dict[str, Any]:
        cfg = self.cfg
        spec: Dict[str, Any] = {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model,
                                cfg.tie_embeddings),
            "stack": blk.stack_spec(cfg, self.tp,
                                    cross_attention=cfg.encoder_decoder),
            "final_norm": norm_spec(cfg.d_model, cfg.norm),
        }
        if cfg.encoder_decoder:
            enc_periods = cfg.n_encoder_layers // len(cfg.block_pattern)
            spec["encoder"] = {
                "stack": blk.stack_spec(cfg, self.tp, n_periods=enc_periods),
                "final_norm": norm_spec(cfg.d_model, cfg.norm),
            }
        return spec

    # ------------------------------------------------------------------
    # Embedding with optional multimodal stubs
    # ------------------------------------------------------------------
    def _embed(self, params, tokens: jax.Array, batch: Dict[str, Any],
               dtype) -> jax.Array:
        cfg = self.cfg
        scale = float(cfg.d_model) ** 0.5 if cfg.embed_scale else None
        x = embed_tokens(params["embed"], tokens, dtype, scale)
        if cfg.frontend == "patch" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dtype)   # (B, P, d)
            pp = batch["patch_pos"]                    # (B, P) int32
            bidx = jnp.arange(x.shape[0])[:, None]
            x = x.at[bidx, pp].add(pe)
        return x

    def _encode(self, params, frames: jax.Array) -> jax.Array:
        """Audio/any encoder over stub frame embeddings (B, T, d_model)."""
        cfg = self.cfg
        x = frames
        positions = jnp.arange(x.shape[1])[None, :]
        x, _, _ = blk.apply_stack(
            cfg, self.tp, params["encoder"]["stack"], x, mode="encode",
            positions=positions, q_block=self.q_block, remat=cfg.remat)
        return apply_norm(params["encoder"]["final_norm"], x, cfg.norm)

    # ------------------------------------------------------------------
    # Train forward + loss
    # ------------------------------------------------------------------
    def logits_causal(self, params, batch: Dict[str, Any],
                      dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch, dtype)
        positions = jnp.arange(tokens.shape[1])[None, :]
        cross_kv = None
        if cfg.encoder_decoder:
            enc_out = self._encode(params, batch["frames"].astype(dtype))
            cross_kv = self._cross_kv_stack(params, enc_out)
        x, _, aux = blk.apply_stack(
            cfg, self.tp, params["stack"], x, mode="causal",
            positions=positions, cross_kv=cross_kv, q_block=self.q_block,
            remat=cfg.remat)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x, cfg.final_softcap)
        return logits, aux

    def loss(self, params, batch: Dict[str, Any],
             dtype=jnp.bfloat16) -> jax.Array:
        logits, aux = self.logits_causal(params, batch, dtype)
        labels = batch["labels"]
        # mask padded label positions (label < 0)
        safe = jnp.maximum(labels, 0)
        nll, zl = cross_entropy(logits, safe)
        return nll + zl + aux

    # ------------------------------------------------------------------
    # Cross-attention KV (enc-dec)
    # ------------------------------------------------------------------
    def _cross_kv_stack(self, params, enc_out: jax.Array):
        """Project encoder output into stacked per-period cross K/V dicts."""
        cfg = self.cfg

        def per_period(p_params):
            out = {}
            for j in range(len(cfg.block_pattern)):
                key = f"i{j}"
                out[key] = attn.cross_kv(p_params[key]["cross"],
                                         cfg.attention, self.tp, enc_out)
            return out

        return jax.vmap(per_period, in_axes=0)(params["stack"])

    # ------------------------------------------------------------------
    # KV / state cache
    # ------------------------------------------------------------------
    def cache_shapes(self, batch: int, s_max: int,
                     t_src: int = 0) -> Dict[str, Any]:
        """Tree of (shape, logical_axes) for the decode cache."""
        cfg = self.cfg
        per = blk.period_cache_shapes(cfg, self.tp, batch, s_max)

        def add_layers(leaf):
            shape, axes = leaf
            return ((cfg.n_periods,) + shape, ("layers",) + axes)

        tree = jax.tree_util.tree_map(add_layers, per, is_leaf=is_shape_leaf)
        out = {"layers": tree}
        if cfg.encoder_decoder:
            _, hkv_e, _ = attn.head_layout(cfg.attention, self.tp)
            d = cfg.attention.head_dim
            ckv = {}
            for j in range(len(cfg.block_pattern)):
                shp = (cfg.n_periods, batch, t_src, hkv_e, d)
                axes = ("layers", "batch", None, "kv_heads", "head_dim")
                ckv[f"i{j}"] = ((shp, axes), (shp, axes))
            out["cross"] = ckv
        return out

    def init_cache(self, batch: int, s_max: int, t_src: int = 0,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
        shapes = self.cache_shapes(batch, s_max, t_src)

        def mk(leaf):
            shape, _ = leaf
            return jnp.zeros(shape, dtype)

        return jax.tree_util.tree_map(mk, shapes, is_leaf=is_shape_leaf)

    # ------------------------------------------------------------------
    # Prefill / decode
    # ------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, Any], cache: Dict[str, Any],
                dtype=jnp.bfloat16, last_pos: Optional[jax.Array] = None):
        """Run the prompt through the model, filling the cache.

        ``last_pos`` (B,) optionally selects which position's logits to
        return (for right-padded prompts); defaults to the final position.
        Returns (logits (B,1,V), cache).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch, dtype)
        positions = jnp.arange(tokens.shape[1])[None, :]
        cross_kv = cache.get("cross")
        if cfg.encoder_decoder and "frames" in batch:
            enc_out = self._encode(params, batch["frames"].astype(dtype))
            cross_kv = self._cross_kv_stack(params, enc_out)
        x, new_layer_cache, _ = blk.apply_stack(
            cfg, self.tp, params["stack"], x, mode="prefill_cache",
            positions=positions, cache=cache["layers"], cross_kv=cross_kv,
            q_block=self.q_block, remat=False)
        if last_pos is not None:
            x = jnp.take_along_axis(x, last_pos[:, None, None], axis=1)
        else:
            x = x[:, -1:]
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x, cfg.final_softcap)
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_cache
        if cfg.encoder_decoder:
            new_cache["cross"] = cross_kv
        return logits, new_cache

    def decode(self, params, tokens: jax.Array, cache: Dict[str, Any],
               cur_len: jax.Array, dtype=jnp.bfloat16):
        """One decode step. tokens (B,1) -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens, {}, dtype)
        positions = cur_len[None, None] if cur_len.ndim == 0 else cur_len
        x, new_layer_cache, _ = blk.apply_stack(
            cfg, self.tp, params["stack"], x, mode="decode",
            positions=positions, cache=cache["layers"], cur_len=cur_len,
            cross_kv=cache.get("cross"), q_block=self.q_block, remat=False)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x, cfg.final_softcap)
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_cache
        return logits, new_cache


# --------------------------------------------------------------------------
# Parameter counting (for MODEL_FLOPS = 6·N·D in the roofline)
# --------------------------------------------------------------------------

def param_count_estimate(cfg: ArchConfig, active_only: bool = False) -> int:
    lm = LM(cfg, tp=1)
    total = count_params(lm.spec())
    if active_only and cfg.has_moe:
        n_moe_layers = sum(
            1 for k in cfg.block_pattern if BlockSpecEntry.parse(k).mlp == "moe"
        ) * cfg.n_periods
        per_layer_expert = 3 * cfg.moe.n_experts * cfg.d_model * cfg.moe.d_ff_expert
        inactive_frac = (cfg.moe.n_experts - cfg.moe.top_k) / cfg.moe.n_experts
        total -= int(n_moe_layers * per_layer_expert * inactive_frac)
    return total
