"""Mixture-of-Experts FFN with expert parallelism over the "model" mesh axis.

Communication pattern (chosen for the production mesh — see DESIGN.md §4):
activations arriving at the MoE layer are sharded over the data axes and
*replicated* over "model" (they exit the attention TP all-reduce that way).
Each model-rank therefore routes all of its local tokens itself, computes
only its *local slice of experts* on a capacity-bounded dispatch buffer, and
the partial outputs are psum'd over "model" — one all-reduce of (tokens × d)
per MoE layer, the same collective class as a TP MLP.  No all-to-all is
needed because tokens never move between data ranks.

Expert weights are additionally sharded over the data axis (ZeRO-3); they are
all-gathered over "data" inside the shard_map right before use.

Capacity routing: per model-rank, each expert takes at most
``C = ceil(top_k · T_loc · capacity_factor / E)`` tokens (overflow dropped —
standard Switch/GShard semantics).  Router runs in fp32 with z-loss + load-
balance aux loss.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.config import MoEConfig
from repro.common.sharding import (
    current_mesh,
    dp_axis_names,
    logical_to_mesh,
    shard_map_compat,
)
from repro.common.utils import ceil_div
from repro.models.param import ParamSpec


def moe_spec(d_model: int, moe: MoEConfig) -> Dict[str, ParamSpec]:
    e, f = moe.n_experts, moe.d_ff_expert
    spec = {
        "router": ParamSpec((d_model, e), (None, None), "small"),
        "w_in": ParamSpec((e, d_model, f),
                          ("experts", "expert_fsdp", "expert_ff")),
        "w_gate": ParamSpec((e, d_model, f),
                            ("experts", "expert_fsdp", "expert_ff")),
        "w_out": ParamSpec((e, f, d_model),
                           ("experts", "expert_ff", "expert_fsdp")),
    }
    if moe.n_shared_experts:
        fs = f * moe.n_shared_experts
        spec["shared_in"] = ParamSpec((d_model, fs), ("fsdp", "mlp"))
        spec["shared_gate"] = ParamSpec((d_model, fs), ("fsdp", "mlp"))
        spec["shared_out"] = ParamSpec((fs, d_model), ("mlp", "fsdp"))
    return spec


def _route(router_w: jax.Array, x2d: jax.Array, moe: MoEConfig):
    """Top-k routing. x2d (T, d) -> (idx (T,k), weights (T,k), aux losses)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, moe.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * sum(frac_tokens * frac_probs)
    e = logits.shape[-1]
    onehot = jax.nn.one_hot(idx[:, 0], e)  # top-1 proxy for load
    frac_tokens = onehot.mean(0)
    frac_probs = probs.mean(0)
    aux = moe.aux_loss_coef * e * jnp.sum(frac_tokens * frac_probs)
    z = moe.router_z_coef * jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1)))
    return idx, weights, aux + z


def _expert_ffn(w_in, w_gate, w_out, xb: jax.Array) -> jax.Array:
    """xb (E_loc, C, d) -> (E_loc, C, d)."""
    dtype = xb.dtype
    h = jnp.einsum("ecd,edf->ecf", xb, w_in.astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(dtype))
    h = jax.nn.silu(h) * g
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(dtype))


def _moe_local(x2d, router_w, w_in, w_gate, w_out, moe: MoEConfig,
               e_start: jax.Array, e_local: int, capacity: int,
               model_axis: Optional[str], fsdp_axis,
               x_replicated: bool = False):
    """Per-(data,model)-shard MoE body. x2d (T_loc, d) replicated over model."""
    t, d = x2d.shape
    e = moe.n_experts
    idx, weights, aux = _route(router_w, x2d, moe)

    # Position of each (token, k) assignment within its expert's capacity.
    flat_e = idx.reshape(-1)                      # (T*k,)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), moe.top_k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot           # 1-based
    pos = jnp.max(pos_in_e, axis=-1) - 1                     # (T*k,)
    keep = (pos >= 0) & (pos < capacity)

    # Only this rank's experts.
    local = (flat_e >= e_start) & (flat_e < e_start + e_local) & keep
    slot = jnp.where(local, (flat_e - e_start) * capacity + pos, e_local * capacity)
    # dispatch buffer (E_loc*C + 1 overflow row, d)
    buf = jnp.zeros((e_local * capacity + 1, d), x2d.dtype)
    buf = buf.at[slot].add(jnp.where(local[:, None], x2d[flat_tok], 0))
    xb = buf[:-1].reshape(e_local, capacity, d)

    import os

    if fsdp_axis and x_replicated and \
            os.environ.get("REPRO_MOE_PARTIAL") == "1":
        # §Perf H3: keep expert weights ZeRO-sharded and exchange
        # *activations* instead — contract each rank's d-slice, psum the
        # (E_loc, C, f) partials, and all-gather the (E_loc, C, d/dp)
        # output slices.  ONLY valid when x (and hence the dispatch buffer)
        # is replicated over the fsdp axis — i.e. the decode/serving path
        # (batch-sharded training buffers differ per rank; the psum would
        # mix tokens).  Activation traffic is O(C·f) per token step vs the
        # baseline's O(params_bytes/16) weight gathers — the long-context
        # decode hillclimb's 45x collective reduction.
        didx = jax.lax.axis_index(fsdp_axis[0])   # single fsdp axis ("data")
        dloc = w_in.shape[1]                      # local d rows
        xb_slice = jax.lax.dynamic_slice_in_dim(xb, didx * dloc, dloc,
                                                axis=2)
        dtype = xb.dtype
        h = jnp.einsum("ecd,edf->ecf", xb_slice, w_in.astype(dtype))
        g = jnp.einsum("ecd,edf->ecf", xb_slice, w_gate.astype(dtype))
        hg = jax.lax.psum(jnp.stack([h, g]), fsdp_axis[0])
        act = jax.nn.silu(hg[0]) * hg[1]
        y_loc = jnp.einsum("ecf,efd->ecd", act, w_out.astype(dtype))
        yb = jax.lax.all_gather(y_loc, fsdp_axis[0], axis=2, tiled=True)
        yb = yb.reshape(e_local * capacity, d)
    else:
        # baseline: gather ZeRO-sharded expert weights over the data axes
        if fsdp_axis:
            w_in = jax.lax.all_gather(w_in, fsdp_axis, axis=1, tiled=True)
            w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, fsdp_axis, axis=2, tiled=True)
        yb = _expert_ffn(w_in, w_gate, w_out, xb).reshape(
            e_local * capacity, d)
    yb = jnp.concatenate([yb, jnp.zeros((1, d), yb.dtype)], axis=0)
    contrib = yb[slot] * (flat_w * local.astype(flat_w.dtype))[:, None].astype(yb.dtype)
    y = jnp.zeros((t, d), x2d.dtype).at[flat_tok].add(contrib)

    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
        aux = aux  # router identical on every model rank; no psum needed
    return y, aux


def apply_moe(params: Dict[str, Any], x: jax.Array, moe: MoEConfig,
              batch_sharded: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar)."""
    mesh = current_mesh()
    b, s, d = x.shape
    e = moe.n_experts

    if mesh is None or "model" not in mesh.axis_names:
        # single-device path (smoke tests / streaming models)
        x2d = x.reshape(b * s, d)
        cap = max(1, ceil_div(moe.top_k * b * s, e))
        cap = int(cap * moe.capacity_factor) + 1
        y, aux = _moe_local(
            x2d, params["router"], params["w_in"], params["w_gate"],
            params["w_out"], moe, jnp.int32(0), e, cap, None, ())
        y = y.reshape(b, s, d)
    else:
        tp = mesh.shape["model"]
        dp_axes = dp_axis_names(mesh) if batch_sharded else ()
        dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
        t_loc = (b * s) // dp
        cap = max(1, int(ceil_div(moe.top_k * t_loc, e) * moe.capacity_factor) + 1)
        e_local = e // tp
        fsdp_axes = tuple(a for a in ("data",) if a in mesh.axis_names)

        batch_spec = P(dp_axes if batch_sharded else None)
        x_spec = P(*(batch_spec + P(None, None)))

        def body(x3d, router_w, w_in, w_gate, w_out):
            t_rank = jax.lax.axis_index("model")
            e_start = t_rank * e_local
            x2d = x3d.reshape(-1, d)
            y, aux = _moe_local(x2d, router_w, w_in, w_gate, w_out, moe,
                                e_start, e_local, cap, "model", fsdp_axes,
                                x_replicated=not batch_sharded)
            # aux identical across model ranks; average over data ranks happens
            # outside via mean of replicated value
            return y.reshape(x3d.shape), aux

        y, aux = shard_map_compat(
            body,
            mesh=mesh,
            in_specs=(
                x_spec,
                P(None, None),
                P("model", fsdp_axes if fsdp_axes else None, None),
                P("model", fsdp_axes if fsdp_axes else None, None),
                P("model", None, fsdp_axes if fsdp_axes else None),
            ),
            out_specs=(x_spec, P()),
            check=False,
        )(x, params["router"], params["w_in"], params["w_gate"],
          params["w_out"])

    if moe.n_shared_experts:
        dtype = x.dtype
        h = jnp.einsum("bsd,df->bsf", x, params["shared_in"].astype(dtype))
        g = jnp.einsum("bsd,df->bsf", x, params["shared_gate"].astype(dtype))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(h) * g,
                           params["shared_out"].astype(dtype))
    return y, aux
