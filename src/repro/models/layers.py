"""Core layers: norms, rotary embeddings, MLPs, embeddings, soft-capping.

All functions are pure (params passed explicitly); logical-axis sharding
constraints are applied via repro.common.sharding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import shard_constraint
from repro.models.param import ParamSpec


# --------------------------------------------------------------------------
# Normalization
# --------------------------------------------------------------------------

def norm_spec(d: int, kind: str) -> Dict[str, ParamSpec]:
    if kind == "layernorm":
        return {
            "scale": ParamSpec((d,), ("embed",), "ones"),
            "bias": ParamSpec((d,), ("embed",), "zeros"),
        }
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def apply_norm(params: Dict[str, Any], x: jax.Array, kind: str,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


def rms_norm_simple(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Scale-only RMSNorm used for qk-norm (per-head-dim scale)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings (full / partial / theta-configurable)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, rotary_pct: float, theta: float) -> jax.Array:
    rot_dim = int(head_dim * rotary_pct)
    rot_dim -= rot_dim % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv  # (rot_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, rotary_pct: float,
               theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    rot_dim = int(head_dim * rotary_pct)
    rot_dim -= rot_dim % 2
    if rot_dim == 0:
        return x
    inv = rope_freqs(head_dim, rotary_pct, theta)  # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., : rot_dim // 2], x_rot[..., rot_dim // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.concatenate([y1, y2, x_pass], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Soft-capping (gemma2)
# --------------------------------------------------------------------------

def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x / cap)).astype(x.dtype)


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------

def mlp_spec(d_model: int, d_ff: int, gated: bool = True) -> Dict[str, ParamSpec]:
    spec = {
        "w_in": ParamSpec((d_model, d_ff), ("fsdp", "mlp")),
        "w_out": ParamSpec((d_ff, d_model), ("mlp", "fsdp")),
    }
    if gated:
        spec["w_gate"] = ParamSpec((d_model, d_ff), ("fsdp", "mlp"))
    return spec


def apply_mlp(params: Dict[str, Any], x: jax.Array, gated: bool = True,
              act: str = "silu") -> jax.Array:
    """x: (B, S, d_model) -> (B, S, d_model); hidden sharded over 'model'."""
    dtype = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dtype))
    h = shard_constraint(h, "batch", "seq", "mlp")
    if act == "gelu":
        h_act = jax.nn.gelu(h)
    else:
        h_act = jax.nn.silu(h)
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dtype))
        h_act = h_act * g
    out = jnp.einsum("bsf,fd->bsd", h_act, params["w_out"].astype(dtype))
    return shard_constraint(out, "batch", "seq", "embed")


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embed_spec(vocab: int, d_model: int, tie: bool) -> Dict[str, ParamSpec]:
    spec = {"table": ParamSpec((vocab, d_model), ("vocab", "fsdp"), "small")}
    if not tie:
        spec["unembed"] = ParamSpec((d_model, vocab), ("fsdp", "vocab"))
    return spec


def embed_tokens(params: Dict[str, Any], tokens: jax.Array, dtype: Any,
                 scale: Optional[float] = None) -> jax.Array:
    x = jnp.take(params["table"].astype(dtype), tokens, axis=0)
    if scale is not None:
        x = (x * jnp.asarray(scale, dtype)).astype(dtype)
    return shard_constraint(x, "batch", "seq", "embed")


def unembed(params: Dict[str, Any], x: jax.Array,
            final_cap: Optional[float] = None) -> jax.Array:
    if "unembed" in params:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    else:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["table"].astype(x.dtype)
        )
    logits = softcap(logits, final_cap)
    return shard_constraint(logits, "batch", "seq", "vocab")


# --------------------------------------------------------------------------
# Cross-entropy with z-loss (vocab-sharded safe: pure reductions)
# --------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_coef: float = 1e-4) -> Tuple[jax.Array, jax.Array]:
    """logits (B,S,V) fp-any, labels (B,S) int32. Returns (loss, z_loss).

    REPRO_ONEHOT_CE=1 (§Perf H1): the label pick runs as a one-hot masked
    reduction instead of take_along_axis — a gather over the vocab-sharded
    axis makes GSPMD all-gather the logits; the masked reduction partitions
    like logsumexp (partial-reduce + tiny psum).
    """
    import os

    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B,S)
    if os.environ.get("REPRO_ONEHOT_CE") == "1":
        v = logits.shape[-1]
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        mask = iota == labels[..., None]
        ll = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    else:
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zl = z_coef * jnp.square(lse)
    return jnp.mean(nll), jnp.mean(zl)
