"""GQA attention: TP-aware head layout, chunked prefill, cached decode.

TP head layout
--------------
The production mesh has a 16-way "model" axis.  Architectures whose head
counts don't divide it get:
  * q heads zero-padded to a multiple of tp (padded heads are masked out of
    the output so they are exact no-ops, including in gradients);
  * kv heads *replicated at compute time* (params keep the true GQA head
    count; the replicated copies are gathered with a static index map, so
    gradients sum back into the true heads).  This is standard TP serving
    practice; the extra KV-cache memory is recorded in the roofline notes.

Prefill attention is computed in q-blocks under ``lax.scan`` with
``jax.checkpoint`` per block, so peak memory is O(S·q_block) instead of
O(S²).  The causal path masks a full-K block panel (up to 2× attention-FLOP
waste vs. an ideal flash schedule — the Pallas flash kernel and the ring
variant remove this on the TPU target; see EXPERIMENTS.md §Perf).
Local (sliding-window) attention slices an exact static window, no waste.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import AttentionConfig
from repro.common.sharding import shard_constraint
from repro.common.utils import pad_to_multiple, scan_unroll
from repro.models.layers import apply_rope, rms_norm_simple, softcap
from repro.models.param import ParamSpec

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Head layout
# --------------------------------------------------------------------------

def head_layout(att: AttentionConfig, tp: int) -> Tuple[int, int, np.ndarray]:
    """Returns (padded q heads, effective kv heads, kv replication index map)."""
    hq_p = pad_to_multiple(att.n_heads, tp)
    if att.n_kv_heads % tp == 0 and hq_p % att.n_kv_heads == 0:
        hkv_e = att.n_kv_heads
    else:
        # smallest multiple of tp that divides hq_p and replicates kv evenly
        hkv_e = hq_p
        m = tp
        while m <= hq_p:
            if hq_p % m == 0 and m % att.n_kv_heads == 0 and m >= att.n_kv_heads:
                hkv_e = m
                break
            m += tp
    kv_map = (np.arange(hkv_e) * att.n_kv_heads) // hkv_e
    return hq_p, hkv_e, kv_map


def attention_spec(d_model: int, att: AttentionConfig, tp: int,
                   cross: bool = False) -> Dict[str, ParamSpec]:
    hq_p, _, _ = head_layout(att, tp)
    d = att.head_dim
    spec: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d_model, hq_p, d), ("fsdp", "heads", "head_dim")),
        "wk": ParamSpec((d_model, att.n_kv_heads, d), ("fsdp", None, "head_dim")),
        "wv": ParamSpec((d_model, att.n_kv_heads, d), ("fsdp", None, "head_dim")),
        "wo": ParamSpec((hq_p, d, d_model), ("heads", "head_dim", "fsdp")),
    }
    if att.qk_norm and not cross:
        spec["q_norm"] = ParamSpec((d,), ("head_dim",), "ones")
        spec["k_norm"] = ParamSpec((d,), ("head_dim",), "ones")
    return spec


def _project_qkv(params, att: AttentionConfig, tp: int, xq: jax.Array,
                 xkv: jax.Array):
    """Project and lay out heads. xq (B,Sq,d), xkv (B,Skv,d)."""
    dtype = xq.dtype
    hq_p, hkv_e, kv_map = head_layout(att, tp)
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(dtype))
    if att.qk_norm and "q_norm" in params:
        q = rms_norm_simple(q, params["q_norm"])
        k = rms_norm_simple(k, params["k_norm"])
    # replicate kv heads to the TP-effective count (static gather)
    if hkv_e != att.n_kv_heads:
        k = jnp.take(k, jnp.asarray(kv_map), axis=2)
        v = jnp.take(v, jnp.asarray(kv_map), axis=2)
    q = shard_constraint(q, "batch", "seq", "heads", "head_dim")
    k = shard_constraint(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = shard_constraint(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return q, k, v


def _head_mask(att: AttentionConfig, tp: int, dtype) -> Optional[jax.Array]:
    hq_p, _, _ = head_layout(att, tp)
    if hq_p == att.n_heads:
        return None
    mask = np.zeros((hq_p,), dtype=np.float32)
    mask[: att.n_heads] = 1.0
    return jnp.asarray(mask, dtype)


def _out_proj(params, att: AttentionConfig, tp: int, out: jax.Array) -> jax.Array:
    dtype = out.dtype
    hm = _head_mask(att, tp, dtype)
    if hm is not None:
        out = out * hm[None, None, :, None]
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return shard_constraint(y, "batch", "seq", "embed")


# --------------------------------------------------------------------------
# Core attention math
# --------------------------------------------------------------------------

def _gqa_logits(q, k, scale, cap):
    """q (B,Sq,H,D), k (B,Sk,Hk,D) -> logits (B,H,Sq,Sk) fp32, GQA-grouped."""
    b, sq, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, sq, hk, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    return logits  # (B, Hk, G, Sq, Sk)


def _gqa_out(probs, v, out_dtype):
    """probs (B,Hk,G,Sq,Sk) fp32, v (B,Sk,Hk,D) -> (B,Sq,H,D)."""
    b, hk, g, sq, sk = probs.shape
    d = v.shape[-1]
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hk * g, d).astype(out_dtype)


def full_attention(q, k, v, *, causal: bool, cap: Optional[float] = None,
                   q_offset: int = 0, kv_len: Optional[jax.Array] = None):
    """Direct (materialized-logits) attention. Use for small S / decode."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = _gqa_logits(q, k, scale, cap)
    sq, sk = logits.shape[-2], logits.shape[-1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(probs, v, q.dtype)


def _block_attend(qb, k, v, qpos, kpos, cap, out_dtype):
    scale = 1.0 / np.sqrt(qb.shape[-1])
    logits = _gqa_logits(qb, k, scale, cap)  # (B,Hk,G,Bq,Sk)
    mask = kpos[None, :] <= qpos[:, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(probs, v, out_dtype)


def chunked_causal_attention(q, k, v, *, cap: Optional[float] = None,
                             q_block: int = 1024):
    """Causal attention, scanned over q blocks. O(S·q_block) live memory."""
    b, s, h, d = q.shape
    if s <= q_block or s % q_block != 0:
        return full_attention(q, k, v, causal=True, cap=cap)
    nq = s // q_block
    qs = q.reshape(b, nq, q_block, h, d)
    kpos = jnp.arange(s)

    @jax.checkpoint
    def step(_, inp):
        i, qb = inp
        qpos = i * q_block + jnp.arange(q_block)
        ob = _block_attend(qb, k, v, qpos, kpos, cap, q.dtype)
        return None, ob

    _, out = jax.lax.scan(step, None,
                          (jnp.arange(nq), jnp.swapaxes(qs, 0, 1)),
                          unroll=scan_unroll(nq))
    out = jnp.swapaxes(out, 0, 1).reshape(b, s, h, d)
    return out


def chunked_bidir_attention(q, k, v, *, cap: Optional[float] = None,
                            q_block: int = 1024):
    """Full bidirectional attention (encoders / cross-attn), q-block scanned."""
    b, s, h, d = q.shape
    if s <= q_block or s % q_block != 0:
        scale = 1.0 / np.sqrt(d)
        logits = _gqa_logits(q, k, scale, cap)
        probs = jax.nn.softmax(logits, axis=-1)
        return _gqa_out(probs, v, q.dtype)
    nq = s // q_block
    qs = jnp.swapaxes(q.reshape(b, nq, q_block, h, d), 0, 1)

    @jax.checkpoint
    def step(_, qb):
        scale = 1.0 / np.sqrt(d)
        logits = _gqa_logits(qb, k, scale, cap)
        probs = jax.nn.softmax(logits, axis=-1)
        return None, _gqa_out(probs, v, q.dtype)

    _, out = jax.lax.scan(step, None, qs, unroll=scan_unroll(nq))
    return jnp.swapaxes(out, 0, 1).reshape(b, s, h, d)


def _windowed_full_attention(q, k, v, *, window: int,
                             cap: Optional[float] = None):
    """Direct attention with causal + sliding-window mask (small-S path)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = _gqa_logits(q, k, scale, cap)
    sq, sk = logits.shape[-2], logits.shape[-1]
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = (kpos[None, :] <= qpos[:, None]) & (
        kpos[None, :] > qpos[:, None] - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(probs, v, q.dtype)


def local_causal_attention(q, k, v, *, window: int, cap: Optional[float] = None,
                           q_block: Optional[int] = None):
    """Sliding-window causal attention with an exact static K panel per block.

    q block i attends K in [i*Bq - window, i*Bq + Bq) — a static-size slice,
    so there is no masked-FLOP waste beyond the window boundary itself.
    """
    b, s, h, d = q.shape
    bq = q_block or min(1024, s)
    if s <= window or s <= bq or s % bq != 0:
        return _windowed_full_attention(q, k, v, window=window, cap=cap)
    nq = s // bq
    panel = window + bq  # static K panel size
    qs = jnp.swapaxes(q.reshape(b, nq, bq, h, d), 0, 1)

    @jax.checkpoint
    def step(_, inp):
        i, qb = inp
        start = jnp.clip(i * bq - window, 0, s - panel)
        kp = jax.lax.dynamic_slice_in_dim(k, start, panel, axis=1)
        vp = jax.lax.dynamic_slice_in_dim(v, start, panel, axis=1)
        qpos = i * bq + jnp.arange(bq)
        kpos = start + jnp.arange(panel)
        scale = 1.0 / np.sqrt(d)
        logits = _gqa_logits(qb, kp, scale, cap)
        mask = (kpos[None, :] <= qpos[:, None]) & (
            kpos[None, :] > qpos[:, None] - window
        )
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        return None, _gqa_out(probs, vp, q.dtype)

    _, out = jax.lax.scan(step, None, (jnp.arange(nq), qs),
                          unroll=scan_unroll(nq))
    return jnp.swapaxes(out, 0, 1).reshape(b, s, h, d)


# --------------------------------------------------------------------------
# Public block-level entry points
# --------------------------------------------------------------------------

def attend_prefill(params, att: AttentionConfig, tp: int, x: jax.Array,
                   positions: jax.Array, *, local: bool = False,
                   q_block: int = 1024,
                   return_kv: bool = False):
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = _project_qkv(params, att, tp, x, x)
    q = apply_rope(q, positions, att.rotary_pct, att.rope_theta)
    k = apply_rope(k, positions, att.rotary_pct, att.rope_theta)
    if local and att.window is not None and x.shape[1] > att.window:
        out = local_causal_attention(q, k, v, window=att.window,
                                     cap=att.softcap, q_block=q_block)
    else:
        out = chunked_causal_attention(q, k, v, cap=att.softcap,
                                       q_block=q_block)
    y = _out_proj(params, att, tp, out)
    if return_kv:
        return y, (k, v)
    return y


def attend_encoder(params, att: AttentionConfig, tp: int, x: jax.Array,
                   positions: jax.Array, q_block: int = 1024) -> jax.Array:
    """Bidirectional self-attention (encoder)."""
    q, k, v = _project_qkv(params, att, tp, x, x)
    q = apply_rope(q, positions, att.rotary_pct, att.rope_theta)
    k = apply_rope(k, positions, att.rotary_pct, att.rope_theta)
    out = chunked_bidir_attention(q, k, v, cap=att.softcap, q_block=q_block)
    return _out_proj(params, att, tp, out)


def attend_cross(params, att: AttentionConfig, tp: int, x: jax.Array,
                 kv_cache: Tuple[jax.Array, jax.Array],
                 q_block: int = 1024) -> jax.Array:
    """Cross-attention against precomputed encoder K/V."""
    dtype = x.dtype
    hq_p, hkv_e, kv_map = head_layout(att, tp)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    q = shard_constraint(q, "batch", "seq", "heads", "head_dim")
    k, v = kv_cache
    out = chunked_bidir_attention(q, k, v, cap=att.softcap, q_block=q_block)
    return _out_proj(params, att, tp, out)


def cross_kv(params, att: AttentionConfig, tp: int,
             enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Project encoder output into replicated-head cross K/V (cached once)."""
    dtype = enc_out.dtype
    _, hkv_e, kv_map = head_layout(att, tp)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dtype))
    if hkv_e != att.n_kv_heads:
        k = jnp.take(k, jnp.asarray(kv_map), axis=2)
        v = jnp.take(v, jnp.asarray(kv_map), axis=2)
    k = shard_constraint(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = shard_constraint(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return k, v


def attend_decode(params, att: AttentionConfig, tp: int, x: jax.Array,
                  cache_k: jax.Array, cache_v: jax.Array,
                  cur_len: jax.Array, *, local: bool = False):
    """Single-token decode against a KV cache.

    x: (B, 1, d).  cache_k/v: (B, S_max, Hkv_e, D).  cur_len: scalar int32
    (uniform lengths — dry-run/serve_step) or (B,) int32 (per-slot lengths —
    continuous-batching engine).  The new token is written at cur_len.
    Returns (y, new_cache_k, new_cache_v).
    """
    b, _, _ = x.shape
    q, k_new, v_new = _project_qkv(params, att, tp, x, x)
    lens = jnp.broadcast_to(cur_len, (b,)) if cur_len.ndim == 0 else cur_len
    q = apply_rope(q, lens[:, None], att.rotary_pct, att.rope_theta)
    k_new = apply_rope(k_new, lens[:, None], att.rotary_pct, att.rope_theta)
    if cur_len.ndim == 0:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), cur_len, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), cur_len, axis=1)
    else:
        bidx = jnp.arange(b)
        cache_k = cache_k.at[bidx, lens].set(k_new[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, lens].set(v_new[:, 0].astype(cache_v.dtype))
    cache_k = shard_constraint(cache_k, "batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = shard_constraint(cache_v, "batch", "kv_seq", "kv_heads", "head_dim")
    kv_len = lens + 1  # (B,)
    window = att.window if (local and att.window is not None) else None
    out = _decode_attend(q, cache_k, cache_v, kv_len, att.softcap,
                         window=window)
    y = _out_proj(params, att, tp, out)
    return y, cache_k, cache_v


def _decode_attend(q, k, v, kv_len, cap, window: Optional[int] = None):
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = _gqa_logits(q, k, scale, cap)  # (B,Hk,G,1,S)
    s = k.shape[1]
    kpos = jnp.arange(s)
    mask = kpos[None, :] < kv_len[:, None]            # (B, S)
    if window is not None:
        mask &= kpos[None, :] > (kv_len[:, None] - 1 - window)
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(probs, v, q.dtype)
