"""glm4-9b — dense decoder, partial RoPE, GQA kv=2.

[hf:THUDM/glm-4-9b; hf]
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.common.config import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    d_ff=13696,
    vocab_size=151552,
    attention=AttentionConfig(n_heads=32, n_kv_heads=2, head_dim=128,
                              rotary_pct=0.5),
    block_pattern=("attn+dense",),
    grad_accum=2,
    notes="kv heads replicated 2->16 for TP=16.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="glm4-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=192,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                  rotary_pct=0.5),
        block_pattern=("attn+dense",),
        remat=False,
    )
