"""pixtral-12b — VLM: pixtral-ViT frontend (stub) + mistral-nemo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, n_patches, d_model) + their positions in the token sequence.
This is the most literal "MLLM operator" backbone for the Saṃsāra case study.
"""
from repro.common.config import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131072,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0),
    block_pattern=("attn+dense",),
    tie_embeddings=False,
    frontend="patch",
    grad_accum=4,
    notes="kv heads replicated 8->16 for TP=16; patch-embed stub frontend.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="pixtral-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        block_pattern=("attn+dense",),
        tie_embeddings=False,
        frontend="patch",
        remat=False,
    )
