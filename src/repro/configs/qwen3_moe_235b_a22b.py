"""qwen3-moe-235b-a22b — Qwen3-MoE (QK-norm, GQA, fine-grained experts).

[hf:Qwen/Qwen3-235B-A22B family; hf]
94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936, MoE 128e top-8.
"""
from repro.common.config import ArchConfig, AttentionConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    d_ff=1536,
    vocab_size=151936,
    attention=AttentionConfig(
        n_heads=64, n_kv_heads=4, head_dim=128, rope_theta=1_000_000.0,
        qk_norm=True),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    block_pattern=("attn+moe",),
    tie_embeddings=False,
    grad_accum=8,
    notes="128 experts top-8; qk-norm; kv heads replicated 4->16 for TP=16.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        d_ff=64,
        vocab_size=512,
        attention=AttentionConfig(n_heads=8, n_kv_heads=2, head_dim=16,
                                  qk_norm=True),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
        block_pattern=("attn+moe",),
        tie_embeddings=False,
        remat=False,
    )
