"""Paper-native streaming configs: the MLLM operator backbones Saṃsāra
actually *executes* in the CPU case study (Toll Booth / Volleyball).

STREAM_MLLM is a small VLM-style decoder (the stand-in for Qwen2.5-VL in the
paper's naive plan); STREAM_MLLM_SMALL is its distilled/pruned counterpart
that the physical-optimization phase may select.  Both use the patch-embed
frontend fed by the streaming preprocessing operators.
"""
from repro.common.config import ArchConfig, AttentionConfig

STREAM_MLLM_CONFIG = ArchConfig(
    name="samsara-stream-mllm",
    family="vlm",
    n_layers=4,
    d_model=256,
    d_ff=768,
    vocab_size=512,
    attention=AttentionConfig(n_heads=8, n_kv_heads=4, head_dim=32),
    block_pattern=("attn+dense",),
    frontend="patch",
    remat=False,
    notes="paper-native CPU-scale MLLM operator backbone",
)

STREAM_MLLM_SMALL_CONFIG = ArchConfig(
    name="samsara-stream-mllm-small",
    family="vlm",
    n_layers=2,
    d_model=128,
    d_ff=384,
    vocab_size=512,
    attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=32),
    block_pattern=("attn+dense",),
    frontend="patch",
    remat=False,
    notes="distilled/pruned target for physical optimization",
)


def smoke() -> ArchConfig:
    return STREAM_MLLM_SMALL_CONFIG
