"""chatglm3-6b — dense decoder, 2D/partial RoPE, extreme GQA (kv=2).

[arXiv:2406.12793; hf]
28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
ChatGLM's 2D rotary is realized as partial rotary (rotary_pct=0.5).
"""
from repro.common.config import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    d_ff=13696,
    vocab_size=65024,
    attention=AttentionConfig(n_heads=32, n_kv_heads=2, head_dim=128,
                              rotary_pct=0.5),
    block_pattern=("attn+dense",),
    grad_accum=2,
    notes="kv heads replicated 2->16 for TP=16.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="chatglm3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=192,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                  rotary_pct=0.5),
        block_pattern=("attn+dense",),
        remat=False,
    )
