"""moonshot-v1-16b-a3b — Moonlight-16B-A3B (DeepSeek-V3-style MoE).

[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=163840, MoE 64e top-6
(+2 shared experts, DeepSeek-V2-lite style).  All layers MoE (Moonlight's
single dense first layer is folded into the uniform scan pattern; noted).
"""
from repro.common.config import ArchConfig, AttentionConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    d_ff=1408,
    vocab_size=163840,
    attention=AttentionConfig(
        n_heads=16, n_kv_heads=16, head_dim=128, rope_theta=50000.0),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2),
    block_pattern=("attn+moe",),
    grad_accum=4,
    notes="64e top-6 MoE; MHA; shared experts add a dense 2x1408 path.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="moonshot-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        d_ff=96,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                      n_shared_experts=1),
        block_pattern=("attn+moe",),
        remat=False,
    )
