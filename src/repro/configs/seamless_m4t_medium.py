"""seamless-m4t-medium — encoder-decoder speech/text model (audio stub).

[arXiv:2308.11596; hf]
12L (enc) + 12L (dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
The audio frontend (fbank + conformer feature extractor) is a STUB:
input_specs() provides precomputed frame embeddings (B, T_src, d_model).
LayerNorm + non-gated GELU FFN (classic transformer FFN).
"""
from repro.common.config import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    n_encoder_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=64),
    block_pattern=("attn+dense",),
    encoder_decoder=True,
    norm="layernorm",
    mlp_gated=False,
    frontend="audio",
    grad_accum=2,
    notes="enc-dec; vocab padded 256206->256256 for TP divisibility.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="seamless-smoke",
        family="audio",
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        block_pattern=("attn+dense",),
        encoder_decoder=True,
        norm="layernorm",
        mlp_gated=False,
        frontend="audio",
        remat=False,
    )
