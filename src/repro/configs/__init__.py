"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

One module per assigned architecture (exact public configs), plus the
paper-native streaming configs (tiny MLLM backbone + TinyDet detector used by
the Saṃsāra case study on CPU).
"""
from __future__ import annotations

from typing import Dict

from repro.common.config import ArchConfig

from repro.configs.moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from repro.configs.qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from repro.configs.jamba_1_5_large_398b import CONFIG as jamba_1_5_large_398b
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium
from repro.configs.chatglm3_6b import CONFIG as chatglm3_6b
from repro.configs.gemma2_2b import CONFIG as gemma2_2b
from repro.configs.glm4_9b import CONFIG as glm4_9b
from repro.configs.phi3_mini_3_8b import CONFIG as phi3_mini_3_8b
from repro.configs.pixtral_12b import CONFIG as pixtral_12b
from repro.configs.mamba2_130m import CONFIG as mamba2_130m
from repro.configs.samsara_stream import (
    STREAM_MLLM_CONFIG as samsara_stream_mllm,
    STREAM_MLLM_SMALL_CONFIG as samsara_stream_mllm_small,
)

REGISTRY: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        moonshot_v1_16b_a3b,
        qwen3_moe_235b_a22b,
        jamba_1_5_large_398b,
        seamless_m4t_medium,
        chatglm3_6b,
        gemma2_2b,
        glm4_9b,
        phi3_mini_3_8b,
        pixtral_12b,
        mamba2_130m,
        samsara_stream_mllm,
        samsara_stream_mllm_small,
    ]
}

ASSIGNED = [
    "moonshot-v1-16b-a3b",
    "qwen3-moe-235b-a22b",
    "jamba-1.5-large-398b",
    "seamless-m4t-medium",
    "chatglm3-6b",
    "gemma2-2b",
    "glm4-9b",
    "phi3-mini-3.8b",
    "pixtral-12b",
    "mamba2-130m",
]


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs():
    return list(ASSIGNED)


def smoke_config(name: str) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests."""
    import importlib

    mod_name = REGISTRY[name].__class__  # noqa: F841 (doc only)
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.smoke()
