"""gemma2-2b — local/global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]
26L d_model=2304 8H (GQA kv=4) head_dim=256 d_ff=9216 vocab=256000.
Period = (local sliding-window 4096, global); attn softcap 50, final logit
softcap 30; sandwich (pre+post) RMSNorm; GeGLU; embeddings scaled sqrt(d).
8 q-heads < TP=16 => heads padded to 16 (masked no-ops; ~2x attention-FLOP
overhead on this small arch, recorded in the roofline notes).
"""
from repro.common.config import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    d_ff=9216,
    vocab_size=256000,
    attention=AttentionConfig(n_heads=8, n_kv_heads=4, head_dim=256,
                              softcap=50.0, window=4096),
    block_pattern=("attn_local+dense", "attn_global+dense"),
    post_block_norm=True,
    embed_scale=True,
    final_softcap=30.0,
    grad_accum=2,
    notes="13 periods of (local, global).",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-smoke",
        family="dense",
        n_layers=4,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                  softcap=50.0, window=16),
        block_pattern=("attn_local+dense", "attn_global+dense"),
        post_block_norm=True,
        embed_scale=True,
        final_softcap=30.0,
        remat=False,
    )
