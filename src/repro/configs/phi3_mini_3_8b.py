"""phi3-mini-3.8b — dense decoder, MHA (kv=32), SwiGLU.

[arXiv:2404.14219; unverified]
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
"""
from repro.common.config import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=32064,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=96),
    block_pattern=("attn+dense",),
    grad_accum=2,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="phi3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        block_pattern=("attn+dense",),
        remat=False,
    )
