"""mamba2-130m — pure SSM (SSD, state-space duality), attention-free.

[arXiv:2405.21060; unverified]
24L d_model=768 (attn-free) vocab=50280, ssm_state=128, head_dim=64,
expand=2 => d_inner=1536, 24 SSD heads (padded to 32 for TP=16).
Sub-quadratic: O(1) decode state => runs long_500k trivially.
The physical-optimization phase also uses this family as the distillation
target for MLLM operator specialization.
"""
from repro.common.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, head_dim=64, expand=2, n_groups=1,
                  chunk=256),
    block_pattern=("mamba+none",),
    sub_quadratic=True,
    notes="vocab padded 50280->50432; heads padded 24->32 for TP=16.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        d_ff=0,
        vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, head_dim=16, expand=2,
                      n_groups=1, chunk=32),
        block_pattern=("mamba+none",),
        sub_quadratic=True,
        remat=False,
    )
