"""jamba-1.5-large-398b — hybrid Mamba+Attention 1:7 interleave with MoE.

[arXiv:2403.19887; hf]
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period of 8 layers: one attention layer per 7 mamba layers; MoE replaces the
dense MLP on every other layer.  The paper's Mamba-1 mixer is implemented as
the TPU-friendly Mamba-2/SSD formulation (see DESIGN.md hardware adaptation).
Sub-quadratic => runs the long_500k cell (attention KV is sequence-sharded).
"""
from repro.common.config import ArchConfig, AttentionConfig, MoEConfig, SSMConfig

_PATTERN = (
    "mamba+dense",
    "mamba+moe",
    "mamba+dense",
    "attn+moe",
    "mamba+dense",
    "mamba+moe",
    "mamba+dense",
    "mamba+moe",
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab_size=65536,
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                  capacity_factor=1.25),
    ssm=SSMConfig(d_state=16, d_conv=4, head_dim=128, expand=2, n_groups=8,
                  chunk=256),
    block_pattern=_PATTERN,
    sub_quadratic=True,
    grad_accum=8,
    notes="1:7 attn:mamba, MoE every other layer; 9 periods of 8.",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="jamba-smoke",
        family="hybrid",
        n_layers=8,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        ssm=SSMConfig(d_state=16, d_conv=4, head_dim=16, expand=2,
                      n_groups=2, chunk=32),
        block_pattern=_PATTERN,
        sub_quadratic=True,
        remat=False,
    )
