from repro.queries.catalog import QUERIES, Query, get_query
