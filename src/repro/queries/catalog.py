"""The paper's 13 queries (Table 1): naive plans + ground-truth evaluators.

Q1–Q9 run on the Toll Booth stream, Q10–Q13 on Volleyball.  Each query
provides:
  * ``naive_plan()`` — Source -> MLLMExtract(all needed tasks) -> relational
    tail -> Sink (every frame through the big MLLM: the paper's baseline);
  * ``evaluate(result)`` — query-level accuracy against stream labels
    (per-car / per-event / per-window semantics, matching how the paper
    scores correctness rather than raw per-frame agreement).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.data.tollbooth import BRANDS, COLORS, PLATE_CHARS
from repro.data.volleyball import ACTIONS
from repro.streaming.operators import (
    FilterOp,
    MLLMExtractOp,
    SinkOp,
    SourceOp,
    WindowAggOp,
)
from repro.streaming.plan import Plan

WINDOW = 256


# ---------------------------------------------------------------------------
# label helpers
# ---------------------------------------------------------------------------

def car_passes(labels: List[Dict]) -> List[Dict]:
    """Group consecutive readable frames of the same plate into passes."""
    passes = []
    cur = None
    for l in labels:
        if l.get("car_readable") and l.get("plate"):
            if cur is not None and cur["plate"] == l["plate"] \
                    and l["index"] - cur["last"] <= 3:
                cur["last"] = l["index"]
                cur["frames"].append(l["index"])
            else:
                if cur:
                    passes.append(cur)
                cur = {"plate": l["plate"], "color": l["color"],
                       "brand": l["brand"], "stolen": l["stolen"],
                       "first": l["index"], "last": l["index"],
                       "frames": [l["index"]]}
        elif cur is not None and l["index"] - cur["last"] > 3:
            passes.append(cur)
            cur = None
    if cur:
        passes.append(cur)
    return passes


def _attr_by_frame(outputs: List[Dict], field: str) -> Dict[int, Any]:
    return {o["idx"]: o[field] for o in outputs if field in o}


def _per_car_accuracy(outputs, labels, field, vocab) -> float:
    """A car pass is correct if any emitted frame in its span matches GT."""
    passes = car_passes(labels)
    if not passes:
        return 1.0
    by_frame = _attr_by_frame(outputs, field)
    ok = 0
    for p in passes:
        truth = p[field] if field != "plate" else p["plate"]
        hit = False
        for fidx in range(p["first"], p["last"] + 1):
            if fidx in by_frame:
                pred = by_frame[fidx]
                if field == "plate":
                    pred_s = "".join(PLATE_CHARS[int(c)] for c in pred)
                    hit = pred_s == truth
                else:
                    hit = vocab[int(pred)] == truth
                if hit:
                    break
        ok += hit
    return ok / len(passes)


def _windows(labels: List[Dict], window: int) -> List[List[Dict]]:
    n = labels[-1]["index"] + 1 if labels else 0
    return [[l for l in labels if w0 <= l["index"] < w0 + window]
            for w0 in range(0, n - window + 1, window)]


def _window_results(result, kind: str) -> List[Dict]:
    """Window results of one kind, one per window span.

    flush() emits the open window early, marked ``partial``; when a
    segmented (snapshot/resume) run later closes the same window, the
    closed result supersedes the partial one (and a fresher partial
    supersedes a staler one), so positional indexing against ground-truth
    windows stays aligned."""
    best: Dict[Tuple, Dict] = {}      # insertion-ordered by window span
    for w in result.window_results:
        if w["kind"] != kind:
            continue
        key = tuple(w["window"])
        if key not in best or best[key].get("partial"):
            best[key] = w
    return list(best.values())


def _event_f1(pred_events: List[int], true_spans: List[Tuple[int, int]],
              slack: int = 2) -> float:
    """Match notification frames to true event spans."""
    if not true_spans:
        return 1.0 if not pred_events else 0.0
    matched = set()
    tp = 0
    fp = 0
    for e in pred_events:
        hit = None
        for i, (a, b) in enumerate(true_spans):
            if a - slack <= e <= b + slack:
                hit = i
                break
        if hit is None:
            fp += 1
        else:
            matched.add(hit)
    tp = len(matched)
    fn = len(true_spans) - tp
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return 2 * prec * rec / max(prec + rec, 1e-9)


# ---------------------------------------------------------------------------
# Query definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Query:
    qid: str
    description: str
    dataset: str                       # tollbooth | volleyball
    tasks: Tuple[str, ...]
    tail: Callable[[], List]           # relational tail ops (fresh instances)
    evaluate: Callable[[Any], float]
    #: semantic hints the optimizer reads from the *query* (not the data)
    needs_color: bool = False
    needs_plate: bool = False
    needs_fine_detail: bool = False    # plates/brand stripes need resolution
    filter_color: Optional[str] = None

    def naive_plan(self) -> Plan:
        ops = [SourceOp(stream_name=self.dataset),
               MLLMExtractOp(tasks=self.tasks, model="big")]
        ops += self.tail()
        ops.append(SinkOp())
        return Plan(ops, query=self.qid)


def _eval_q1(result):
    return _per_car_accuracy(result.outputs, result.labels, "brand", BRANDS)


def _eval_q2(result):
    return _per_car_accuracy(result.outputs, result.labels, "color", COLORS)


def _eval_q3(result):
    return _per_car_accuracy(result.outputs, result.labels, "plate", None)


def _topk_window_eval(result, labels, field, vocab, kind, key):
    wins = _window_results(result, kind)
    gt_wins = _windows(labels, WINDOW)
    if not gt_wins:
        return 1.0
    ok, tot = 0, 0
    for i, wl in enumerate(gt_wins):
        truth_counts = Counter(l[field] for l in wl
                               if l.get("car_readable") and l.get(field))
        if not truth_counts:
            continue
        truth = truth_counts.most_common(1)[0][0]
        pred = wins[i][key] if i < len(wins) and wins[i].get(key) else None
        tot += 1
        ok += pred == truth
    return ok / max(tot, 1)


def _eval_q4(result):
    a = _topk_window_eval(result, result.labels, "brand", BRANDS,
                          "top_brand_color", "top_brand")
    b = _topk_window_eval(result, result.labels, "color", COLORS,
                          "top_brand_color", "top_color")
    return 0.5 * (a + b)


def _eval_q5(result):
    return _topk_window_eval(result, result.labels, "brand", BRANDS,
                             "top_brand", "top_brand")


def _eval_q6(result):
    return _topk_window_eval(result, result.labels, "color", COLORS,
                             "top_color", "top_color")


def _eval_q7(result):
    wins = _window_results(result, "repeated_plates")
    gt_wins = _windows(result.labels, WINDOW)
    ok, tot = 0, 0
    for i, wl in enumerate(gt_wins):
        passes = car_passes(wl)
        c = Counter(p["plate"] for p in passes)
        truth = set(pl for pl, k in c.items() if k >= 2)
        pred = set(wins[i]["repeated"]) if i < len(wins) else set()
        tot += 1
        if truth or pred:
            inter = len(truth & pred)
            union = len(truth | pred)
            ok += inter / max(union, 1)
        else:
            ok += 1
    return ok / max(tot, 1)


def _eval_q8(result):
    # notifications = frames that survived the stolen-car filter
    pred_events = [o["idx"] for o in result.outputs]
    passes = [p for p in car_passes(result.labels) if p["stolen"]]
    spans = [(p["first"], p["last"]) for p in passes]
    return _event_f1(pred_events, spans)


def _eval_q9(result):
    wins = _window_results(result, "count_distinct_plates")
    gt_wins = _windows(result.labels, WINDOW)
    ok, tot = 0, 0
    for i, wl in enumerate(gt_wins):
        truth = len(set(p["plate"] for p in car_passes(wl)))
        pred = wins[i]["distinct_plates"] if i < len(wins) else 0
        tot += 1
        ok += 1.0 - min(abs(pred - truth) / max(truth, 1), 1.0)
    return ok / max(tot, 1)


def _eval_q10(result):
    wins = _window_results(result, "count_jumping")
    gt_wins = _windows(result.labels, WINDOW)
    ok, tot = 0, 0
    for i, wl in enumerate(gt_wins):
        truth = sum(l["n_jumping"] for l in wl)
        pred = wins[i]["total_jumping"] if i < len(wins) else 0
        tot += 1
        ok += 1.0 - min(abs(pred - truth) / max(truth, 1), 1.0)
    return ok / max(tot, 1)


def _eval_q11(result):
    # offense proxy scored on spike counts per window
    wins = _window_results(result, "top_team")
    gt_wins = _windows(result.labels, WINDOW)
    ok, tot = 0, 0
    for i, wl in enumerate(gt_wins):
        truth = sum(1 for l in wl if l["action"] == "spike")
        pred = wins[i]["spikes"] if i < len(wins) else 0
        tot += 1
        ok += 1.0 - min(abs(pred - truth) / max(truth, 1), 1.0)
    return ok / max(tot, 1)


def _eval_q12(result):
    pred_events = [o["idx"] for o in result.outputs]
    spans = []
    start = None
    for l in result.labels:
        if l["action"] == "spike" and start is None:
            start = l["index"]
        elif l["action"] != "spike" and start is not None:
            spans.append((start, l["index"] - 1))
            start = None
    if start is not None:
        spans.append((start, result.labels[-1]["index"]))
    return _event_f1(pred_events, spans)


def _eval_q13(result):
    wins = _window_results(result, "top3_actions")
    gt_wins = _windows(result.labels, WINDOW)
    ok, tot = 0, 0
    for i, wl in enumerate(gt_wins):
        c = Counter(l["action"] for l in wl)
        truth = set(a for a, _ in c.most_common(3))
        pred = set(wins[i]["top3"]) if i < len(wins) else set()
        tot += 1
        ok += len(truth & pred) / max(len(truth | pred), 1)
    return ok / max(tot, 1)


QUERIES: Dict[str, Query] = {
    "Q1": Query("Q1", "Car brand recognition", "tollbooth",
                ("present", "brand"),
                lambda: [FilterOp(("eq", "present", 1))], _eval_q1,
                needs_fine_detail=True),
    "Q2": Query("Q2", "Car color recognition", "tollbooth",
                ("present", "color"),
                lambda: [FilterOp(("eq", "present", 1))], _eval_q2,
                needs_color=True),
    "Q3": Query("Q3", "License plate detection", "tollbooth",
                ("present", "plate"),
                lambda: [FilterOp(("eq", "present", 1))], _eval_q3,
                needs_plate=True, needs_fine_detail=True),
    "Q4": Query("Q4", "Most popular brand & color", "tollbooth",
                ("present", "brand", "color"),
                lambda: [FilterOp(("eq", "present", 1)),
                         WindowAggOp("top_brand_color", WINDOW)], _eval_q4,
                needs_color=True, needs_fine_detail=True),
    "Q5": Query("Q5", "Most popular brand", "tollbooth",
                ("present", "brand"),
                lambda: [FilterOp(("eq", "present", 1)),
                         WindowAggOp("top_brand", WINDOW)], _eval_q5,
                needs_fine_detail=True),
    "Q6": Query("Q6", "Most popular color", "tollbooth",
                ("present", "color"),
                lambda: [FilterOp(("eq", "present", 1)),
                         WindowAggOp("top_color", WINDOW)], _eval_q6,
                needs_color=True),
    "Q7": Query("Q7", "Repeated car detection", "tollbooth",
                ("present", "plate"),
                lambda: [FilterOp(("eq", "present", 1)),
                         WindowAggOp("repeated_plates", WINDOW)], _eval_q7,
                needs_plate=True, needs_fine_detail=True),
    "Q8": Query("Q8", "Red stolen 'MTT' car", "tollbooth",
                ("present", "color", "plate"),
                lambda: [FilterOp(("and", ("eq", "present", 1),
                                   ("and", ("eq", "color", "red"),
                                    ("prefix", "plate", "MTT"))))], _eval_q8,
                needs_color=True, needs_plate=True, needs_fine_detail=True,
                filter_color="red"),
    "Q9": Query("Q9", "Unique license plates", "tollbooth",
                ("present", "plate"),
                lambda: [FilterOp(("eq", "present", 1)),
                         WindowAggOp("count_distinct_plates", WINDOW)],
                _eval_q9, needs_plate=True, needs_fine_detail=True),
    "Q10": Query("Q10", "Amount of jumping players", "volleyball",
                 ("action", "n_jumping"),
                 lambda: [WindowAggOp("count_jumping", WINDOW)], _eval_q10),
    "Q11": Query("Q11", "Most offensive team", "volleyball",
                 ("action", "team"),
                 lambda: [WindowAggOp("top_team", WINDOW)], _eval_q11),
    "Q12": Query("Q12", "Notify when someone spikes", "volleyball",
                 ("action",),
                 lambda: [FilterOp(("eq", "action", "spike"))], _eval_q12),
    "Q13": Query("Q13", "3 most common actions", "volleyball",
                 ("action",),
                 lambda: [WindowAggOp("top3_actions", WINDOW)], _eval_q13),
}


def get_query(qid: str) -> Query:
    return QUERIES[qid]
