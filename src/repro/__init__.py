"""repro — Saṃsāra-JAX: a multimodal stream processing framework on TPU.

Reproduction of "[Vision Paper] Towards a Multimodal Stream Processing
System" (CS.DB 2025) as a production-grade JAX framework: streaming runtime
with MLLM operators, the Saṃsāra super-optimizer (semantic/logical/physical),
a sharded serving+training substrate over the assigned architecture pool,
and Pallas TPU kernels for the compute hot spots.
"""

__version__ = "1.0.0"
