"""Resumable data pipelines.

``TokenStream``: deterministic synthetic LM token stream.  Batch ``i`` is a
pure function of ``(seed, i)``, so the pipeline state is a single integer —
checkpointing it gives exactly-once replay semantics after restart (the same
contract a production sharded data service provides, with the index playing
the role of the per-shard offset).

The synthetic distribution is a order-2 Markov chain over the vocab with a
few high-frequency "template" n-grams, so small models show a real, visibly
decreasing loss curve (needed by the distillation/specialization examples).

``DistillBatcher``: wraps a teacher model to emit (tokens, teacher_logits)
batches for the physical-optimization distillation path.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, extra_fn: Optional[Callable[[np.random.RandomState, int], Dict[str, np.ndarray]]] = None):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.index = 0
        self.extra_fn = extra_fn
        # fixed random Markov transition structure (shared across batches)
        rs = np.random.RandomState(seed)
        self._succ = rs.randint(0, vocab_size, size=(vocab_size, 4))

    # -- resumable state ---------------------------------------------------
    def state(self) -> Dict[str, Any]:
        return {"index": np.asarray(self.index), "seed": np.asarray(self.seed)}

    def set_state(self, st: Dict[str, Any]) -> None:
        self.index = int(st["index"])
        self.seed = int(st["seed"])

    # -- batch generation ----------------------------------------------------
    def _gen(self, i: int) -> Dict[str, jnp.ndarray]:
        rs = np.random.RandomState((self.seed * 1_000_003 + i) % 2**31)
        toks = np.zeros((self.batch, self.seq + 1), np.int64)
        toks[:, 0] = rs.randint(0, self.vocab, self.batch)
        choice = rs.randint(0, 4, size=(self.batch, self.seq))
        noise = rs.rand(self.batch, self.seq) < 0.1
        rand_tok = rs.randint(0, self.vocab, size=(self.batch, self.seq))
        for t in range(self.seq):
            nxt = self._succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if self.extra_fn is not None:
            batch.update({k: jnp.asarray(v)
                          for k, v in self.extra_fn(rs, self.batch).items()})
        return batch

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        b = self._gen(self.index)
        self.index += 1
        return b


class DistillBatcher:
    """Generates (student batch + teacher logits) for distillation."""

    def __init__(self, stream: TokenStream, teacher_fn: Callable[[Dict], Any]):
        self.stream = stream
        self.teacher_fn = teacher_fn

    def state(self):
        return self.stream.state()

    def set_state(self, st):
        self.stream.set_state(st)

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        batch = self.stream.next_batch()
        batch["teacher_logits"] = jax.lax.stop_gradient(
            self.teacher_fn(batch))
        return batch


def distill_loss_fn(lm, temperature: float = 2.0, alpha: float = 0.5):
    """KL(teacher || student) + alpha·CE hard-label loss."""

    def loss(params, batch):
        logits, aux = lm.logits_causal(params, batch, jnp.float32)
        t = temperature
        t_logits = batch["teacher_logits"].astype(jnp.float32)
        p_t = jax.nn.softmax(t_logits / t, axis=-1)
        logp_s = jax.nn.log_softmax(logits / t, axis=-1)
        kl = -jnp.mean(jnp.sum(p_t * logp_s, axis=-1)) * t * t
        from repro.models.layers import cross_entropy

        ce, zl = cross_entropy(logits, jnp.maximum(batch["labels"], 0))
        return (1 - alpha) * kl + alpha * ce + zl + aux

    return loss
