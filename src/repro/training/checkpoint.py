"""Atomic, elastic checkpointing.

Layout:  <dir>/step_<n>/{manifest.json, <flat-key>.npy...}
  * atomic commit: written to ``step_<n>.tmp`` then ``os.rename``d — a crash
    mid-save never corrupts the latest checkpoint;
  * manifest records step, save-time mesh shape, and the flattened tree
    structure (keypaths), so a restore can validate compatibility;
  * **elastic restore**: arrays are saved as full (host-gathered) tensors and
    re-sharded at load onto whatever mesh/shardings the restoring job passes —
    a 256-chip checkpoint restores onto 512 chips (or 1 CPU) unchanged.  On a
    real multi-host pod, each host gathers only its addressable shards; the
    single-process container exercises the same code path trivially.
  * retention: keeps the last ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def list_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        manifest = {"step": step, "keys": sorted(flat.keys()),
                    "n_devices": jax.device_count()}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, key.replace("/", "__") + ".npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int, shardings: Any = None) -> Any:
        """Rebuild the tree saved at ``step``.

        ``shardings`` (optional) is a prefix-tree of NamedShardings keyed the
        same way as the saved tree; matching leaves are device_put with their
        sharding (elastic re-shard), everything else loads replicated.
        """
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out: Dict[str, Any] = {}
        for key in manifest["keys"]:
            arr = np.load(os.path.join(d, key.replace("/", "__") + ".npy"))
            sh = flat_shard.get(key)
            out[key] = jax.device_put(arr, sh) if sh is not None else \
                jax.numpy.asarray(arr)
        return _unflatten(out)


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _listify(root)


def _listify(node: Any) -> Any:
    """Convert dicts whose keys are 0..n-1 ints back into lists/tuples."""
    if not isinstance(node, dict):
        return node
    out = {k: _listify(v) for k, v in node.items()}
    keys = list(out.keys())
    if keys and all(k.isdigit() for k in keys):
        idx = sorted(int(k) for k in keys)
        if idx == list(range(len(idx))):
            return [out[str(i)] for i in idx]
    return out
