"""Train step factory + fault-tolerant training loop.

``make_train_step`` builds one jitted SPMD program: microbatched gradient
accumulation (``lax.scan``), global-norm clipping, AdamW (optionally int8
moments), donated params/opt-state buffers.

``Trainer`` owns the loop: resumable data, periodic atomic checkpoints,
preemption-signal checkpointing (SIGTERM/SIGINT), step-time watchdog
(straggler logging), and elastic restore (a checkpoint taken on one mesh
restores onto another — shardings are re-applied at load).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import scan_unroll
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    grad_accum: int = 1
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0  # warn when a step takes 3x the median


def make_train_step(loss_fn: Callable[[Any, Dict[str, Any]], jax.Array],
                    opt_cfg: OptimizerConfig, grad_accum: int = 1,
                    donate: bool = True, jit: bool = True):
    """loss_fn(params, microbatch) -> scalar.  Returns the train_step
    (jitted unless jit=False — the dry-run lowers it with explicit
    shardings itself)."""

    import os

    cast_step = os.environ.get("REPRO_CAST_BF16_STEP") == "1"

    def cast_loss(p, mb):
        if cast_step:
            # §Perf H2: cast the param tree to bf16 inside the diff'd fn —
            # GSPMD pushes the (elementwise) convert below the ZeRO-3
            # all-gathers, halving every weight-gather's bytes; the
            # optimizer still updates the fp32 master copy (the cast's
            # transpose accumulates grads back to fp32).
            p = jax.tree_util.tree_map(
                lambda w: w.astype(jnp.bfloat16)
                if w.dtype == jnp.float32 and w.ndim >= 2 else w, p)
        return loss_fn(p, mb)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(cast_loss)(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(cast_loss)(params, mb)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            split = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), split,
                                           unroll=scan_unroll(grad_accum))
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    if not jit:
        return train_step
    return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())


class Trainer:
    def __init__(self, loss_fn, params, opt_cfg: OptimizerConfig,
                 train_cfg: TrainConfig, data_iter,
                 ckpt: Optional[CheckpointManager] = None,
                 param_shardings: Any = None):
        self.loss_fn = loss_fn
        self.params = params
        self.opt_cfg = opt_cfg
        self.cfg = train_cfg
        self.data = data_iter
        self.ckpt = ckpt
        self.param_shardings = param_shardings
        self.opt_state = adamw_init(params, opt_cfg)
        self.step = 0
        self.history: list = []
        self._train_step = make_train_step(loss_fn, opt_cfg,
                                           train_cfg.grad_accum)
        self._preempted = False
        self._step_times: list = []

    # -- preemption handling ------------------------------------------------
    def install_signal_handlers(self) -> None:
        def handler(signum, frame):  # pragma: no cover - signal path
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    # -- checkpoint / restore -----------------------------------------------
    def save(self) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step, {
            "params": self.params,
            "opt_state": self.opt_state,
            "data_state": self.data.state(),
        })

    def restore(self, step: Optional[int] = None) -> bool:
        if self.ckpt is None:
            return False
        step = step if step is not None else self.ckpt.latest_step()
        if step is None:
            return False
        tree = self.ckpt.restore(step, shardings={
            "params": self.param_shardings} if self.param_shardings else None)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.data.set_state(tree["data_state"])
        self.step = step
        return True

    # -- loop -----------------------------------------------------------------
    def train(self, steps: Optional[int] = None) -> Dict[str, Any]:
        steps = steps if steps is not None else self.cfg.steps
        end = self.step + steps
        while self.step < end and not self._preempted:
            batch = self.data.next_batch()
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self._step_times.append(dt)
            med = float(np.median(self._step_times[-50:]))
            if len(self._step_times) > 5 and dt > self.cfg.straggler_factor * med:
                print(f"[straggler] step {self.step} took {dt:.3f}s "
                      f"(median {med:.3f}s)")
            self.step += 1
            self.history.append(loss)
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                print(f"step {self.step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if self.ckpt is not None and self.step % self.cfg.ckpt_every == 0:
                self.save()
        if self._preempted:  # pragma: no cover - signal path
            print(f"[preempt] checkpointing at step {self.step} and exiting")
            self.save()
        return {"final_loss": self.history[-1] if self.history else None,
                "history": self.history, "step": self.step}
