"""AdamW with optional block-wise int8-quantized moments.

The int8 moment compression (bitsandbytes-style, block size 256 with a f32
absmax scale per block) cuts optimizer state from 8 B/param to ~2 B/param —
this is what fits jamba-398B training on a single 256-chip pod (see
DESIGN.md §4 and EXPERIMENTS.md §Dry-run memory table).

State layout per param leaf:
  fp32 moments:  {"m": f32[shape], "v": f32[shape]}
  int8 moments:  {"m_q": i8[shape], "m_s": f32[nblocks],
                  "v_q": i8[shape], "v_s": f32[nblocks]}
plus a scalar step counter at the tree root.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(np.pi * prog))
    return cfg.lr * warm * cos


# ---------------------------------------------------------------------------
# int8 block quantization of moments
# ---------------------------------------------------------------------------

def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-last-axis-row linear symmetric int8 (signed first moment m).

    Row-wise (not flat-block) scales keep the scale tensor sharded exactly
    like the parameter's leading axes — no cross-shard blocks, no resharding
    collectives inside the optimizer (crucial at 398B scale)."""
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _dq8(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def _q8_v(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Nonlinear int8 for the second moment v (non-negative, huge dynamic
    range): linear-quantize u = v**0.25.  A small v in a block with a large
    max then keeps ~(1/127)^4 relative resolution in v-space instead of
    collapsing to zero — which would blow up mhat/sqrt(vhat)."""
    return _q8(jnp.sqrt(jnp.sqrt(jnp.maximum(x, 0.0))))


def _dq8_v(q: jax.Array, s: jax.Array) -> jax.Array:
    u = _dq8(q, s)
    u2 = u * u
    return u2 * u2


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------

def adamw_init(params: Any, cfg: OptimizerConfig) -> Dict[str, Any]:
    def init_leaf(p):
        if cfg.quantized_state and p.ndim >= 2:
            srow = p.shape[:-1] + (1,)
            return {
                "m_q": jnp.zeros(p.shape, jnp.int8),
                "m_s": jnp.zeros(srow, jnp.float32),
                "v_q": jnp.zeros(p.shape, jnp.int8),
                "v_s": jnp.zeros(srow, jnp.float32),
            }
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}

    moments = jax.tree_util.tree_map(init_leaf, params)
    return {"moments": moments, "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: OptimizerConfig) -> Tuple[Any, Dict[str, Any],
                                                Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, mom):
        g = g.astype(jnp.float32) * scale
        quant = cfg.quantized_state and p.ndim >= 2
        if quant:
            m = _dq8(mom["m_q"], mom["m_s"])
            v = _dq8_v(mom["v_q"], mom["v_s"])
        else:
            m, v = mom["m"], mom["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if quant:
            m_q, m_s = _q8(m)
            v_q, v_s = _q8_v(v)
            return new_p, {"m_q": m_q, "m_s": m_s, "v_q": v_q, "v_s": v_s}
        return new_p, {"m": m, "v": v}

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = treedef.flatten_up_to(state["moments"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_moments = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"moments": new_moments, "step": step}, metrics
