from repro.training.optimizer import (
    adamw_init,
    adamw_update,
    OptimizerConfig,
)
from repro.training.trainer import Trainer, TrainConfig, make_train_step
from repro.training.checkpoint import CheckpointManager
from repro.training.data import TokenStream, DistillBatcher
