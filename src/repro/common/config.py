"""Architecture + shape-cell configuration.

Every assigned architecture is expressed as an ``ArchConfig``.  Block stacks
are described by a repeating ``block_pattern`` (one *period* of block kinds);
the model stacks ``n_layers / len(block_pattern)`` periods with a
``lax.scan`` so the HLO (and compile time) is O(one period), not O(n_layers).

Block kind strings are ``"<mixer>+<mlp>"``:
  mixer: ``attn`` | ``attn_local`` | ``attn_global`` | ``mamba``
  mlp:   ``dense`` | ``moe`` | ``none``
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.common.utils import pad_to_multiple

VOCAB_PAD = 256  # pad vocab to a multiple of this (divisible by model axis 16)


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0          # chatglm/glm4 rotate half the head dims
    qk_norm: bool = False            # qwen3-style RMSNorm on q/k
    softcap: Optional[float] = None  # gemma2 attention logit soft-capping
    window: Optional[int] = None     # sliding-window size for attn_local

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    block_pattern: Tuple[str, ...] = ("attn+dense",)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    post_block_norm: bool = False    # gemma2 sandwich norms
    embed_scale: bool = False        # gemma scales embeds by sqrt(d_model)
    final_softcap: Optional[float] = None
    tie_embeddings: bool = True
    frontend: Optional[str] = None   # "patch" (vlm) | "audio" — stub embeddings
    sub_quadratic: bool = False      # eligible for long_500k
    mlp_gated: bool = True
    grad_accum: int = 1              # microbatch count in train_step
    remat: bool = True
    notes: str = ""

    # ---- derived ----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, VOCAB_PAD)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}"
        )
        return self.n_layers // len(self.block_pattern)

    @property
    def has_attention(self) -> bool:
        return any(k.split("+")[0].startswith("attn") for k in self.block_pattern)

    @property
    def has_mamba(self) -> bool:
        return any(k.split("+")[0] == "mamba" for k in self.block_pattern)

    @property
    def has_moe(self) -> bool:
        return any(k.split("+")[1] == "moe" for k in self.block_pattern)

    def n_params_dense_equiv(self) -> int:
        """Approximate parameter count N (all params)."""
        from repro.models.model import param_count_estimate

        return param_count_estimate(self)

    def n_params_active(self) -> int:
        """Active params per token (MoE uses top_k experts only)."""
        from repro.models.model import param_count_estimate

        return param_count_estimate(self, active_only=True)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class BlockSpecEntry:
    """One entry of a block pattern, parsed."""

    mixer: str
    mlp: str

    @staticmethod
    def parse(kind: str) -> "BlockSpecEntry":
        mixer, mlp = kind.split("+")
        return BlockSpecEntry(mixer, mlp)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable_cells(cfg: ArchConfig) -> Tuple[str, ...]:
    """Which shape cells run for this architecture.

    ``long_500k`` needs sub-quadratic attention: only SSM/hybrid archs run it
    (skip recorded in DESIGN.md / EXPERIMENTS.md for the others).
    """
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return tuple(cells)
