"""Logical-axis sharding: one rules table maps logical tensor axes to mesh axes.

The production mesh is ``("data","model")`` single-pod or
``("pod","data","model")`` multi-pod (see launch/mesh.py).  Model code only
ever names *logical* axes; the rules below translate them, dropping mesh axes
that are absent (so the same model runs on a 1-device test mesh, a single-pod
mesh, and a multi-pod mesh unchanged).

Per-cell overrides (e.g. long_500k shards the KV sequence over "data" and
replicates the batch) are applied with ``rules_scope``.
"""
from __future__ import annotations

import contextlib
import inspect
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisVal = Union[None, str, Tuple[str, ...]]

# logical axis -> mesh axes (tuple entries mean "sharded over both, major first")
DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),      # global batch -> DP over pod+data
    "seq": None,                   # activations: sequence replicated by default
    "kv_seq": None,                # KV cache sequence (sharded for long_500k)
    "embed": None,                 # activation d_model
    "fsdp": ("data",),             # weight rows: ZeRO-3 over the data axis
    "vocab": ("model",),
    "heads": ("model",),           # attention q heads (TP)
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),             # FFN hidden (TP)
    "experts": ("model",),         # MoE expert parallelism
    "expert_fsdp": ("data",),      # expert weight d-rows (ZeRO-3; kept even
                                   # when dense "fsdp" is overridden — the
                                   # MoE shard_map handles the exchange)
    "expert_ff": None,
    "ssm_heads": ("model",),       # mamba heads (TP)
    "ssm_state": None,
    "conv": None,
    "layers": None,                # scan-stacked leading dim
    "frames": None,
    "pixels": None,
}

_local = threading.local()


def _stack() -> list:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


def current_rules() -> Dict[str, AxisVal]:
    rules = dict(DEFAULT_RULES)
    for override in _stack():
        rules.update(override)
    return rules


@contextlib.contextmanager
def rules_scope(**overrides: AxisVal):
    """Temporarily override logical->mesh rules (e.g. for decode cells)."""
    _stack().append(overrides)
    try:
        yield
    finally:
        _stack().pop()


# Global mesh used by shard_constraint / shard_map blocks. ``None`` disables
# constraints entirely (pure single-device smoke-test mode).
_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def current_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def mesh_scope(mesh: Optional[Mesh]):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _MESH = prev


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """Version-compat ``shard_map``: ``jax.shard_map`` where exposed,
    falling back to ``jax.experimental.shard_map.shard_map`` (jax 0.4.x).
    The replication-check kwarg is detected from the signature — the
    top-level export and the ``check_rep`` -> ``check_vma`` rename landed
    in different JAX releases."""
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    try:
        params = inspect.signature(sm).parameters
        kw = "check_vma" if "check_vma" in params else "check_rep"
    except (TypeError, ValueError):
        kw = "check_vma" if hasattr(jax, "shard_map") else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check})


def _filter_axes(val: AxisVal, mesh: Mesh) -> AxisVal:
    names = set(mesh.axis_names)
    if val is None:
        return None
    if isinstance(val, str):
        return val if val in names else None
    kept = tuple(a for a in val if a in names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_mesh(
    axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None
) -> PartitionSpec:
    """Translate a tuple of logical axis names into a PartitionSpec."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return PartitionSpec()
    rules = current_rules()
    used: set = set()
    out = []
    for ax in axes:
        val = rules.get(ax) if ax is not None else None
        val = _filter_axes(val, mesh)
        # a mesh axis may appear at most once in a spec
        if isinstance(val, tuple):
            val = tuple(a for a in val if a not in used) or None
            if isinstance(val, tuple) and len(val) == 1:
                val = val[0]
        if isinstance(val, str) and val in used:
            val = None
        if isinstance(val, tuple):
            used.update(val)
        elif isinstance(val, str):
            used.add(val)
        out.append(val)
    return PartitionSpec(*out)


def shard_constraint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_mesh(axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None):
    mesh = mesh or current_mesh()
    assert mesh is not None
    return NamedSharding(mesh, logical_to_mesh(axes, mesh))


def param_sharding_tree(axes_tree: Any, mesh: Optional[Mesh] = None) -> Any:
    """Map a tree of logical-axes tuples to NamedShardings."""
    mesh = mesh or current_mesh()
    return jax.tree_util.tree_map(
        lambda axes: named_sharding(axes, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(a is None or isinstance(a, str) for a in x),
    )


def dp_axis_names(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    """All mesh axes that carry data parallelism (everything but 'model')."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(name: str, mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def tp_size(mesh: Optional[Mesh] = None) -> int:
    return axis_size("model", mesh)


def dp_size(mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in dp_axis_names(mesh):
        n *= mesh.shape[a]
    return n
