"""Small shared utilities: padding, tree math, timing."""
from __future__ import annotations

import math
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def scan_unroll(length: int) -> int:
    """Unroll factor for lax.scan loops.

    The dry-run sets REPRO_UNROLL_SCANS=1 so every scan fully unrolls into
    its (single-iteration) while body — XLA's cost_analysis counts while
    bodies exactly once, so this is what makes HLO_FLOPs and the parsed
    collective bytes reflect the *whole* step instead of one iteration.
    Normal execution keeps unroll=1 (compact HLO, fast compiles).
    """
    return length if os.environ.get("REPRO_UNROLL_SCANS") == "1" else 1


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to_multiple(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m``."""
    return ceil_div(x, m) * m


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


class Timer:
    """Context timer used by benchmarks."""

    def __init__(self) -> None:
        self.elapsed = 0.0

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.elapsed = time.perf_counter() - self._t0


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_flops(n: float) -> str:
    for unit in ("F", "KF", "MF", "GF", "TF", "PF"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}EF"
