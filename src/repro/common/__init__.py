from repro.common.config import (
    ArchConfig,
    AttentionConfig,
    MoEConfig,
    SSMConfig,
    BlockSpecEntry,
    ShapeCell,
    SHAPE_CELLS,
)
from repro.common.sharding import (
    DEFAULT_RULES,
    logical_to_mesh,
    shard_constraint,
    param_sharding_tree,
)
from repro.common.utils import pad_to_multiple, ceil_div, tree_size_bytes
