"""Semantic query optimization — the paper's new optimization phase.

Three-stage procedure (Figure 3), with an *empirical validation* loop:

  (1) World-knowledge extraction: measure the stream sample (per-region
      frame-diff activity, active-region bbox, empty-frame fraction, object
      dwell times) and combine with query metadata into a symbolic
      ``SceneKnowledge`` — the reasoning context a human expert (or the
      paper's LLM agent) would build.
  (2) Operator selection: instantiate data-reduction operators from the
      catalog whose semantic preconditions hold (Skip/Crop/Downscale;
      Greyscale is *rejected* whenever the query needs color — the paper's
      flagship example of semantic reasoning).
  (3) Plan update: insert the operators at dependency-correct points
      (Skip directly after the source; Crop before Downscale).

The reasoning engine here is a deterministic knowledge base over measured
statistics (the container has no LLM); ``SemanticReasoner`` is the documented
plug-point where the paper drops in an MLLM (see DESIGN.md §3).

Validation: run naive vs. rewritten plan on a held-out sample; while the
query-level accuracy drop exceeds ``tolerance``, back off the most aggressive
operator (downscale factor, then skip amount, then crop) and re-validate —
the self-correcting hypothesize/test/refine loop from §3.2.1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.catalog import CATALOG
from repro.streaming.operators import (
    CropOp,
    DownscaleOp,
    GreyscaleOp,
    MLLMExtractOp,
    SkipOp,
)
from repro.streaming.plan import Plan


@dataclasses.dataclass
class SceneKnowledge:
    """Symbolic scene representation (stage 1 output)."""

    empty_fraction: float
    active_bbox: Optional[Tuple[int, int, int, int]]   # y0,x0,h,w
    active_area_frac: float
    min_dwell: int                # min consecutive active frames per object
    median_dwell: float
    mean_region_activity: np.ndarray
    metadata: Dict[str, Any]
    facts: List[str] = dataclasses.field(default_factory=list)

    def describe(self) -> str:
        return "\n".join("  - " + f for f in self.facts)


def extract_knowledge(sample_frames: np.ndarray, metadata: Dict[str, Any],
                      regions: Tuple[int, int] = (8, 16),
                      diff_threshold: float = 0.02) -> SceneKnowledge:
    """Stage 1: measure the sample; emit symbolic facts."""
    n, c, h, w = sample_frames.shape
    ry, rx = regions
    rh, rw = h // ry, w // rx
    x = sample_frames.astype(np.float32) / 255.0
    d = np.abs(x[1:] - x[:-1]).mean(axis=1)            # (n-1, h, w)
    dr = d.reshape(n - 1, ry, rh, rx, rw).mean(axis=(2, 4))  # (n-1, ry, rx)

    mean_act = dr.mean(axis=0)                          # (ry, rx)
    frame_active = dr.max(axis=(1, 2)) > diff_threshold
    empty_frac = 1.0 - frame_active.mean()

    # active bbox over regions with meaningful average activity
    act_regions = mean_act > max(diff_threshold * 0.5,
                                 mean_act.mean() + mean_act.std())
    if act_regions.any():
        ys, xs = np.where(act_regions)
        y0, y1 = ys.min() * rh, (ys.max() + 1) * rh
        x0, x1 = xs.min() * rw, (xs.max() + 1) * rw
        # quantize outward to 32px tiles
        y0, x0 = (y0 // 32) * 32, (x0 // 32) * 32
        y1, x1 = min(h, -(-y1 // 32) * 32), min(w, -(-x1 // 32) * 32)
        bbox = (int(y0), int(x0), int(y1 - y0), int(x1 - x0))
        area_frac = (y1 - y0) * (x1 - x0) / (h * w)
    else:
        bbox, area_frac = None, 1.0

    # dwell: lengths of consecutive active runs
    runs, cur = [], 0
    for a in frame_active:
        if a:
            cur += 1
        elif cur:
            runs.append(cur)
            cur = 0
    if cur:
        runs.append(cur)
    min_dwell = int(min(runs)) if runs else 1
    med_dwell = float(np.median(runs)) if runs else 1.0

    facts = [
        f"{empty_frac:.0%} of frames show no activity (empty-road prior)",
        f"activity is confined to bbox {bbox} "
        f"({area_frac:.0%} of the frame)" if bbox else
        "activity spans the whole frame (moving camera?)",
        f"objects dwell >= {min_dwell} frames (median {med_dwell:.0f}) — "
        "temporal continuity bound",
        f"stream metadata: fps={metadata.get('fps')}, "
        f"v_max={metadata.get('v_max_kmh', 'n/a')} km/h, "
        f"scene='{metadata.get('scene', '')}'",
    ]
    return SceneKnowledge(empty_fraction=float(empty_frac), active_bbox=bbox,
                          active_area_frac=float(area_frac),
                          min_dwell=min_dwell, median_dwell=med_dwell,
                          mean_region_activity=mean_act, metadata=metadata,
                          facts=facts)


class SemanticReasoner:
    """Stage 2: operator selection from the catalog.

    Deterministic knowledge-base stand-in for the paper's LLM agent —
    same inputs (SceneKnowledge + query intent), same outputs (a list of
    (operator, rationale) selections and explicit rejections).
    Swap this class for an MLLM-backed reasoner on a connected deployment.
    """

    def select(self, know: SceneKnowledge, query) -> Tuple[List, List[str]]:
        chosen, log = [], []

        # cross-frame reasoning: Skip
        if know.empty_fraction > 0.10 and know.min_dwell >= 3:
            amount = max(1, know.min_dwell // 3)
            chosen.append(SkipOp(amount=amount, condition="no_car",
                                 roi=know.active_bbox))
            log.append(
                f"SELECT Skip({amount}, no_car): {know.empty_fraction:.0%} "
                f"empty frames; objects dwell >= {know.min_dwell} frames so "
                f"re-checking every {amount+1} frames cannot miss a pass "
                f"[{CATALOG['skip']['precondition']}]")
        else:
            log.append(
                f"REJECT Skip: empty fraction {know.empty_fraction:.0%} too "
                "low or dwell too short (moving-camera stream)")

        # intra-frame reasoning: Crop
        if know.active_bbox is not None and know.active_area_frac < 0.7:
            chosen.append(CropOp(region=know.active_bbox))
            log.append(
                f"SELECT Crop{know.active_bbox}: activity confined to "
                f"{know.active_area_frac:.0%} of the frame "
                f"[{CATALOG['crop']['precondition']}]")
        else:
            log.append("REJECT Crop: no stable region of interest")

        # Downscale — resolution-sensitive features gate the factor
        if not query.needs_plate:
            chosen.append(DownscaleOp(factor=2))
            log.append(
                "SELECT Downscale(2): query reads "
                + ("color/brand blobs" if query.dataset == "tollbooth"
                   else "coarse motion")
                + ", which survive 2x area pooling "
                f"[{CATALOG['downscale']['precondition']}]")
        else:
            chosen.append(DownscaleOp(factor=2))
            log.append(
                "TENTATIVE Downscale(2): plate glyphs may not survive — "
                "flagged for empirical validation (back off on failure)")

        # Greyscale — the paper's explicit semantic rejection
        if query.needs_color:
            log.append(
                "REJECT Greyscale: the query predicate depends on color — "
                "removing chroma would change query semantics "
                f"[{CATALOG['greyscale']['precondition']}]")
        elif query.dataset == "tollbooth" and not query.needs_color:
            log.append(
                "REJECT Greyscale: downstream extraction (brand/plate) was "
                "trained on RGB statistics; chroma carries contrast")
        return chosen, log


class SemanticOptimizer:
    name = "semantic"

    def __init__(self, tolerance: float = 0.10, sample_frames: int = 256,
                 val_frames: int = 512):
        self.tolerance = tolerance
        self.sample_frames = sample_frames
        self.val_frames = val_frames
        self.reasoner = SemanticReasoner()

    # -- OptimizationPhase adapter (repro.core.phases) -------------------
    def run(self, plan: Plan, pctx) -> Tuple[Plan, Dict[str, Any]]:
        return self.optimize(plan, pctx.query, pctx.stream_factory,
                             pctx.run_fn, catalog=pctx.catalog)

    # ------------------------------------------------------------------
    def optimize(self, plan: Plan, query, stream_factory, run_fn,
                 catalog=None) -> Tuple[Plan, Dict[str, Any]]:
        """run_fn(plan, stream, n) -> RunResult; stream_factory(seed).
        ``catalog`` (a CostCatalog) receives the validation runs' wall
        clocks as run-derived model-cost samples."""
        report: Dict[str, Any] = {"phase": "semantic"}

        # (1) world knowledge from a sample
        sample_stream = stream_factory(101)
        frames, _ = sample_stream.batch(self.sample_frames)
        know = extract_knowledge(frames, sample_stream.metadata)
        report["knowledge"] = know.facts

        # (2) operator selection
        chosen, log = self.reasoner.select(know, query)
        report["selection_log"] = log

        # (3) plan update: Skip after source, then Crop, then Downscale
        new = plan.clone()
        order = {SkipOp: 0, CropOp: 1, DownscaleOp: 2, GreyscaleOp: 3}
        for op in sorted(chosen, key=lambda o: order[type(o)], reverse=True):
            new.insert_after_source(op, note=f"semantic: +{op.name}")

        # (4) empirical validation loop (self-correcting rewrites)
        def validated_run(p):
            res = run_fn(p, stream_factory(202), self.val_frames)
            if catalog is not None:
                catalog.record_run(p.ops, res.wall_s, res.mllm_frames)
            return res

        naive_acc = query.evaluate(validated_run(plan))
        attempts = []
        for round_i in range(4):
            acc = query.evaluate(validated_run(new))
            attempts.append({"plan": new.describe(), "accuracy": acc})
            if acc >= naive_acc - self.tolerance:
                break
            backed_off = self._back_off(new)
            report.setdefault("backoffs", []).append(backed_off)
            if backed_off is None:
                break
        report["naive_accuracy"] = naive_acc
        report["validation"] = attempts
        return new, report

    def _back_off(self, plan: Plan) -> Optional[str]:
        """Weaken the most aggressive reduction, strongest first."""
        i = plan.index_of(DownscaleOp)
        if i is not None:
            op = plan.ops[i]
            if op.factor > 2:
                op.factor //= 2
                return f"downscale factor -> {op.factor}"
            plan.ops.pop(i)
            return "removed downscale"
        i = plan.index_of(SkipOp)
        if i is not None:
            op = plan.ops[i]
            if op.amount > 1:
                op.amount //= 2
                return f"skip amount -> {op.amount}"
            plan.ops.pop(i)
            return "removed skip"
        i = plan.index_of(CropOp)
        if i is not None:
            plan.ops.pop(i)
            return "removed crop"
        return None
