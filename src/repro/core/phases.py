"""The common optimization-phase interface.

``SemanticOptimizer`` / ``LogicalOptimizer`` / ``PhysicalOptimizer`` each
grew their own ``optimize(...)`` signature (stream factories here, sample
frames there), which is why the orchestrator special-cased every phase and
why nothing else — in particular the fleet optimizer — could drive them.
This module extracts the shared contract:

* ``PhaseContext`` — everything a phase may need for one query: the query,
  its stream factory, a ``run_fn`` executing candidate plans, validation
  budgets, and the shared ``CostCatalog`` all phase timings flow into.

* ``OptimizationPhase`` — the protocol: a ``name`` and
  ``run(plan, pctx) -> (plan, report_dict)``.  The three optimizers
  implement it via thin adapters (keeping their richer native signatures
  for direct callers), so ``SuperOptimizer`` and ``FleetOptimizer`` drive
  any phase sequence uniformly and time each phase's wall clock in one
  place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

import numpy as np

from repro.streaming.plan import Plan

#: frames sampled for logical-phase measurement / chain calibration
SAMPLE_FRAMES = 64
#: held-out seed for the sample stream (matches the logical phase's
#: historical choice; distinct from validation seeds 202/303)
SAMPLE_SEED = 404


@dataclasses.dataclass
class PhaseContext:
    """Per-query inputs shared by every optimization phase."""

    query: Any
    stream_factory: Callable[[int], Any]
    run_fn: Callable[[Plan, Any, int], Any]   # (plan, stream, n) -> RunResult
    val_frames: int = 512
    catalog: Any = None                        # CostCatalog (optional)
    _sample: Optional[np.ndarray] = None

    def sample_frames(self, n: int = SAMPLE_FRAMES) -> np.ndarray:
        """A cached sample batch from the query's stream (phases measuring
        op costs / knowledge share one draw instead of re-sampling)."""
        if self._sample is None or self._sample.shape[0] < n:
            stream = self.stream_factory(SAMPLE_SEED)
            self._sample, _ = stream.batch(max(n, SAMPLE_FRAMES))
        return self._sample[:n]


class OptimizationPhase(Protocol):
    """One rewrite phase: semantically valid plan in, better plan out."""

    name: str

    def run(self, plan: Plan, pctx: PhaseContext
            ) -> Tuple[Plan, Dict[str, Any]]:
        """Rewrite ``plan`` for ``pctx.query``; returns the new plan and a
        report dict whose ``"phase"`` key names the phase."""
        ...
