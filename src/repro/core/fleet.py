"""Fleet optimizer: joint, sharing-aware super-optimization of a query set.

``SuperOptimizer`` specializes one query to one stream; running it per
query destroys exactly the structure the sharing tier depends on: two
queries that would share a prefix and a union extract come back with
slightly different Crop boxes, different backoff results, a cheap filter
one of them pushed down, or different physical model choices — and
``SharingTreePlanner`` (which groups by ``Op.signature()`` chains and the
extract's merge key) can no longer share anything.  The fleet optimizer
closes that gap: it optimizes the *set* of queries, trading per-query
rewrites against the sharing they would break.

The fleet cost objective
------------------------
For an assignment of one concrete plan per query, the fleet cost is the
estimated per-source-frame cost of executing the whole workload through
the sharing forest the planner would build for it:

    fleet_cost(plans) = Σ_feeds Σ_groups [ cost(shared prefix, once)
                                           + Σ_tails cost(tail) ]
                        − coalescing_saving(forests)

with per-op costs *measured* (the ``CostCatalog`` stamped ``cost_us``) and
selectivity-aware (a filter's measured ``pass_rate`` discounts everything
downstream — the logical optimizer's pushdown gate applied fleet-wide).
The subtracted term is the *server-level* cross-feed interaction
(``scheduler.sharing_tree.coalescing_saving_us``): groups on different
feeds whose extracts land in the same (variant, frame-shape) bucket
coalesce at the ``SharedExtractServer`` into one dispatch instead of k,
so the objective rewards canonical prefixes that keep feeds
bucket-aligned.  A rewrite is accepted only if it lowers this joint
objective: a rewrite that saves 5% on one query but breaks a prefix four
other queries share (or knocks a feed out of a cross-feed bucket) raises
the objective and is rejected.

Procedure
---------
1. **Solo pass** — each query runs the ordinary phase pipeline through the
   common ``OptimizationPhase`` interface, sharing one ``CostCatalog`` so
   every timing (logical micro-benchmarks, semantic/physical validation
   runs, final chain calibration) lands in one measured cost model.
2. **Canonicalization** — per feed, the solo plans' pre-extract chains are
   joined into a canonical prefix with *safe-join* parameters (union crop,
   min skip amount, min downscale factor, …: the least aggressive setting
   any member needed), ops not common to every member dropped (they are
   data-reduction ops; dropping only returns toward naive semantics), and
   the physical model chosen **jointly**: the cheapest variant inside
   every member's accuracy-viable set.  Canonical chains are built from
   one op instance and copied, so semantically-equivalent prefixes keep
   bitwise-identical ``Op.signature()`` chains — the unit the planner
   factors on.  Each member's canonical plan is re-validated against its
   naive accuracy; members that fail the tolerance keep their solo plan.
3. **Assignment** — greedy coordinate descent over {canonical, solo} per
   query, minimizing the fleet cost objective; every accept/reject is
   logged with its cost delta.

The result carries per-query plans whose execution through
``MultiQueryRuntime`` / ``MultiStreamRuntime`` is bitwise identical to
running each chosen plan alone — sharing changes how many forwards run,
never what a query observes.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.costs import CostCatalog, mllm_key
from repro.core.phases import PhaseContext
from repro.core.superopt import OptimizationReport, SuperOptimizer
from repro.streaming.operators import (
    CheapColorFilterOp,
    CropOp,
    DetectOp,
    DownscaleOp,
    FusedPreprocessOp,
    GreyscaleOp,
    MLLMExtractOp,
    Op,
    OpContext,
    SkipOp,
    SourceOp,
)
from repro.streaming.plan import Plan


@dataclasses.dataclass
class FleetQuery:
    """One member of the fleet: a catalog query standing on a feed."""

    query: Any                                # queries.catalog.Query
    stream_factory: Callable[[int], Any]      # seed -> stream
    feed: str = ""                            # defaults to query.dataset

    def __post_init__(self):
        if not self.feed:
            self.feed = self.query.dataset


@dataclasses.dataclass
class FleetResult:
    """Joint optimization output: one stamped plan per query, grouped by
    feed, plus the forests / reports / decision log that justify it."""

    plans: Dict[str, Plan]                    # qid -> chosen plan
    plans_by_feed: Dict[str, List[Plan]]
    #: per-feed SharingForest over the chosen plans
    forests: Dict[str, Any]
    reports: Dict[str, OptimizationReport]    # per-query solo reports
    decisions: List[str]                      # fleet-level accept/reject log
    fleet_cost_us: Dict[str, float]           # naive / solo / fleet totals
    catalog: CostCatalog
    #: the baselines the fleet assignment chose over, calibrated with the
    #: same catalog (benchmarks compare all three without re-optimizing)
    solo_plans: Dict[str, Plan] = dataclasses.field(default_factory=dict)
    naive_plans: Dict[str, Plan] = dataclasses.field(default_factory=dict)
    feed_keys: Dict[str, List[str]] = dataclasses.field(default_factory=dict)

    def audit(self, tolerance: float = 0.5):
        """A ``repro.obs.audit.PlanAudit`` over this result's forests and
        optimization reports — join with a served run's metrics for the
        predicted-vs-measured decision table, or call
        ``verify_predictions()`` to check the stored costs still derive
        from the catalog."""
        from repro.obs.audit import PlanAudit
        return PlanAudit.from_fleet(self, tolerance=tolerance)

    def describe(self) -> str:
        lines = ["=== fleet optimization ==="]
        lines += [f"  {d}" for d in self.decisions]
        lines.append(
            "fleet cost (µs/frame): " + "  ".join(
                f"{k}={v:.0f}" for k, v in self.fleet_cost_us.items()))
        for feed, forest in self.forests.items():
            lines.append(f"[{feed}]")
            lines.append(forest.describe())
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# safe-join: the least aggressive parameterization any member needed
# ---------------------------------------------------------------------------

def _union_bbox(regions: List[Tuple[int, int, int, int]]
                ) -> Tuple[int, int, int, int]:
    y0 = min(r[0] for r in regions)
    x0 = min(r[1] for r in regions)
    y1 = max(r[0] + r[2] for r in regions)
    x1 = max(r[1] + r[3] for r in regions)
    return (y0, x0, y1 - y0, x1 - x0)


def _join_skip(ops: List[SkipOp]) -> Optional[SkipOp]:
    if len({o.condition for o in ops}) != 1 or \
            len({o.regions for o in ops}) != 1:
        return None
    rois = [o.roi for o in ops]
    roi = None if any(r is None for r in rois) else _union_bbox(rois)
    return SkipOp(amount=min(o.amount for o in ops),
                  condition=ops[0].condition,
                  threshold=min(o.threshold for o in ops),
                  roi=roi, regions=ops[0].regions)


def _join_cheap_color(ops: List[CheapColorFilterOp]
                      ) -> Optional[CheapColorFilterOp]:
    if len({o.color for o in ops}) != 1:
        return None                     # different predicates never join
    rois = [o.roi for o in ops]
    roi = None if any(r is None for r in rois) else _union_bbox(rois)
    return CheapColorFilterOp(color=ops[0].color,
                              min_frac=min(o.min_frac for o in ops),
                              roi=roi)


def _join_fused(ops: List[FusedPreprocessOp]) -> FusedPreprocessOp:
    return FusedPreprocessOp(crop=_union_bbox([o.crop for o in ops]),
                             factor=min(o.factor for o in ops),
                             grey=all(o.grey for o in ops))


def _join_source(ops: List[SourceOp]) -> Optional[SourceOp]:
    if len({o.stream_name for o in ops}) != 1:
        return None                     # never rebind a query's source
    return SourceOp(stream_name=ops[0].stream_name)


_SAFE_JOIN: Dict[type, Callable[[List[Op]], Optional[Op]]] = {
    SourceOp: _join_source,
    SkipOp: _join_skip,
    CropOp: lambda ops: CropOp(region=_union_bbox([o.region for o in ops])),
    DownscaleOp: lambda ops: DownscaleOp(factor=min(o.factor for o in ops)),
    GreyscaleOp: lambda ops: GreyscaleOp(),
    FusedPreprocessOp: _join_fused,
    CheapColorFilterOp: _join_cheap_color,
    DetectOp: lambda ops: DetectOp(threshold=min(o.threshold for o in ops)),
}


def safe_join(ops: List[Op]) -> Optional[Op]:
    """One op valid for every member, or None when the class cannot join
    (then it is *dropped* from the canonical prefix — every joinable class
    here is a data-reduction op, so dropping is semantics-safe)."""
    cls = type(ops[0])
    if any(type(o) is not cls for o in ops):
        return None
    fn = _SAFE_JOIN.get(cls)
    if fn is not None:
        return fn(ops)
    # unknown class: join only when structurally identical already
    if len({o.signature() for o in ops}) == 1:
        return copy.deepcopy(ops[0])
    return None


def joined_prefix(chains: List[List[Op]]) -> List[Op]:
    """Join N pre-extract chains into one canonical chain: classes present
    in every chain (in the first chain's order, verified consistent) with
    safe-join parameters; everything else dropped."""
    class_sets = [[type(o) for o in ch] for ch in chains]
    common = [cls for cls in class_sets[0]
              if all(cls in cs for cs in class_sets)]
    # order consistency: the common subsequence must be ordered the same in
    # every chain, or the later op's semantics could change (e.g. a crop
    # before vs after a downscale) — drop everything past a violation
    joined: List[Op] = []
    last_pos = [-1] * len(chains)
    for cls in common:
        pos = [cs.index(cls) for cs in class_sets]
        if any(p <= lp for p, lp in zip(pos, last_pos)):
            break
        op = safe_join([ch[p] for ch, p in zip(chains, pos)])
        if op is None:
            continue
        joined.append(op)
        last_pos = pos
    return joined


# ---------------------------------------------------------------------------
# fleet optimizer
# ---------------------------------------------------------------------------

class FleetOptimizer:
    """Jointly optimize a workload of queries over one or more feeds.

    ``planner`` scores candidate assignments (it carries the calibrated
    catalog); ``tolerance`` bounds the accuracy a canonicalized plan may
    lose vs the query's naive accuracy (the same contract the semantic
    phase enforces for its own rewrites)."""

    def __init__(self, ctx: OpContext, tolerance: float = 0.10,
                 min_rel_accuracy: float = 0.90, micro_batch: int = 16,
                 val_frames: int = 256,
                 catalog: Optional[CostCatalog] = None,
                 planner=None,
                 max_rounds: int = 3, rel_margin: float = 0.02,
                 gate_hit_rate: Optional[float] = None):
        # deferred: repro.scheduler <-> repro.core import cycle
        from repro.scheduler.sharing_tree import SharingTreePlanner

        self.ctx = ctx
        self.tolerance = tolerance
        self.val_frames = val_frames
        #: a flip away from the current assignment must beat it by this
        #: relative margin — calibrated costs carry measurement noise, and
        #: breaking a share for a hair-thin estimated win is a bad trade
        self.rel_margin = rel_margin
        self.catalog = catalog if catalog is not None else CostCatalog()
        self.solo = SuperOptimizer(ctx, tolerance=tolerance,
                                   min_rel_accuracy=min_rel_accuracy,
                                   micro_batch=micro_batch,
                                   val_frames=val_frames,
                                   catalog=self.catalog)
        # gated plans pay the model only for the novel fraction of their
        # frames: the planner discounts extract costs by the measured
        # semantic-cache hit rate (catalog.gate_hit_rates, or an explicit
        # override), so assignments are priced for the serving tier as it
        # actually runs — sharing that only paid off at full model load
        # is correctly dropped once gating absorbs most of it
        self.planner = planner if planner is not None \
            else SharingTreePlanner(catalog=self.catalog,
                                    micro_batch=micro_batch,
                                    gate_hit_rate=gate_hit_rate)
        self.max_rounds = max_rounds

    # ------------------------------------------------------------------
    def optimize(self, workload: List[FleetQuery],
                 phases: Tuple[str, ...] = ("semantic", "logical",
                                            "physical")) -> FleetResult:
        assert workload, "empty fleet"
        keys = self._keys(workload)
        by_feed: Dict[str, List[str]] = {}
        fq_of: Dict[str, FleetQuery] = {}
        for key, fq in zip(keys, workload):
            by_feed.setdefault(fq.feed, []).append(key)
            fq_of[key] = fq

        decisions: List[str] = []

        # (1) solo pass — per-query phase pipeline, one shared catalog
        solo_plans: Dict[str, Plan] = {}
        reports: Dict[str, OptimizationReport] = {}
        naive_plans: Dict[str, Plan] = {}
        for key in keys:
            fq = fq_of[key]
            plan, report = self.solo.optimize(fq.query, fq.stream_factory,
                                              phases=phases)
            plan.query = key
            solo_plans[key], reports[key] = plan, report
            naive = fq.query.naive_plan()
            naive.query = key
            self._calibrate(naive, fq)
            naive_plans[key] = naive

        # (2) canonicalization per feed
        canonical: Dict[str, Plan] = {}
        for feed, fkeys in by_feed.items():
            canonical.update(self._canonicalize(
                feed, fkeys, fq_of, solo_plans, reports, decisions))

        # (3) assignment by fleet cost: greedy coordinate descent.  A flip
        # re-plans exactly one feed's forest, but the objective is *not*
        # per-feed additive: the server-level coalescing term rewards
        # bucket alignment across feeds, so every candidate is scored over
        # the full forest set (the cross-feed term itself is cheap).
        choice: Dict[str, str] = {
            key: ("fleet" if key in canonical else "solo") for key in keys}

        def feed_plans(feed: str, ch: Dict[str, str]) -> List[Plan]:
            return [canonical[k] if ch[k] == "fleet" else solo_plans[k]
                    for k in by_feed[feed]]

        forests = {feed: self.planner.plan(feed_plans(feed, choice))
                   for feed in by_feed}
        base_cost = self._forests_cost(forests)
        for rnd in range(self.max_rounds):
            changed = False
            for key in keys:
                if key not in canonical:
                    continue
                feed = fq_of[key].feed
                flipped = dict(choice)
                flipped[key] = "solo" if choice[key] == "fleet" else "fleet"
                alt_forests = dict(forests)
                alt_forests[feed] = self.planner.plan(
                    feed_plans(feed, flipped))
                alt_cost = self._forests_cost(alt_forests)
                if alt_cost < base_cost * (1.0 - self.rel_margin):
                    decisions.append(
                        f"{key}: {flipped[key]} plan accepted "
                        f"(fleet cost {base_cost:.0f} -> {alt_cost:.0f}"
                        "µs/frame)")
                    choice, base_cost, changed = flipped, alt_cost, True
                    forests = alt_forests
                elif rnd == 0 and choice[key] == "fleet":
                    partners = [k for k in by_feed[feed] if k != key]
                    decisions.append(
                        f"{key}: per-query rewrite rejected — fleet cost "
                        f"{base_cost:.0f} -> {alt_cost:.0f}µs/frame "
                        f"(breaks sharing with "
                        f"{{{','.join(partners) or '-'}}})")
            if not changed:
                break

        save = self._coalescing_saving(forests)
        if save > 0:
            decisions.append(
                f"cross-feed bucket alignment: {save:.0f}µs/frame server "
                "coalescing saving across the chosen forests")

        plans = {key: (canonical[key] if choice[key] == "fleet"
                       else solo_plans[key]) for key in keys}
        plans_by_feed = {feed: [plans[k] for k in fkeys]
                         for feed, fkeys in by_feed.items()}
        costs = {
            "naive": self._fleet_cost(
                {f: [naive_plans[k] for k in ks]
                 for f, ks in by_feed.items()}),
            "solo": self._fleet_cost(
                {f: [solo_plans[k] for k in ks]
                 for f, ks in by_feed.items()}),
            "fleet": base_cost,
        }
        return FleetResult(plans=plans, plans_by_feed=plans_by_feed,
                           forests=forests, reports=reports,
                           decisions=decisions, fleet_cost_us=costs,
                           catalog=self.catalog, solo_plans=solo_plans,
                           naive_plans=naive_plans, feed_keys=dict(by_feed))

    # ------------------------------------------------------------------
    @staticmethod
    def _keys(workload: List[FleetQuery]) -> List[str]:
        seen: Dict[str, int] = {}
        keys = []
        for fq in workload:
            qid = fq.query.qid
            if qid in seen:
                keys.append(f"{fq.feed}:{qid}")
            else:
                keys.append(qid)
            seen[qid] = seen.get(qid, 0) + 1
        assert len(set(keys)) == len(keys), f"duplicate fleet keys {keys}"
        return keys

    def _calibrate(self, plan: Plan, fq: FleetQuery) -> None:
        pctx = PhaseContext(query=fq.query, stream_factory=fq.stream_factory,
                            run_fn=self.solo._run,
                            val_frames=self.val_frames,
                            catalog=self.catalog)
        self.catalog.calibrate_chain(plan.ops, pctx.sample_frames(),
                                     self.ctx)
        self.catalog.stamp(plan.ops)

    def _model_cost(self, variant: str) -> float:
        from repro.scheduler.sharing_tree import MODEL_COST_US

        us = self.catalog.lookup(mllm_key(variant))
        return us if us is not None \
            else MODEL_COST_US.get(variant, MODEL_COST_US["big"])

    def _viable_models(self, key: str, plan: Plan,
                       reports: Dict[str, OptimizationReport]) -> List[str]:
        for ph in reports[key].phases:
            sel = ph.get("model_selection")
            if sel is not None:
                return list(sel.get("viable", [sel["chosen"]]))
        mi = plan.index_of(MLLMExtractOp)
        return [plan.ops[mi].model] if mi is not None else []

    # ------------------------------------------------------------------
    def _canonicalize(self, feed: str, fkeys: List[str],
                      fq_of: Dict[str, FleetQuery],
                      solo_plans: Dict[str, Plan],
                      reports: Dict[str, OptimizationReport],
                      decisions: List[str]) -> Dict[str, Plan]:
        """Build the canonical (shareable) candidate per member of one
        feed; members whose canonical plan fails validation keep solo."""
        members = [k for k in fkeys
                   if solo_plans[k].index_of(MLLMExtractOp) is not None]
        if len(members) < 2:
            return {}
        # a feed is one physical stream; a workload that labels two
        # different sources with the same feed string cannot canonicalize
        # (the join would silently rebind a query's source)
        if len({solo_plans[k].ops[0].stream_name for k in members}) != 1:
            decisions.append(
                f"{feed}: canonicalization skipped — members read "
                "different source streams")
            return {}
        mis = {k: solo_plans[k].index_of(MLLMExtractOp) for k in members}

        def expand(ops):
            # class-intersection joining reasons about the unfused op
            # descriptors; a physically fused prefix re-expands here so
            # fusion never blocks cross-query sharing (the runtimes
            # re-fuse per group where calibration still favors it)
            out = []
            for op in ops:
                stage_ops = getattr(op, "unfuse", None)
                out.extend(op.unfuse() if stage_ops is not None else [op])
            return out

        chains = [expand(solo_plans[k].ops[:mis[k]]) for k in members]
        joined = joined_prefix(chains)

        # joint physical model: cheapest variant viable for every member
        viable_all = None
        for k in members:
            v = set(self._viable_models(k, solo_plans[k], reports))
            viable_all = v if viable_all is None else viable_all & v
        variant = min(viable_all, key=self._model_cost) if viable_all \
            else "big"
        dt = min(solo_plans[k].ops[mis[k]].density_threshold
                 for k in members)
        decisions.append(
            f"{feed}: canonical prefix "
            f"[{' -> '.join(op.name for op in joined)}] + mllm[{variant}] "
            f"for {{{','.join(members)}}}")

        out: Dict[str, Plan] = {}
        for k in members:
            fq = fq_of[k]
            solo_ex = solo_plans[k].ops[mis[k]]
            ops = [copy.deepcopy(op) for op in joined]
            ops.append(MLLMExtractOp(tasks=solo_ex.tasks, model=variant,
                                     density_threshold=dt))
            ops.extend(copy.deepcopy(op)
                       for op in solo_plans[k].ops[mis[k] + 1:])
            cand = Plan(ops, query=k,
                        notes=list(solo_plans[k].notes)
                        + ["fleet: canonicalized prefix"])
            # re-validate: canonical must stay within tolerance of naive
            naive_acc = self._naive_accuracy(k, fq, reports)
            res = self.solo._run(cand, fq.stream_factory(202),
                                 self.val_frames)
            acc = fq.query.evaluate(res)
            self.catalog.record_run(cand.ops, res.wall_s, res.mllm_frames)
            if acc < naive_acc - self.tolerance:
                decisions.append(
                    f"{k}: canonical plan rejected by validation "
                    f"(acc {acc:.3f} < naive {naive_acc:.3f} - "
                    f"{self.tolerance:.2f}) — keeps solo plan")
                continue
            self._calibrate(cand, fq)
            out[k] = cand
        return out

    def _naive_accuracy(self, key: str, fq: FleetQuery,
                        reports: Dict[str, OptimizationReport]) -> float:
        for ph in reports[key].phases:
            if "naive_accuracy" in ph:
                return ph["naive_accuracy"]
        res = self.solo._run(fq.query.naive_plan(), fq.stream_factory(202),
                             self.val_frames)
        return fq.query.evaluate(res)

    # ------------------------------------------------------------------
    def _coalescing_saving(self, forests: Dict[str, Any]) -> float:
        from repro.scheduler.sharing_tree import coalescing_saving_us

        return coalescing_saving_us(
            forests.values(), self.catalog,
            micro_batch=getattr(self.planner, "micro_batch", 16),
            frame_shape=self.ctx.frame_shape)

    def _forests_cost(self, forests: Dict[str, Any]) -> float:
        """The joint objective over a forest per feed: summed per-feed
        shared costs minus the server-level cross-feed coalescing saving
        (groups on different feeds landing in the same (variant, shape)
        bucket pay one extract dispatch, not k)."""
        per_feed = sum(g.shared_cost_us
                       for f in forests.values() for g in f.groups())
        return per_feed - self._coalescing_saving(forests)

    def _fleet_cost(self, plans_by_feed: Dict[str, List[Plan]]) -> float:
        """The joint objective for an assignment: per-source-frame cost of
        the sharing forests the planner would build for it, including the
        cross-feed server term.  The planner never mutates submitted plans
        (factor_plans clones), so assignments are scored without copying
        model-bearing ops."""
        return self._forests_cost({feed: self.planner.plan(plans)
                                   for feed, plans in plans_by_feed.items()})
