"""Multi-query planner pass: factor N plans over one stream into a shared
prefix + per-query tails.

The paper's throughput lever is MLLM model load; serving many concurrent
queries over the same stream multiplies that load N× unless the executor
shares work.  This pass takes N Plans whose sources name the same stream,
walks their operator chains in lockstep, and factors out the longest common
prefix:

  * structurally identical ops (Skip / Crop / FusedPreprocess / cheap
    filters — compared by ``Op.signature()``, i.e. class + init params,
    never runtime state) are kept once;
  * a column of ``MLLMExtractOp``s with the same physical model merges into
    a *single* op extracting the union of the requested tasks — one batched
    forward per surviving frame instead of one per query (StreamMLLM
    computes every head in one pass, so the union costs the same forward
    and each query reads exactly the attributes it asked for);
  * factoring stops at the first structural divergence, and never absorbs a
    Sink — the relational tail (Filter / WindowAgg / Sink) stays per-query.

The result is executed by ``repro.streaming.multiquery.MultiQueryRuntime``,
which fans each annotated shared batch out to the per-query tails.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.streaming.mllm import MLLM_TASKS
from repro.streaming.operators import MLLMExtractOp, Op, SinkOp, SourceOp
from repro.streaming.plan import Plan


@dataclasses.dataclass
class SharedExecution:
    """A factored multi-query execution: one prefix chain, N tail chains."""

    prefix: List[Op]                 # Source ... (maybe merged MLLM ...)
    tails: List[List[Op]]            # per-query suffix, each ends in a Sink
    queries: List[str]               # query ids, parallel to ``tails``
    notes: List[str] = dataclasses.field(default_factory=list)

    def describe(self) -> str:
        head = " -> ".join(op.name for op in self.prefix)
        lines = [f"shared: {head}"]
        for qid, tail in zip(self.queries, self.tails):
            lines.append(f"  {qid}: ... -> " +
                         " -> ".join(op.name for op in tail))
        return "\n".join(lines)


def mllm_merge_key(op: MLLMExtractOp) -> Tuple:
    """Physical identity of an extract op *modulo its task set*: two
    extracts with the same merge key run the same backbone variant and can
    therefore collapse into one union-task forward."""
    return (op.model, op.density_threshold)


def merge_mllm_column(ops: List[Op]) -> Optional[MLLMExtractOp]:
    """Merge one MLLMExtractOp per plan into a union-task op, or None if the
    column is not uniformly the same physical MLLM configuration."""
    if not all(isinstance(o, MLLMExtractOp) for o in ops):
        return None
    keys = {mllm_merge_key(o) for o in ops}
    if len(keys) != 1:
        return None
    union = tuple(t for t in MLLM_TASKS
                  if any(t in o.tasks for o in ops))
    model, threshold = keys.pop()
    return MLLMExtractOp(tasks=union, model=model,
                         density_threshold=threshold)


def share_key(plan: Plan) -> Tuple:
    """Grouping key for the sharing-tree planner: the signature chain of
    every op before the first MLLM extract, plus that extract's merge key.

    Plans with equal share keys factor into one group whose prefix reaches
    *through* a merged union-task extract (the expensive op); plans with
    different keys would stop factoring at the first structural divergence
    anyway, so grouping by this key is exactly "share where it pays".
    Plans without an MLLM get ``(pre-sink signature chain, None)``, so
    pure relational plans only share if structurally identical up to the
    sink."""
    pre: List[Tuple] = []
    for op in plan.ops:
        if isinstance(op, MLLMExtractOp):
            return (tuple(pre), mllm_merge_key(op))
        if isinstance(op, SinkOp):
            break
        pre.append(op.signature())
    return (tuple(pre), None)


def factor_plans(plans: List[Plan]) -> SharedExecution:
    """Factor N single-stream plans into a SharedExecution."""
    assert plans, "need at least one plan"
    sources = {p.ops[0].stream_name for p in plans
               if isinstance(p.ops[0], SourceOp)}
    assert len(sources) == 1, \
        f"multi-query sharing needs one common stream, got {sources}"

    clones = [p.clone() for p in plans]     # never alias caller op state
    notes: List[str] = []
    max_depth = min(len(p.ops) for p in clones) - 1   # keep every Sink
    # the structurally-identical leading segment comes from the Plan API
    # (equality is transitive, so the N-way prefix is the pairwise minimum)
    depth = min([clones[0].common_prefix(p) for p in clones[1:]],
                default=max_depth)
    prefix, _ = clones[0].split_at(depth)
    # past the identical segment: columns may still merge (union-task MLLM),
    # and a merge can re-open identical sharing behind it
    while depth < max_depth:
        column = [p.ops[depth] for p in clones]
        if any(isinstance(o, SinkOp) for o in column):
            break
        if len({o.signature() for o in column}) == 1:
            prefix.append(column[0])
            depth += 1
            continue
        merged = merge_mllm_column(column)
        if merged is None:
            break
        prefix.append(merged)
        notes.append(
            f"merged {len(column)} MLLM extracts -> union tasks "
            f"{','.join(merged.tasks)} ({merged.model})")
        depth += 1
    assert depth >= 1, "plans share no source — nothing to factor"

    tails = [p.split_at(depth)[1] for p in clones]
    # per-query results are keyed by id — duplicate submissions of the same
    # query must not collapse onto one key, so disambiguate repeats
    queries: List[str] = []
    used: set = set()
    for i, p in enumerate(plans):
        qid = p.query or f"q{i}"
        if qid in used:
            k = 1
            while f"{qid}#{k}" in used:
                k += 1
            qid = f"{qid}#{k}"
        used.add(qid)
        queries.append(qid)
    notes.append(f"shared prefix depth {depth} across {len(plans)} queries")
    return SharedExecution(prefix=prefix, tails=tails, queries=queries,
                           notes=notes)
