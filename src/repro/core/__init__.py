from repro.core.semantic import SceneKnowledge, SemanticOptimizer
from repro.core.logical import LogicalOptimizer
from repro.core.physical import PhysicalOptimizer, structured_prune
from repro.core.superopt import SuperOptimizer, OptimizationReport
from repro.core.multiquery import SharedExecution, factor_plans
