from repro.core.semantic import SceneKnowledge, SemanticOptimizer
from repro.core.logical import LogicalOptimizer
from repro.core.physical import PhysicalOptimizer, structured_prune
from repro.core.phases import OptimizationPhase, PhaseContext
from repro.core.costs import CostCatalog, CostEntry, op_cost_key
from repro.core.superopt import SuperOptimizer, OptimizationReport
from repro.core.multiquery import SharedExecution, factor_plans
from repro.core.fleet import FleetOptimizer, FleetQuery, FleetResult
