"""The data-reduction operator catalog the semantic optimizer selects from.

Mirrors the paper's Figure 3: the optimizer doesn't synthesize arbitrary
code — it instantiates operators from a curated catalog, each annotated with
its *semantic precondition* (when the rewrite preserves query correctness)
and its parameter-derivation rule.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

CATALOG = {
    "skip": {
        "params": "(amount, condition, threshold, roi)",
        "precondition": "objects persist >= k frames; empty frames carry no "
                        "query-relevant information",
        "derivation": "amount <= min observed object dwell // safety so a "
                      "re-check always lands inside any pass",
    },
    "crop": {
        "params": "(region)",
        "precondition": "query-relevant objects confined to a spatial region",
        "derivation": "bounding box of frame-diff activity, quantized to "
                      "32px tiles",
    },
    "downscale": {
        "params": "(factor)",
        "precondition": "query features survive the resolution loss "
                        "(color: yes; glyph-level text: validate!)",
        "derivation": "factor 2 unless the query needs glyph detail",
    },
    "greyscale": {
        "params": "()",
        "precondition": "NO query predicate or extraction depends on color",
        "derivation": "reject whenever the query mentions color",
    },
}
