"""Physical optimization — implementation selection for the MLLM operator.

§3.2.3's levers, all in-framework:
  * detector cascade: TinyDet prefilters frames before the MLLM (YOLO role),
    cost-gated like every pushdown;
  * accuracy-constrained model selection: candidates {big, distilled-small,
    pruned} are evaluated on the validation sample; the cheapest variant
    within ``min_rel_accuracy`` of the big model wins (the LOTUS/Palimpzest
    -style contract the paper adopts);
  * structured pruning: magnitude-based FFN-column pruning that *actually
    shrinks* the matrices (d_ff -> d_ff·(1-rate)) — not masking;
  * int8 weight quantization (serving/quantize.py; the Pallas int8 matmul
    is the TPU execution path);
  * adaptive pruning hook: the runtime may switch big <-> pruned per
    micro-batch from observed stream density (the paper's adaptive-pruning
    direction) — exposed as ``model="adaptive"`` on MLLMExtractOp.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.streaming.operators import (
    DetectOp,
    MLLMExtractOp,
    OpContext,
)
from repro.streaming.plan import Plan


# ---------------------------------------------------------------------------
# structured pruning
# ---------------------------------------------------------------------------

def structured_prune(mllm, params: Any, rate: float = 0.5) -> Any:
    """Prune FFN hidden columns by joint |w_in|·|w_out| magnitude.

    Returns params for the same architecture with d_ff' = d_ff·(1-rate)
    (every layer prunes the same count, keeping the scanned stack uniform).
    """
    import copy

    params = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy tree
    stack = params["backbone"]["stack"]

    def prune_block(block):
        if "mlp" not in block or "w_in" not in block["mlp"]:
            return block
        mlp = block["mlp"]
        w_in, w_out = mlp["w_in"], mlp["w_out"]      # (L, d, f), (L, f, d)
        f = w_in.shape[-1]
        keep = int(f * (1.0 - rate))
        score = (jnp.linalg.norm(w_in, axis=1)
                 * jnp.linalg.norm(w_out, axis=2))   # (L, f)
        idx = jnp.argsort(-score, axis=-1)[:, :keep]  # (L, keep)
        idx = jnp.sort(idx, axis=-1)

        def take2(w, axis):
            return jnp.take_along_axis(
                w, jnp.expand_dims(idx, axis=1 if axis == 2 else 2), axis=axis)

        new = dict(mlp)
        new["w_in"] = take2(w_in, 2)
        new["w_out"] = take2(w_out, 1)
        if "w_gate" in mlp:
            new["w_gate"] = take2(mlp["w_gate"], 2)
        block = dict(block)
        block["mlp"] = new
        return block

    new_stack = {k: prune_block(v) for k, v in stack.items()}
    out = dict(params)
    out["backbone"] = dict(params["backbone"])
    out["backbone"]["stack"] = new_stack
    return out


# ---------------------------------------------------------------------------
# physical optimizer
# ---------------------------------------------------------------------------

class PhysicalOptimizer:
    name = "physical"

    def __init__(self, ctx: OpContext, min_rel_accuracy: float = 0.90):
        self.ctx = ctx
        self.min_rel = min_rel_accuracy

    # -- OptimizationPhase adapter (repro.core.phases) -------------------
    def run(self, plan: Plan, pctx) -> Tuple[Plan, Dict[str, Any]]:
        return self.optimize(plan, pctx.query, pctx.stream_factory,
                             pctx.run_fn, val_frames=pctx.val_frames,
                             catalog=pctx.catalog,
                             sample=pctx.sample_frames())

    def optimize(self, plan: Plan, query, stream_factory, run_fn,
                 val_frames: int = 512, catalog=None, sample=None
                 ) -> Tuple[Plan, Dict[str, Any]]:
        report: Dict[str, Any] = {"phase": "physical", "decisions": []}
        new = plan.clone()

        # ---- detector cascade before the MLLM (cost-gated) ----------------
        if query.dataset == "tollbooth":
            mi = new.index_of(MLLMExtractOp)
            det = DetectOp(threshold=0.5)
            new.insert_before(MLLMExtractOp, det,
                              note="physical: TinyDet cascade")
            report["decisions"].append(
                "cascade: TinyDet (≈50k params) prefilters car-less frames "
                "before the MLLM (the YOLOv8 role)")

        # ---- accuracy-constrained model selection --------------------------
        candidates = ["big", "small"]
        if self.ctx.mllm_pruned_params is not None:
            candidates.append("pruned")
        accs: Dict[str, float] = {}
        costs: Dict[str, float] = {}
        base_plan = new.clone()
        for cand in candidates:
            p = base_plan.clone()
            mi = p.index_of(MLLMExtractOp)
            p.ops[mi].model = cand
            t0 = time.perf_counter()
            res = run_fn(p, stream_factory(303), val_frames)
            costs[cand] = time.perf_counter() - t0
            accs[cand] = query.evaluate(res)
            if catalog is not None:
                catalog.record_run(p.ops, res.wall_s, res.mllm_frames)
        base = max(accs["big"], 1e-9)
        viable = [c for c in candidates
                  if accs[c] >= self.min_rel * base]
        best = min(viable, key=lambda c: costs[c]) if viable else "big"
        report["model_selection"] = {
            "accuracies": accs, "wall_s": costs,
            "constraint": f">= {self.min_rel:.0%} of big-model accuracy",
            "viable": viable or ["big"],   # fleet: joint selection reads this
            "chosen": best,
        }
        mi = new.index_of(MLLMExtractOp)
        new.ops[mi].model = best
        new.notes.append(f"physical: model={best}")
        report["decisions"].append(
            f"model selection: '{best}' — accuracy {accs[best]:.3f} vs big "
            f"{accs['big']:.3f} (constraint {self.min_rel:.0%}), "
            f"wall {costs[best]:.2f}s vs {costs['big']:.2f}s")
        report["decisions"].append(
            "quantization: int8 weight path available for the chosen model "
            "(serving/quantize.py + Pallas int8_matmul on TPU); applied when "
            "the accuracy constraint still holds")

        # ---- fused prefix execution (calibrated one-pass choice) -----------
        self._fuse_prefix(new, report, catalog, stream_factory, sample)
        return new, report

    # ------------------------------------------------------------------
    def _fuse_prefix(self, plan: Plan, report: Dict[str, Any], catalog,
                     stream_factory, sample) -> None:
        """Replace the plan's surviving-frame prefix with a single
        ``FusedPrefixOp`` device pass — but only when the calibrated cost
        model says the fused call beats the unfused op sequence on a
        sample micro-batch.

        Both alternatives are timed through ``catalog.calibrate_chain``
        (fresh descriptor copies, so plan state is untouched) and
        compared at the sample batch size with the fitted
        ``T(n) = overhead + marginal·n`` model, survivor fractions
        shrinking n down the unfused chain.  No catalog → no fusion:
        this decision is always measurement-backed, never a guess."""
        from repro.core.phases import SAMPLE_FRAMES, SAMPLE_SEED
        from repro.streaming.fused import (
            FUSABLE,
            FusedPrefixOp,
            fusable_segment,
        )

        report["fused_prefix"] = {"fused": False, "reason": "no catalog"}
        if catalog is None:
            return
        mi = plan.index_of(MLLMExtractOp)
        if mi is None:
            report["fused_prefix"] = {"fused": False, "reason": "no extract"}
            return
        start = mi
        while start > 0 and isinstance(plan.ops[start - 1], FUSABLE):
            start -= 1
        # every member is FUSABLE; trim from the left until the ordering
        # constraints (Skip first, Detect last) hold too
        while start < mi and not fusable_segment(plan.ops[start:mi]):
            start += 1
        seg = plan.ops[start:mi]
        if len(seg) < 2:
            report["fused_prefix"] = {
                "fused": False, "reason": "segment too short",
                "segment": [o.name for o in seg]}
            return
        if sample is None:
            sample, _ = stream_factory(SAMPLE_SEED).batch(SAMPLE_FRAMES)

        def copies(ops):
            import dataclasses as _dc
            return [type(o)(**{f.name: getattr(o, f.name)
                               for f in _dc.fields(o) if f.init})
                    for o in ops]

        cand = FusedPrefixOp(stage_ops=tuple(copies(seg)), sig=True)
        unfused_probe = copies(seg)
        catalog.calibrate_chain(unfused_probe, sample, self.ctx)
        catalog.calibrate_chain([cand], sample, self.ctx)

        n = sample.shape[0]
        unfused_us = _chain_cost_us(unfused_probe, n)
        fused_us = _chain_cost_us([cand], n)
        info = {"segment": [o.name for o in seg], "batch": n,
                "fused_us": fused_us, "unfused_us": unfused_us,
                # fitted T(n) terms, so the audit layer can re-price the
                # decision at the batch size serving actually observed
                "fused_marginal_us": cand.cost_us,
                "fused_overhead_us": cand.overhead_us,
                "fused": fused_us <= unfused_us}
        report["fused_prefix"] = info
        if not info["fused"]:
            report["decisions"].append(
                f"fused prefix: refused — calibrated {fused_us:.0f}µs vs "
                f"{unfused_us:.0f}µs unfused at batch {n}")
            return
        fop = FusedPrefixOp(stage_ops=tuple(seg), sig=True)
        fop.cost_us = cand.cost_us
        fop.overhead_us = cand.overhead_us
        fop.pass_rate = cand.pass_rate
        plan.ops[start:mi] = [fop]
        plan.notes.append(f"physical: fused prefix ({len(seg)} ops -> 1 "
                          "device pass)")
        report["decisions"].append(
            f"fused prefix: {'+'.join(o.name for o in seg)} -> one device "
            f"pass — calibrated {fused_us:.0f}µs vs {unfused_us:.0f}µs "
            f"unfused at batch {n} (gate signature included for free)")


def _chain_cost_us(ops: List[Any], n: int) -> float:
    """Expected chain wall time at batch size ``n`` under the calibrated
    ``T = overhead + marginal·rows`` model, rows shrinking by each op's
    measured survivor fraction."""
    rows, total = float(n), 0.0
    for op in ops:
        total += max(op.overhead_us, 0.0) + max(op.cost_us, 0.0) * rows
        rows *= min(max(op.pass_rate, 0.0), 1.0)
    return total
