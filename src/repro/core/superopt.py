"""Saṃsāra: the super-optimizer orchestrator.

Spends large *offline* effort specializing one long-running query to one
stream (the paper's core bet): semantic -> logical -> physical, each phase
validated empirically, producing an OptimizationReport whose artifacts
(knowledge facts, selection log, rewrite rules, model-selection table) are
the inspectable equivalent of the paper's Figures 2-4.

Phases are driven through the common ``OptimizationPhase`` interface
(``repro.core.phases``): each phase's wall clock is timed here, every
measurement the phases take flows into a shared ``CostCatalog``, and a
final calibration pass stamps the optimized plan's operators with measured
``cost_us``/``pass_rate`` — the inputs ``repro.core.fleet`` and the
sharing-tree planner score against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.costs import CostCatalog
from repro.obs import resolve_obs
from repro.core.logical import LogicalOptimizer
from repro.core.phases import OptimizationPhase, PhaseContext
from repro.core.physical import PhysicalOptimizer
from repro.core.semantic import SemanticOptimizer
from repro.streaming.operators import OpContext
from repro.streaming.plan import Plan
from repro.streaming.runtime import StreamRuntime


@dataclasses.dataclass
class OptimizationReport:
    query: str
    naive_plan: str
    phases: List[Dict[str, Any]]
    final_plan: str
    #: wall-clock seconds spent inside each phase, keyed by phase name
    phase_wall_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: calibrated per-op timings for the final plan (one row per op:
    #: name, catalog key, measured µs/frame, survivor fraction)
    op_timings: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def describe(self) -> str:
        lines = [f"=== Saṃsāra optimization report: {self.query} ===",
                 f"naive:  {self.naive_plan}"]
        for ph in self.phases:
            wall = self.phase_wall_s.get(ph.get("phase", ""), None)
            head = f"--- phase: {ph['phase']}" + \
                (f" ({wall:.2f}s) ---" if wall is not None else " ---")
            lines.append(head)
            for key in ("knowledge", "selection_log", "rules", "decisions"):
                for item in ph.get(key, []):
                    lines.append(f"  {item}")
            if "model_selection" in ph:
                lines.append(f"  model selection: {ph['model_selection']}")
            if "validation" in ph:
                for att in ph["validation"]:
                    lines.append(f"  validate: acc={att['accuracy']:.3f} "
                                 f"{att['plan']}")
        lines.append(f"final:  {self.final_plan}")
        for row in self.op_timings:
            lines.append(f"  calibrated: {row['op']:<40s} "
                         f"{row['us']:>10.1f}µs/frame  "
                         f"pass={row['pass_rate']:.2f}")
        return "\n".join(lines)

    def to_rows(self) -> List[Dict[str, Any]]:
        """Structured rows (phase walls + calibrated op timings) for the
        benchmark driver's ``--json`` output."""
        rows = [{"kind": "phase_wall", "query": self.query,
                 "phase": ph, "wall_s": w}
                for ph, w in self.phase_wall_s.items()]
        rows += [{"kind": "op_timing", "query": self.query, **row}
                 for row in self.op_timings]
        return rows


class SuperOptimizer:
    def __init__(self, ctx: OpContext, tolerance: float = 0.10,
                 min_rel_accuracy: float = 0.90, micro_batch: int = 16,
                 val_frames: int = 512,
                 catalog: Optional[CostCatalog] = None):
        self.ctx = ctx
        self.micro_batch = micro_batch
        self.val_frames = val_frames
        #: shared measurement sink — pass one catalog across queries (the
        #: fleet optimizer does) to accumulate a workload-wide cost model
        self.catalog = catalog if catalog is not None else CostCatalog()
        self.semantic = SemanticOptimizer(tolerance=tolerance,
                                          val_frames=val_frames)
        self.logical = LogicalOptimizer(ctx)
        self.physical = PhysicalOptimizer(ctx,
                                          min_rel_accuracy=min_rel_accuracy)
        #: the phase registry, every entry an OptimizationPhase
        self.phase_registry: Dict[str, OptimizationPhase] = {
            p.name: p for p in (self.semantic, self.logical, self.physical)}

    # ------------------------------------------------------------------
    def _run(self, plan: Plan, stream, n: int):
        rt = StreamRuntime(plan, self.ctx, micro_batch=self.micro_batch)
        return rt.run(stream, n)

    def optimize(self, query, stream_factory,
                 phases: Tuple[str, ...] = ("semantic", "logical",
                                            "physical"),
                 calibrate: bool = True
                 ) -> Tuple[Plan, OptimizationReport]:
        plan = query.naive_plan()
        pctx = PhaseContext(query=query, stream_factory=stream_factory,
                            run_fn=self._run, val_frames=self.val_frames,
                            catalog=self.catalog)
        report_phases: List[Dict[str, Any]] = []
        phase_wall_s: Dict[str, float] = {}
        naive_desc = plan.describe()

        obs = resolve_obs(getattr(self.ctx, "obs", None))

        for name in phases:
            phase = self.phase_registry[name]
            t0 = time.perf_counter()
            t0_ns = obs.now() if obs.enabled else 0
            plan, rep = phase.run(plan, pctx)
            phase_wall_s[name] = time.perf_counter() - t0
            if obs.enabled:
                obs.tracer.span(f"opt:{name}", "optimize", t0_ns,
                                obs.now(), track="superopt")
            report_phases.append(rep)

        op_timings: List[Dict[str, Any]] = []
        if calibrate:
            t0 = time.perf_counter()
            t0_ns = obs.now() if obs.enabled else 0
            op_timings = self.calibrate(plan, pctx)
            phase_wall_s["calibration"] = time.perf_counter() - t0
            if obs.enabled:
                obs.tracer.span("opt:calibration", "optimize", t0_ns,
                                obs.now(), track="superopt")

        if obs.enabled:
            # the report's phase walls + calibrated op timings land in the
            # registry next to the serving metrics (one accounting surface)
            m = obs.metrics
            for ph, w in phase_wall_s.items():
                m.set_gauge(f"superopt/{query.qid}/{ph}_wall_s", w)
            for row in op_timings:
                m.set_gauge(
                    f"superopt/{query.qid}/op_us/{row['op']}", row["us"])

        report = OptimizationReport(
            query=query.qid, naive_plan=naive_desc,
            phases=report_phases, final_plan=plan.describe(),
            phase_wall_s=phase_wall_s, op_timings=op_timings)
        return plan, report

    def calibrate(self, plan: Plan, pctx: PhaseContext
                  ) -> List[Dict[str, Any]]:
        """Measure every op of ``plan`` on its actual chain input, stamping
        ``cost_us``/``pass_rate`` in place; returns the timing rows."""
        from repro.core.costs import op_cost_key

        self.catalog.calibrate_chain(plan.ops, pctx.sample_frames(),
                                     self.ctx)
        self.catalog.stamp(plan.ops)        # chains cut short by a filter
        return [{"op": op.name, "key": op_cost_key(op), "us": op.cost_us,
                 "pass_rate": op.pass_rate} for op in plan.ops
                if op.cost_us >= 0]
