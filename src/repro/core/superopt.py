"""Saṃsāra: the super-optimizer orchestrator.

Spends large *offline* effort specializing one long-running query to one
stream (the paper's core bet): semantic -> logical -> physical, each phase
validated empirically, producing an OptimizationReport whose artifacts
(knowledge facts, selection log, rewrite rules, model-selection table) are
the inspectable equivalent of the paper's Figures 2-4.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.logical import LogicalOptimizer
from repro.core.physical import PhysicalOptimizer
from repro.core.semantic import SemanticOptimizer
from repro.streaming.operators import OpContext
from repro.streaming.plan import Plan
from repro.streaming.runtime import StreamRuntime


@dataclasses.dataclass
class OptimizationReport:
    query: str
    naive_plan: str
    phases: List[Dict[str, Any]]
    final_plan: str

    def describe(self) -> str:
        lines = [f"=== Saṃsāra optimization report: {self.query} ===",
                 f"naive:  {self.naive_plan}"]
        for ph in self.phases:
            lines.append(f"--- phase: {ph['phase']} ---")
            for key in ("knowledge", "selection_log", "rules", "decisions"):
                for item in ph.get(key, []):
                    lines.append(f"  {item}")
            if "model_selection" in ph:
                lines.append(f"  model selection: {ph['model_selection']}")
            if "validation" in ph:
                for att in ph["validation"]:
                    lines.append(f"  validate: acc={att['accuracy']:.3f} "
                                 f"{att['plan']}")
        lines.append(f"final:  {self.final_plan}")
        return "\n".join(lines)


class SuperOptimizer:
    def __init__(self, ctx: OpContext, tolerance: float = 0.10,
                 min_rel_accuracy: float = 0.90, micro_batch: int = 16,
                 val_frames: int = 512):
        self.ctx = ctx
        self.micro_batch = micro_batch
        self.val_frames = val_frames
        self.semantic = SemanticOptimizer(tolerance=tolerance,
                                          val_frames=val_frames)
        self.logical = LogicalOptimizer(ctx)
        self.physical = PhysicalOptimizer(ctx,
                                          min_rel_accuracy=min_rel_accuracy)

    # ------------------------------------------------------------------
    def _run(self, plan: Plan, stream, n: int):
        rt = StreamRuntime(plan, self.ctx, micro_batch=self.micro_batch)
        return rt.run(stream, n)

    def optimize(self, query, stream_factory,
                 phases: Tuple[str, ...] = ("semantic", "logical",
                                            "physical")
                 ) -> Tuple[Plan, OptimizationReport]:
        plan = query.naive_plan()
        report_phases: List[Dict[str, Any]] = []
        naive_desc = plan.describe()

        if "semantic" in phases:
            plan, rep = self.semantic.optimize(
                plan, query, stream_factory, self._run)
            report_phases.append(rep)

        if "logical" in phases:
            sample_stream = stream_factory(404)
            frames, _ = sample_stream.batch(64)
            plan, rep = self.logical.optimize(plan, query, frames)
            report_phases.append(rep)

        if "physical" in phases:
            plan, rep = self.physical.optimize(
                plan, query, stream_factory, self._run,
                val_frames=self.val_frames)
            report_phases.append(rep)

        report = OptimizationReport(
            query=query.qid, naive_plan=naive_desc,
            phases=report_phases, final_plan=plan.describe())
        return plan, report
