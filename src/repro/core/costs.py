"""Calibrated operator cost catalog — the measured cost model the fleet
optimizer and the sharing-tree planner share.

Every timing the optimization phases already take (``logical._time_op``
micro-benchmarks, semantic/physical validation runs) flows into one
``CostCatalog``; a dedicated ``calibrate_chain`` pass additionally walks a
plan on a sample batch, timing each operator on its *actual* input (post-
crop/downscale shapes, post-filter survivor sets) and measuring its
survivor fraction.  Calibration stamps ``op.cost_us`` / ``op.pass_rate``
in place, so ``scheduler.sharing_tree.op_cost_us`` uses measured costs end
to end and the static ``MODEL_COST_US`` / ``OP_COST_US`` tables become the
fallback of last resort.

Entries are keyed coarsely — ``"<OpClass>"`` for relational/semantic ops,
``"mllm[<variant>]"`` for extracts; stamping the op instances in place is
what carries the per-plan (post-crop/downscale resolution) differences,
and per-resolution ``"mllm[<variant>]@<H>x<W>"`` entries are recorded as
diagnostics for the benchmark report.  Direct per-op measurements outrank
run-derived estimates: a whole-pipeline validation run only brackets the
extract's cost, so it never overwrites a micro-benchmarked entry.

The catalog persists as JSON (``save``/``load`` round-trip exactly) so a
long-lived deployment keeps its measurements across optimizer sessions,
and ``rows()`` emits the structured form the benchmark driver writes under
``--json``.
"""
from __future__ import annotations

import copy
import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.streaming.operators import MLLMExtractOp, Op, OpContext

#: EMA weight for merging a new sample into an existing entry of the same
#: provenance — recent measurements dominate (streams drift)
EMA = 0.5


def op_cost_key(op: Op) -> str:
    """Catalog key for one operator: extracts key by physical variant,
    every other op by class.  (Per-resolution extract measurements are
    additionally recorded under ``mllm_key(variant, shape)`` — diagnostic
    rows for the benchmark report; cost resolution itself reads the
    stamped op first, so the per-plan resolution difference is already
    captured where it matters.)"""
    if isinstance(op, MLLMExtractOp):
        return f"mllm[{op.model}]"
    return type(op).__name__


def mllm_key(variant: str, shape: Optional[tuple] = None) -> str:
    if shape is None:
        return f"mllm[{variant}]"
    return f"mllm[{variant}]@{shape[-2]}x{shape[-1]}"


@dataclasses.dataclass
class CostEntry:
    us: float                 # marginal per-input-frame cost, µs
    pass_rate: float = 1.0    # survivor fraction on the calibration sample
    overhead_us: float = 0.0  # fixed per-invocation cost, µs
    n: int = 1                # samples merged into this entry
    direct: bool = False      # micro-benchmarked (vs run-derived estimate)

    def merge(self, us: float, pass_rate: float, direct: bool,
              overhead_us: float = 0.0) -> None:
        if self.direct and not direct:
            return                      # run estimates never clobber direct
        if direct and not self.direct:  # first direct sample wins outright
            self.us, self.pass_rate = us, pass_rate
            self.overhead_us = overhead_us
            self.direct, self.n = True, 1
            return
        self.us = (1 - EMA) * self.us + EMA * us
        self.pass_rate = (1 - EMA) * self.pass_rate + EMA * pass_rate
        self.overhead_us = (1 - EMA) * self.overhead_us + EMA * overhead_us
        self.n += 1


class CostCatalog:
    """Persistent measured per-op cost table (µs per input frame)."""

    VERSION = 1

    def __init__(self):
        self.entries: Dict[str, CostEntry] = {}
        #: measured semantic-gate hit rate per feed (fraction of extract
        #: frames answered from the keyframe cache) — the model-load term
        #: the sharing-tree planner discounts extract costs by
        self.gate_hit_rates: Dict[str, float] = {}

    # -- recording ---------------------------------------------------------
    def record(self, key: str, us: float, pass_rate: float = 1.0,
               direct: bool = False, overhead_us: float = 0.0) -> None:
        assert us >= 0, f"negative cost for {key}"
        if key in self.entries:
            self.entries[key].merge(us, pass_rate, direct, overhead_us)
        else:
            self.entries[key] = CostEntry(us=us, pass_rate=pass_rate,
                                          overhead_us=overhead_us,
                                          direct=direct)

    def record_op(self, op: Op, us: float, pass_rate: float = 1.0,
                  direct: bool = True, overhead_us: float = 0.0) -> None:
        """Record a measurement for one op (and, for extracts, the
        shape-free per-variant aggregate that backs unstamped plans)."""
        self.record(op_cost_key(op), us, pass_rate, direct, overhead_us)

    def record_run(self, plan_ops: List[Op], wall_s: float,
                   mllm_frames: int) -> None:
        """Fold a whole-pipeline validation run into the catalog: the
        extract dominates the wall, so wall/mllm_frames upper-bounds the
        chosen variant's per-frame cost.  Run-derived, never direct."""
        if mllm_frames <= 0:
            return
        us = wall_s / mllm_frames * 1e6
        for op in plan_ops:
            if isinstance(op, MLLMExtractOp):
                self.record(mllm_key(op.model), us, direct=False)

    def record_gate_hit_rate(self, feed: str, rate: float) -> None:
        """Fold one measured semantic-cache hit rate for a feed (from a
        gated run's counters) into the catalog — EMA-merged like every
        other measurement, so recent traffic dominates."""
        assert 0.0 <= rate <= 1.0, rate
        if feed in self.gate_hit_rates:
            self.gate_hit_rates[feed] = \
                (1 - EMA) * self.gate_hit_rates[feed] + EMA * rate
        else:
            self.gate_hit_rates[feed] = rate

    def reconcile(self, measured: Dict[str, Dict[str, float]],
                  tolerance: float = 0.5) -> List[str]:
        """Fold *serving-time* measurements back into the catalog — the
        audit loop's write path, mirroring ``record_gate_hit_rate``:
        predictions that drift from reality are EMA-pulled toward what
        the last run actually measured, so the next planning pass
        self-corrects instead of compounding a stale calibration.

        ``measured`` maps catalog key → ``{"us": marginal µs/frame,
        "overhead_us"?: per-invocation µs, "pass_rate"?: survivor
        fraction, "frames"?: sample weight}``.  Unlike ``record``, this
        deliberately bypasses the direct-outranks-run protection: a
        measurement taken *under serving conditions* (real batches, real
        interleaving, device-probed forwards) is better ground truth for
        planning than an offline micro-benchmark, however directly that
        was timed.  Keys without a prior entry are created outright.

        Returns the keys whose prior marginal cost was off by more than
        ``tolerance`` (relative, both directions) — the drift flags the
        flight report surfaces."""
        flagged: List[str] = []
        for key, m in measured.items():
            us = float(m["us"])
            if us < 0 or not np.isfinite(us):
                continue
            e = self.entries.get(key)
            if e is None:
                self.entries[key] = CostEntry(
                    us=us, pass_rate=float(m.get("pass_rate", 1.0)),
                    overhead_us=float(m.get("overhead_us", 0.0)),
                    direct=False)
                continue
            if e.us > us * (1 + tolerance) or us > e.us * (1 + tolerance):
                flagged.append(key)
            e.us = (1 - EMA) * e.us + EMA * us
            if "pass_rate" in m:
                e.pass_rate = (1 - EMA) * e.pass_rate \
                    + EMA * float(m["pass_rate"])
            if "overhead_us" in m:
                e.overhead_us = (1 - EMA) * e.overhead_us \
                    + EMA * float(m["overhead_us"])
            e.n += 1
        return flagged

    def mean_gate_hit_rate(self) -> float:
        """Workload-level hit rate the planner discounts extract costs
        by; 0 until a gated run has been measured."""
        if not self.gate_hit_rates:
            return 0.0
        return sum(self.gate_hit_rates.values()) / len(self.gate_hit_rates)

    # -- lookup / stamping -------------------------------------------------
    def lookup(self, key: str) -> Optional[float]:
        e = self.entries.get(key)
        return e.us if e is not None else None

    #: the catalog key for an op — exposed as a method so consumers that
    #: cannot import this module at load time (scheduler <-> core cycle)
    #: reach it through the catalog instance
    key_of = staticmethod(op_cost_key)

    def lookup_op(self, op: Op) -> Optional[float]:
        return self.lookup(op_cost_key(op))

    def lookup_op_overhead(self, op: Op) -> Optional[float]:
        e = self.entries.get(op_cost_key(op))
        return e.overhead_us if e is not None else None

    def stamp(self, ops: List[Op]) -> List[str]:
        """Fill ``op.cost_us``/``op.pass_rate``/``op.overhead_us`` from
        catalog entries for every op that has no stamped measurement yet;
        returns the names of ops the catalog could not cover."""
        missing: List[str] = []
        for op in ops:
            if op.cost_us >= 0:
                continue
            e = self.entries.get(op_cost_key(op))
            if e is None:
                missing.append(op.name)
                continue
            op.cost_us = e.us
            op.pass_rate = e.pass_rate
            op.overhead_us = e.overhead_us
        return missing

    # -- direct calibration ------------------------------------------------
    def calibrate_chain(self, ops: List[Op], frames: np.ndarray,
                        ctx: OpContext, reps: int = 2) -> None:
        """Walk a plan on a sample batch, timing each op on its actual
        input and measuring its survivor fraction; stamps each op in place
        and records the measurement for catalog fallback.

        Each op is timed at two batch sizes and the pair is fit to
        ``T(n) = overhead + marginal·n``: the fixed per-invocation term
        (dispatch, compiled-program lookup, padding) is what sharing
        amortizes, and folding it into a per-frame average — the old
        estimate — systematically undervalues shared execution on sparse
        streams where few frames reach the expensive ops.

        Ops are timed on *clones* (timing reps mutate stateful ops like
        Skip), but the real chain advances with the original instances so
        downstream ops see realistic inputs."""
        batch = {"frames": frames, "idx": np.arange(frames.shape[0])}
        for op in ops:
            n_in = int(batch["idx"].shape[0])
            if n_in == 0:
                break
            probe = copy.deepcopy(op)
            probe.open(ctx)
            probe.reset()             # validation runs may have left state
            t_full = _time_probe(probe, batch, reps)
            n_small = n_in // 4
            if n_small >= 1 and n_small < n_in:
                small = _copy_batch(batch)
                small["frames"] = batch["frames"][:n_small]
                small["idx"] = batch["idx"][:n_small]
                if "attrs" in batch:
                    small["attrs"] = {k: np.asarray(v)[:n_small]
                                      for k, v in batch["attrs"].items()}
                t_small = _time_probe(probe, small, reps)
                marginal = max(t_full - t_small, 0.0) / (n_in - n_small)
                overhead = max(t_small - marginal * n_small, 0.0)
            else:
                marginal, overhead = t_full / n_in, 0.0
            us = marginal * 1e6
            over_us = overhead * 1e6
            op.open(ctx)
            op.reset()                # a stale skip carry would empty the
            out = op.process(_copy_batch(batch))       # whole sample chain
            out.pop("window_results", None)
            n_out = int(out["idx"].shape[0])
            op.reset()
            op.cost_us = us
            op.overhead_us = over_us
            op.pass_rate = n_out / n_in
            self.record_op(op, us, op.pass_rate, direct=True,
                           overhead_us=over_us)
            if isinstance(op, MLLMExtractOp):
                self.record(mllm_key(op.model, batch["frames"].shape),
                            us, op.pass_rate, direct=True,
                            overhead_us=over_us)
            batch = out

    # -- persistence / reporting -------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": self.VERSION,
            "entries": {k: dataclasses.asdict(e)
                        for k, e in sorted(self.entries.items())},
            "gate_hit_rates": dict(sorted(self.gate_hit_rates.items())),
        }

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "CostCatalog":
        assert data.get("version") == cls.VERSION, \
            f"cost catalog version {data.get('version')} != {cls.VERSION}"
        cat = cls()
        for k, e in data.get("entries", {}).items():
            cat.entries[k] = CostEntry(**e)
        cat.gate_hit_rates = dict(data.get("gate_hit_rates", {}))
        return cat

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CostCatalog":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def rows(self) -> List[Dict[str, Any]]:
        """Structured rows for ``benchmarks/run.py --json``."""
        return [{"op": k, "us": e.us, "pass_rate": e.pass_rate,
                 "overhead_us": e.overhead_us, "n": e.n, "direct": e.direct}
                for k, e in sorted(self.entries.items())]

    def __len__(self) -> int:
        return len(self.entries)


def _copy_batch(batch: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(batch)
    if "attrs" in out:
        out["attrs"] = dict(out["attrs"])
    return out


def _time_probe(probe: Op, batch: Dict[str, Any], reps: int) -> float:
    """Seconds per invocation of ``probe`` on ``batch`` (after an untimed
    warmup invocation that compiles this batch shape)."""
    probe.process(_copy_batch(batch))
    probe.reset()
    t0 = time.perf_counter()
    for _ in range(reps):
        probe.process(_copy_batch(batch))
        probe.reset()
    return (time.perf_counter() - t0) / reps
