"""Logical optimization — relational rewrites mapped to multimodal operators.

Following §3.2.2's three steps:
  (1) Data-model reconciliation: an image is a relation
      (row_id, col_id, r, g, b) with (row_id, col_id) as the composite key.
  (2) Operation mapping:  Crop ≙ selection on the key / projection,
      Downscale ≙ group-by-aggregate, Greyscale ≙ projection,
      MLLM-Extract ≙ expensive UDF, attribute Filter ≙ selection.
  (3) Optimization-rule mapping, cost-gated:
      R1 predicate split + pushdown  — a conjunctive filter with a cheaply
         approximable conjunct (color) splits; the cheap half becomes a
         pixel-statistics filter *before* the MLLM UDF.
      R2 projection pushdown        — Crop commutes before Downscale
         (select-before-aggregate): same output, fewer pixels aggregated.
      R3 operator fusion            — adjacent Crop/Downscale/Greyscale
         collapse into FusedPreprocessOp (one HBM pass; the Pallas kernel).

The cost model is *measured*: each candidate operator is timed per-frame on
a sample batch, and a pushdown is applied only when
    cost(cheap_filter) < (1 - selectivity) · cost(downstream MLLM)
— the paper's warning that an expensive early filter can increase cost.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.streaming.operators import (
    CheapColorFilterOp,
    CropOp,
    DownscaleOp,
    FusedPreprocessOp,
    GreyscaleOp,
    MLLMExtractOp,
    OpContext,
    SkipOp,
)
from repro.streaming.plan import Plan

RECONCILIATION = (
    "image(frame_id) ≅ relation pixels(row_id, col_id, r, g, b) "
    "with key (row_id, col_id); "
    "Crop ≅ σ_{y0<=row<y1 ∧ x0<=col<x1}; Downscale(f) ≅ "
    "γ_{row/f, col/f; avg(r),avg(g),avg(b)}; Greyscale ≅ π_{lum(r,g,b)}; "
    "MLLM-Extract ≅ expensive UDF; attribute Filter ≅ σ over UDF output"
)


def _time_op(op, frames: np.ndarray, ctx: OpContext, reps: int = 3,
             catalog=None) -> float:
    """Measured µs/frame for one operator on a sample batch; the sample
    flows into ``catalog`` (a CostCatalog) when one is given."""
    batch = {"frames": frames, "idx": np.arange(frames.shape[0])}
    op.open(ctx)
    op.process(dict(batch))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        op.process(dict(batch))
    dt = (time.perf_counter() - t0) / reps
    us = dt / frames.shape[0] * 1e6
    if catalog is not None:
        # average cost (overhead folded in): a coarse estimate — the
        # calibration pass's decomposed marginal+overhead fit outranks it
        catalog.record_op(op, us, direct=False)
    return us


class LogicalOptimizer:
    name = "logical"

    def __init__(self, ctx: OpContext):
        self.ctx = ctx

    # -- OptimizationPhase adapter (repro.core.phases) -------------------
    def run(self, plan: Plan, pctx) -> Tuple[Plan, Dict[str, Any]]:
        return self.optimize(plan, pctx.query, pctx.sample_frames(),
                             catalog=pctx.catalog)

    def optimize(self, plan: Plan, query, sample_frames: np.ndarray,
                 catalog=None) -> Tuple[Plan, Dict[str, Any]]:
        report: Dict[str, Any] = {"phase": "logical",
                                  "reconciliation": RECONCILIATION,
                                  "rules": []}
        new = plan.clone()

        # R2: projection pushdown — Crop before Downscale
        ci, di = new.index_of(CropOp), new.index_of(DownscaleOp)
        if ci is not None and di is not None and di < ci:
            op = new.ops.pop(ci)
            new.ops.insert(di, op)
            report["rules"].append(
                "R2 projection-pushdown: moved Crop before Downscale "
                "(σ-before-γ: aggregate fewer pixels)")

        # R1: predicate split + cheap-filter pushdown (cost-gated)
        if query.filter_color is not None:
            mi = new.index_of(MLLMExtractOp)
            crop_op = new.ops[new.index_of(CropOp)] if \
                new.index_of(CropOp) is not None else None
            cheap = CheapColorFilterOp(color=query.filter_color,
                                       min_frac=0.008)
            # measure costs on the sample (post-reduction frame sizes approx)
            mllm_op = new.ops[mi]
            cheap_cost = _time_op(cheap, sample_frames[:8], self.ctx,
                                  catalog=catalog)
            mllm_cost = _time_op(MLLMExtractOp(tasks=mllm_op.tasks,
                                               model=mllm_op.model),
                                 _shrink(sample_frames[:8]), self.ctx,
                                 catalog=catalog)
            # selectivity of the color predicate measured on the sample
            cheap.open(self.ctx)
            test = cheap.process({"frames": sample_frames,
                                  "idx": np.arange(sample_frames.shape[0])})
            selectivity = len(test["idx"]) / sample_frames.shape[0]
            saving = (1 - selectivity) * mllm_cost
            if cheap_cost < saving:
                new.insert_before(MLLMExtractOp, cheap,
                                  note="logical: predicate split + pushdown")
                report["rules"].append(
                    f"R1 predicate-split: σ(color={query.filter_color} ∧ "
                    f"plate…) splits; cheap color filter pushed before the "
                    f"MLLM UDF (cost {cheap_cost:.0f}µs/frame < saving "
                    f"{saving:.0f}µs/frame at selectivity "
                    f"{selectivity:.0%})")
            else:
                report["rules"].append(
                    f"R1 rejected by cost model: cheap filter "
                    f"{cheap_cost:.0f}µs/frame >= expected saving "
                    f"{saving:.0f}µs/frame")

        # R3: fuse the preprocessing chain into one kernel pass
        fused = self._fuse_preprocess(new, report)

        return fused, report

    def _fuse_preprocess(self, plan: Plan, report) -> Plan:
        ops = plan.ops
        idxs = [i for i, op in enumerate(ops)
                if isinstance(op, (CropOp, DownscaleOp, GreyscaleOp))]
        if not idxs:
            return plan
        # collapse a contiguous run of preprocessing ops
        first = idxs[0]
        crop, factor, grey = None, 1, False
        run = []
        for i in idxs:
            if i != first + len(run):
                break
            run.append(i)
            op = ops[i]
            if isinstance(op, CropOp):
                crop = op.region
            elif isinstance(op, DownscaleOp):
                factor *= op.factor
            elif isinstance(op, GreyscaleOp):
                grey = True
        if len(run) < 2 and factor == 1 and not grey:
            return plan
        h, w = None, None
        fused = FusedPreprocessOp(
            crop=crop if crop is not None else (0, 0) + (
                self.ctx.frame_shape[1], self.ctx.frame_shape[2]),
            factor=factor, grey=grey)
        for i in reversed(run):
            plan.ops.pop(i)
        plan.ops.insert(first, fused)
        report["rules"].append(
            f"R3 fusion: {len(run)} preprocessing ops -> {fused.name} "
            "(single HBM pass; Pallas fused_preprocess on TPU)")
        plan.notes.append("logical: fused preprocessing")
        return plan


def _shrink(frames: np.ndarray) -> np.ndarray:
    """Approximate post-reduction MLLM input for cost measurement."""
    x = frames[:, :, 64:, :].astype(np.float32)
    b, c, h, w = x.shape
    x = x.reshape(b, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))
    return ((x / 255.0 - 0.5) / 0.25).astype(np.float32)
