"""GPipe-style pipeline parallelism over a mesh axis (the "pod" axis).

Inter-pod links (DCN) are slow relative to ICI; point-to-point microbatch
hand-off is the communication pattern that fits them — so the multi-pod mesh
optionally maps its "pod" axis to pipeline stages instead of pure DP.

Implementation: shard_map over the pipeline axis.  Each rank holds one
stage's parameters; microbatches stream through a lax.fori_loop whose body
(a) runs the local stage on its current microbatch and (b) rotates
activations to the next rank with ppermute.  With S stages and M
microbatches the loop runs M + S - 1 ticks (the classic GPipe bubble
S-1/(M+S-1), reported by ``bubble_fraction``).

This module is deliberately model-agnostic: ``stage_fn(stage_params, x)``
is any jittable function (tests drive it with an MLP stack; the LM stack's
period structure slots in the same way by stacking periods per stage).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.sharding import shard_map_compat


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    axis: str = "pod"
    microbatches: int = 4

    def bubble_fraction(self, n_stages: int) -> float:
        return (n_stages - 1) / (self.microbatches + n_stages - 1)


def gpipe(stage_fn: Callable[[Any, jax.Array], jax.Array], mesh: Mesh,
          cfg: PipelineConfig = PipelineConfig()):
    """Returns pipelined_fn(stage_params, x) -> y.

    stage_params: pytree whose leaves have a leading stage axis sharded over
    ``cfg.axis`` (rank i holds stage i).  x: (batch, ...) replicated over
    ``cfg.axis`` (it is split into microbatches internally).
    """
    axis = cfg.axis
    n_stages = mesh.shape[axis]
    m = cfg.microbatches
    assert m >= n_stages, "microbatches must cover the pipeline depth"

    def local(stage_params, x):
        # stage_params leaves: (1, ...) local slice -> squeeze stage dim
        sp = jax.tree_util.tree_map(lambda l: l[0], stage_params)
        rank = jax.lax.axis_index(axis)
        b = x.shape[0]
        mb = b // m
        xs = x.reshape(m, mb, *x.shape[1:])
        n_ticks = m + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, carry):
            buf, out = carry
            # which microbatch does this rank process at tick t?
            idx = t - rank
            active = (idx >= 0) & (idx < m)
            # stage 0 ingests microbatch idx; others use the rotated buffer
            inject = jnp.where(
                jnp.logical_and(rank == 0, active),
                xs[jnp.clip(idx, 0, m - 1)], buf)
            y = stage_fn(sp, inject)
            y = jnp.where(active, y, buf)
            # last stage writes its finished microbatch to the output slot
            done_idx = jnp.clip(idx, 0, m - 1)
            write = jnp.logical_and(rank == n_stages - 1, active)
            out = jax.lax.cond(write,
                               lambda o: o.at[done_idx].set(y),
                               lambda o: o, out)
            # rotate activations to the next stage
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, out

        buf0 = jnp.zeros(xs.shape[1:], x.dtype)
        out0 = jnp.zeros_like(xs)
        _, out = jax.lax.fori_loop(0, n_ticks, tick, (buf0, out0))
        # only the last rank holds real outputs; broadcast via psum of
        # masked contribution
        is_last = (rank == n_stages - 1).astype(out.dtype)
        out = jax.lax.psum(out * is_last, axis)
        return out.reshape(b, *out.shape[2:])

    def pipelined(stage_params, x):
        in_specs = (jax.tree_util.tree_map(lambda _: P(axis), stage_params,
                                           is_leaf=lambda l: hasattr(
                                               l, "shape")),
                    P())
        return shard_map_compat(local, mesh=mesh, in_specs=in_specs,
                                out_specs=P(), check=False)(
            stage_params, x)

    return pipelined
