from repro.distribution.pipeline import gpipe, PipelineConfig
