"""Continuous-batching serving engine.

Slot-based scheduler: ``max_slots`` concurrent sequences share one batched
KV cache.  Prefill runs per-request (prompt padded to a power-of-two bucket
to bound recompilation), its cache prefix is scattered into the request's
slot, and a single batched ``decode_step`` advances every active slot each
tick.  Finished slots are freed and refilled from the queue — the standard
vLLM-style loop, expressed with jitted JAX programs.

Right-padded bucketed prefill is exact for attention blocks (causal rows
never see the padding) — the first sampled token reads logits at the true
last position via ``last_pos``.  SSM/hybrid archs use exact-length prefill
(the recurrent state would otherwise consume padding); documented trade-off.

On the production mesh the same code runs pjit'd: cache/batch dims carry the
"batch"/"kv_seq" logical axes; the engine itself is mesh-agnostic.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig
from repro.models.model import LM
from repro.serving.sampler import sample_logits


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: Any, *, max_slots: int = 4,
                 s_max: int = 512, dtype=jnp.float32, eos_id: int = 1,
                 tp: int = 1, q_block: int = 128):
        assert not cfg.encoder_decoder, "engine serves decoder-only archs"
        self.cfg = cfg
        self.lm = LM(cfg, tp=tp, q_block=q_block)
        self.params = params
        self.max_slots = max_slots
        self.s_max = s_max
        self.dtype = dtype
        self.eos_id = eos_id
        self.exact_prefill = cfg.has_mamba  # SSM state must not see padding

        self.cache = self.lm.init_cache(max_slots, s_max, dtype=dtype)
        self.lens = jnp.zeros((max_slots,), jnp.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.queue: collections.deque = collections.deque()
        self.key = jax.random.PRNGKey(0)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "finished": 0}

        self._decode_step = jax.jit(self._decode_step_impl, donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_impl)

    # ------------------------------------------------------------------
    # jitted programs
    # ------------------------------------------------------------------
    def _prefill_impl(self, params, tokens, last_pos):
        cache1 = self.lm.init_cache(1, self.s_max, dtype=self.dtype)
        logits, cache1 = self.lm.prefill(params, {"tokens": tokens}, cache1,
                                         dtype=self.dtype, last_pos=last_pos)
        return logits[:, 0], cache1                     # (1,V), cache

    def _decode_step_impl(self, params, tokens, cache, lens, active):
        logits, cache = self.lm.decode(params, tokens, cache, lens,
                                       dtype=self.dtype)
        next_tok = sample_logits(logits[:, 0])
        lens = jnp.where(active, lens + 1, lens)
        return next_tok, cache, lens

    # ------------------------------------------------------------------
    # scheduler
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in [i for i, r in enumerate(self.slot_req) if r is None]:
            if not self.queue:
                break
            req = self.queue.popleft()
            plen = len(req.prompt)
            assert plen + req.max_new_tokens <= self.s_max, "prompt too long"
            padded = plen if self.exact_prefill else min(_bucket(plen),
                                                         self.s_max)
            tokens = np.zeros((1, padded), np.int32)
            tokens[0, :plen] = req.prompt
            logits, cache1 = self._prefill(
                self.params, jnp.asarray(tokens),
                jnp.asarray([plen - 1], jnp.int32))
            first = int(sample_logits(logits)[0])
            req.output.append(first)
            self._insert_slot(slot, cache1, plen)
            self.slot_req[slot] = req
            self.stats["prefill_tokens"] += plen

    def _insert_slot(self, slot: int, cache1: Any, plen: int) -> None:
        def insert_leaf(full, one):
            # cache leaves are (n_periods, B, ...) after layer stacking
            return full.at[:, slot].set(one[:, 0])

        self.cache = jax.tree_util.tree_map(insert_leaf, self.cache, cache1)
        self.lens = self.lens.at[slot].set(plen)

    def step(self) -> List[Request]:
        """One scheduler tick: admit, batched decode, collect finishes."""
        self._admit()
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return []
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None:
                tokens[i, 0] = r.output[-1]
        next_tok, self.cache, self.lens = self._decode_step(
            self.params, jnp.asarray(tokens), self.cache, self.lens,
            jnp.asarray(active))
        self.stats["decode_steps"] += 1
        next_np = np.asarray(next_tok)
        finished = []
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            tok = int(next_np[i])
            r.output.append(tok)
            if tok == self.eos_id or len(r.output) >= r.max_new_tokens:
                r.done = True
                finished.append(r)
                self.slot_req[i] = None
                self.stats["finished"] += 1
        return finished

    def run(self, requests: List[Request], max_ticks: int = 10_000
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            done.extend(self.step())
            ticks += 1
        return done
