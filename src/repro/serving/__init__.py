from repro.serving.sampler import sample_logits
from repro.serving.engine import ServingEngine, Request
from repro.serving.quantize import quantize_params_int8
