"""Token sampling: greedy / temperature / top-k."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_logits(logits: jax.Array, key: Optional[jax.Array] = None, *,
                  temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32."""
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
