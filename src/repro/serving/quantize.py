"""Physical-optimization: int8 weight quantization of model parameters.

Quantizes the large 2D+ matmul weights (per-output-channel symmetric int8)
and leaves vectors/norms in their original dtype — the standard W8 recipe
the paper's physical phase applies ("quantization reduced the MLLM's weights
and activations to 8-bit integers, halving model size and memory bandwidth").

``QuantizedLinear`` leaves are dicts {"q": int8, "scale": f32}; ``dequant``
reconstructs dense weights (used by the CPU fallback), while the TPU path
feeds the int8_matmul Pallas kernel.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import tree_size_bytes

MIN_QUANT_SIZE = 4096  # don't quantize tiny tensors (norms, biases)


def _quantize_leaf(w: jax.Array) -> Any:
    if w.ndim < 2 or w.size < MIN_QUANT_SIZE:
        return w
    # per-last-axis-channel symmetric scale over all other axes
    amax = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return {"__quant__": True, "q": q, "scale": scale.astype(jnp.float32)}


def _is_quant(x: Any) -> bool:
    return isinstance(x, dict) and x.get("__quant__") is True


def quantize_params_int8(params: Any) -> Tuple[Any, Dict[str, float]]:
    """Returns (quantized tree, {orig_bytes, quant_bytes, ratio})."""
    orig = tree_size_bytes(params)
    qparams = jax.tree_util.tree_map(_quantize_leaf, params)
    stats_bytes = tree_size_bytes(
        jax.tree_util.tree_map(
            lambda x: x, qparams,
            is_leaf=lambda x: hasattr(x, "shape")))
    return qparams, {
        "orig_bytes": float(orig),
        "quant_bytes": float(stats_bytes),
        "ratio": float(stats_bytes) / max(float(orig), 1.0),
    }


def dequantize_params(qparams: Any, dtype=jnp.float32) -> Any:
    def deq(x):
        if _is_quant(x):
            return (x["q"].astype(jnp.float32) * x["scale"]).astype(dtype)
        return x

    return jax.tree_util.tree_map(deq, qparams, is_leaf=_is_quant)
