"""Multi-query shared-execution runtime.

``MultiQueryRuntime`` serves N concurrent queries over one stream with one
pass over the frames: the planner (``repro.core.multiquery.factor_plans``)
factors the plans' longest common operator prefix — including a single
union-task MLLM extract — and the runtime pushes each micro-batch through
that prefix once, then fans the annotated batch out to the per-query
relational tails (Filter / WindowAgg / Sink).

Results are reported *per query* as ordinary ``RunResult``s (so the catalog
evaluators score each query exactly as if it ran alone), plus aggregate
throughput and the total MLLM frame count — the sharing claim is
``mllm_frames(shared) < sum_q mllm_frames(independent_q)`` with per-query
outputs bitwise identical.

Fault tolerance mirrors ``StreamRuntime``: an aligned snapshot captures the
source offset + every prefix and tail operator's state, and the first
``run()`` after ``restore()`` suppresses the warmup reset so the restored
operator graph survives.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List

from repro.streaming.operators import (
    Batch,
    MLLMExtractOp,
    Op,
    OpContext,
    SinkOp,
)
from repro.streaming.plan import Plan
from repro.streaming.runtime import (
    RunResult,
    drive_stream,
    flush_ops,
    warmup_ops,
)


@dataclasses.dataclass
class MultiQueryResult:
    #: aggregate throughput in query-frames/s (n_queries * n_frames / wall)
    fps: float
    wall_s: float
    n_frames: int
    n_queries: int
    #: frames through MLLM extracts this run (shared prefix counted once)
    mllm_frames: int
    shared_plan: str
    #: per-query RunResults score exactly as standalone runs; their wall_s
    #: is the shared wall *amortized* over the queries (so per-query walls
    #: sum to the true shared wall, and per-query fps is the effective
    #: throughput each query experiences under sharing)
    per_query: Dict[str, RunResult]


class MultiQueryRuntime:
    def __init__(self, plans: List[Plan], ctx: OpContext,
                 micro_batch: int = 16):
        # local import: repro.core pulls in the whole optimizer stack
        from repro.core.multiquery import factor_plans

        self.shared = factor_plans(plans)
        self.ctx = dataclasses.replace(ctx, micro_batch=micro_batch)
        self.micro_batch = micro_batch
        for op in self._all_ops():
            op.open(self.ctx)
        for tail in self.shared.tails:
            assert isinstance(tail[-1], SinkOp), "tails must end in a Sink"
        self._source_index = 0
        self._restored = False

    def _all_ops(self) -> List[Op]:
        ops = list(self.shared.prefix)
        for tail in self.shared.tails:
            ops.extend(tail)
        return ops

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {
            "source_index": self._source_index,
            "prefix": [op.snapshot() for op in self.shared.prefix],
            "tails": [[op.snapshot() for op in tail]
                      for tail in self.shared.tails],
        }

    def restore(self, st: Dict[str, Any]) -> None:
        self._source_index = st["source_index"]
        for op, s in zip(self.shared.prefix, st["prefix"]):
            op.restore(s)
        for tail, states in zip(self.shared.tails, st["tails"]):
            for op, s in zip(tail, states):
                op.restore(s)
        # the next run() must not warmup-reset the restored state
        self._restored = True

    # ------------------------------------------------------------------
    def _fan_out(self, batch: Batch, counts: List[Dict[str, int]],
                 windows: List[List[Dict[str, Any]]]) -> None:
        for qi, tail in enumerate(self.shared.tails):
            b = batch
            for op in tail:
                counts[qi][op.name] += len(b["idx"])
                b = op.process(b)
                if "window_results" in b:
                    windows[qi].extend(b.pop("window_results"))

    def _advance(self, batch: Batch, pcounts: Dict[str, int],
                 counts: List[Dict[str, int]],
                 windows: List[List[Dict[str, Any]]]) -> None:
        for op in self.shared.prefix:
            pcounts[op.name] += len(batch["idx"])
            batch = op.process(batch)
            if "window_results" in batch:
                # a window op shared by every query: results belong to all
                wr = batch.pop("window_results")
                for w in windows:
                    w.extend(wr)
        self._fan_out(batch, counts, windows)

    def _flush(self, counts: List[Dict[str, int]],
               windows: List[List[Dict[str, Any]]]) -> None:
        def emit_all(wr):
            # a shared window op's results belong to every query
            for w in windows:
                w.extend(wr)

        flush_ops(self.shared.prefix, emit_all,
                  terminal=lambda b: self._fan_out(b, counts, windows))
        for qi, tail in enumerate(self.shared.tails):
            flush_ops(tail, windows[qi].extend)

    # ------------------------------------------------------------------
    def run(self, stream, n_frames: int, warmup: int = 1,
            flush: bool = True) -> MultiQueryResult:
        sinks = [tail[-1] for tail in self.shared.tails]
        for sink in sinks:
            sink.collected = []
        pcounts: Dict[str, int] = {op.name: 0 for op in self.shared.prefix}
        counts: List[Dict[str, int]] = [
            {op.name: 0 for op in tail} for tail in self.shared.tails]
        windows: List[List[Dict[str, Any]]] = [[] for _ in self.shared.tails]
        labels_all: List[Dict[str, Any]] = []

        if warmup and not self._restored:
            # throwaway accumulators; SinkOp.reset() drops warmup records
            warmup_ops(
                stream, self.micro_batch,
                lambda b: self._advance(b, dict(pcounts),
                                        [dict(c) for c in counts],
                                        [[] for _ in windows]),
                self._all_ops())
            self._source_index = 0
        self._restored = False
        # per-run (not lifetime) model load, as in StreamRuntime.run
        prefix_mllm_start = sum(
            op.frames_processed for op in self.shared.prefix
            if isinstance(op, MLLMExtractOp))
        tail_mllm_start = [
            sum(op.frames_processed for op in tail
                if isinstance(op, MLLMExtractOp))
            for tail in self.shared.tails]

        def advance(batch):
            # per-micro-batch checkpoint offset, as in StreamRuntime.run
            self._source_index = int(batch["idx"][-1]) + 1
            self._advance(batch, pcounts, counts, windows)

        t0 = time.perf_counter()
        drive_stream(stream, n_frames, self.micro_batch,
                     self._source_index, advance, labels_all)
        if flush:
            self._flush(counts, windows)
        wall = time.perf_counter() - t0

        n_q = len(self.shared.tails)
        prefix_mllm = sum(op.frames_processed for op in self.shared.prefix
                          if isinstance(op, MLLMExtractOp)) \
            - prefix_mllm_start
        per_query: Dict[str, RunResult] = {}
        total_mllm = prefix_mllm
        for qi, (qid, tail) in enumerate(zip(self.shared.queries,
                                             self.shared.tails)):
            tail_mllm = sum(op.frames_processed for op in tail
                            if isinstance(op, MLLMExtractOp)) \
                - tail_mllm_start[qi]
            total_mllm += tail_mllm
            q_counts = dict(pcounts)
            q_counts.update(counts[qi])
            per_query[qid] = RunResult(
                fps=n_frames * n_q / wall,
                wall_s=wall / n_q,
                n_frames=n_frames,
                outputs=sinks[qi].collected,
                window_results=windows[qi],
                op_input_counts=q_counts,
                mllm_frames=prefix_mllm + tail_mllm,
                labels=labels_all,
            )
        return MultiQueryResult(
            fps=len(self.shared.tails) * n_frames / wall,
            wall_s=wall,
            n_frames=n_frames,
            n_queries=len(self.shared.tails),
            mllm_frames=total_mllm,
            shared_plan=self.shared.describe(),
            per_query=per_query,
        )
