"""Multi-query shared-execution runtime.

``MultiQueryRuntime`` serves N concurrent queries over one stream with one
pass over the frames: the planner (``repro.core.multiquery.factor_plans``)
factors the plans' longest common operator prefix — including a single
union-task MLLM extract — and the runtime pushes each micro-batch through
that prefix once, then fans the annotated batch out to the per-query
relational tails (Filter / WindowAgg / Sink).

Results are reported *per query* as ordinary ``RunResult``s (so the catalog
evaluators score each query exactly as if it ran alone), plus aggregate
throughput and the total MLLM frame count — the sharing claim is
``mllm_frames(shared) < sum_q mllm_frames(independent_q)`` with per-query
outputs bitwise identical.

Per-query tails are independent (each owns its operator instances and its
accumulators), so the fan-out dispatches them on a process-wide thread pool;
the relational tails are cheap today, but tails that grow models of their
own overlap their device work this way.

Fault tolerance mirrors ``StreamRuntime``: an aligned snapshot captures the
source offset + every prefix and tail operator's state, and the first
``run()`` after ``restore()`` suppresses the warmup reset so the restored
operator graph survives.

Passing a ``SharedExtractServer`` (``server=``) switches ``run`` to the
*pipelined* serving path: the shared prefix suspends at its extract op,
the forward is dispatched asynchronously through the server, and the next
micro-batch's source pull / prefix ops / tail fan-out overlap the device
work — the same dispatch/poll/resume protocol ``MultiStreamRuntime`` uses,
so single-feed workloads get the overlap too.  Outputs stay bitwise
identical to the synchronous path (``server=None``, the default).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import numpy as np

from repro.streaming.operators import (
    Batch,
    Op,
    OpContext,
    SinkOp,
)
from repro.streaming.plan import Plan
from repro.streaming.runtime import (
    RunResult,
    RunScaffold,
    drive_stream,
    flush_ops,
    mllm_frames_of,
)

#: one process-wide pool shared by every fan-out (runtimes come and go per
#: benchmark run; a per-runtime pool would leak idle threads)
_FANOUT_POOL: Optional[ThreadPoolExecutor] = None
_FANOUT_WORKERS = 8


def _fanout_pool() -> ThreadPoolExecutor:
    global _FANOUT_POOL
    if _FANOUT_POOL is None:
        _FANOUT_POOL = ThreadPoolExecutor(
            max_workers=_FANOUT_WORKERS, thread_name_prefix="fanout")
    return _FANOUT_POOL


def fan_out_tails(tails: List[List[Op]], batch: Batch,
                  counts: List[Dict[str, int]],
                  windows: List[List[Dict[str, Any]]],
                  parallel: bool = True) -> None:
    """Push one fully-advanced prefix batch through every per-query tail.

    Each tail owns its op instances and writes only its own ``counts[qi]``
    / ``windows[qi]`` slot, and operators copy-on-write the shared batch
    dict — so the tails are embarrassingly parallel.  ``parallel=False``
    keeps the sequential loop (single tail, or debugging).
    """
    def one(qi: int) -> None:
        b = batch
        for op in tails[qi]:
            counts[qi][op.name] += len(b["idx"])
            b = op.process(b)
            if "window_results" in b:
                windows[qi].extend(b.pop("window_results"))

    if not parallel or len(tails) <= 1:
        for qi in range(len(tails)):
            one(qi)
    else:
        # list() propagates the first tail exception to the caller
        list(_fanout_pool().map(one, range(len(tails))))


def broadcast_windows(batch: Batch,
                      windows: List[List[Dict[str, Any]]]) -> Batch:
    """Pop window results emitted by a *shared prefix* op and append them
    to every query's accumulator — a window op shared by every query
    produces results that belong to all of them.  One implementation for
    every shared executor, so the broadcast semantics cannot drift."""
    if "window_results" in batch:
        wr = batch.pop("window_results")
        for w in windows:
            w.extend(wr)
    return batch


def flush_shared(prefix: List[Op], tails: List[List[Op]],
                 windows: List[List[Dict[str, Any]]], fan_out) -> None:
    """End-of-stream flush for a shared prefix + per-query tails: prefix
    partials broadcast to every query and fan out through the tails, then
    each tail flushes into its own accumulator."""
    def emit_all(wr):
        for w in windows:
            w.extend(wr)

    flush_ops(prefix, emit_all, terminal=fan_out)
    for qi, tail in enumerate(tails):
        flush_ops(tail, windows[qi].extend)


@dataclasses.dataclass
class MultiQueryResult:
    #: aggregate throughput in query-frames/s (n_queries * n_frames / wall)
    fps: float
    wall_s: float
    n_frames: int
    n_queries: int
    #: frames through MLLM extracts this run (shared prefix counted once)
    mllm_frames: int
    shared_plan: str
    #: per-query RunResults score exactly as standalone runs; their wall_s
    #: is the shared wall *amortized* over the queries (so per-query walls
    #: sum to the true shared wall, and per-query fps is the effective
    #: throughput each query experiences under sharing)
    per_query: Dict[str, RunResult]


class MultiQueryRuntime(RunScaffold):
    def __init__(self, plans: List[Plan], ctx: OpContext,
                 micro_batch: int = 16, parallel_tails: bool = True,
                 server=None, max_pending: int = 2,
                 coalesce_frames: Optional[int] = None):
        # local import: repro.core pulls in the whole optimizer stack
        from repro.core.multiquery import factor_plans

        self.shared = factor_plans(plans)
        self.parallel_tails = parallel_tails
        self._init_scaffold(ctx, micro_batch, self._all_ops())
        for tail in self.shared.tails:
            assert isinstance(tail[-1], SinkOp), "tails must end in a Sink"
        #: pipelined serving (a SharedExtractServer) — None keeps the
        #: synchronous in-line extract path
        self.server = server
        self.max_pending = max_pending
        #: dispatch once this many frames are queued; a single feed fills
        #: one micro-batch per pull, so default to shipping every batch
        self.coalesce_frames = coalesce_frames if coalesce_frames is not None \
            else micro_batch
        self._gexec = None
        if server is not None:
            # deferred: repro.scheduler imports this module at top level
            from repro.scheduler.multistream import _GroupExec

            self._gexec = _GroupExec(self.shared, self.ctx, server,
                                     feed="mq",
                                     parallel_tails=parallel_tails,
                                     open_ops=False)

    @classmethod
    def from_fleet(cls, fleet, feed: str, ctx: OpContext,
                   **kw) -> "MultiQueryRuntime":
        """Serve one feed of a ``repro.core.fleet.FleetResult``: the fleet
        optimizer already canonicalized the plans' prefixes (identical
        ``Op.signature()`` chains where sharing pays), so factoring here
        recovers exactly the sharing the joint optimizer planned for."""
        return cls([p.clone() for p in fleet.plans_by_feed[feed]], ctx, **kw)

    def _all_ops(self) -> List[Op]:
        ops = list(self.shared.prefix)
        for tail in self.shared.tails:
            ops.extend(tail)
        return ops

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        st = {
            "source_index": self._source_index,
            "prefix": [op.snapshot() for op in self.shared.prefix],
            "tails": [[op.snapshot() for op in tail]
                      for tail in self.shared.tails],
        }
        if self.server is not None and self.server.gate is not None:
            # the server path gates under this runtime's feed label; the
            # solo path's gate state rides the extract op's own snapshot
            st["gate"] = self.server.gate.snapshot_feed("mq")
        return st

    def restore(self, st: Dict[str, Any]) -> None:
        self._source_index = st["source_index"]
        for op, s in zip(self.shared.prefix, st["prefix"]):
            op.restore(s)
        for tail, states in zip(self.shared.tails, st["tails"]):
            for op, s in zip(tail, states):
                op.restore(s)
        if st.get("gate") is not None and self.server is not None \
                and self.server.gate is not None:
            self.server.gate.restore_feed("mq", st["gate"])
        self._mark_restored()

    # ------------------------------------------------------------------
    def _fan_out(self, batch: Batch, counts: List[Dict[str, int]],
                 windows: List[List[Dict[str, Any]]]) -> None:
        fan_out_tails(self.shared.tails, batch, counts, windows,
                      parallel=self.parallel_tails)

    def _advance(self, batch: Batch, pcounts: Dict[str, int],
                 counts: List[Dict[str, int]],
                 windows: List[List[Dict[str, Any]]]) -> None:
        for op in self.shared.prefix:
            pcounts[op.name] += len(batch["idx"])
            batch = broadcast_windows(op.process(batch), windows)
        self._fan_out(batch, counts, windows)

    def _flush(self, counts: List[Dict[str, int]],
               windows: List[List[Dict[str, Any]]]) -> None:
        flush_shared(self.shared.prefix, self.shared.tails, windows,
                     lambda b: self._fan_out(b, counts, windows))

    # ------------------------------------------------------------------
    def run(self, stream, n_frames: int, warmup: int = 1,
            flush: bool = True) -> MultiQueryResult:
        if self.server is not None:
            return self._run_pipelined(stream, n_frames, warmup, flush)
        sinks = [tail[-1] for tail in self.shared.tails]
        for sink in sinks:
            sink.collected = []
        pcounts: Dict[str, int] = {op.name: 0 for op in self.shared.prefix}
        counts: List[Dict[str, int]] = [
            {op.name: 0 for op in tail} for tail in self.shared.tails]
        windows: List[List[Dict[str, Any]]] = [[] for _ in self.shared.tails]
        labels_all: List[Dict[str, Any]] = []

        def warm_advance(batch):
            # throwaway accumulators; SinkOp.reset() drops warmup records
            self._advance(batch, dict(pcounts), [dict(c) for c in counts],
                          [[] for _ in windows])

        self._begin_run(stream, warmup, warm_advance, self._all_ops())
        # per-run (not lifetime) model load, per prefix/tail component
        prefix_mllm_start = mllm_frames_of(self.shared.prefix)
        tail_mllm_start = [mllm_frames_of(tail)
                           for tail in self.shared.tails]

        obs = self.obs

        def advance(batch):
            self._stamp(batch)
            if obs.enabled:
                t_arr = obs.now()
                n0 = len(batch["idx"])
                self._advance(batch, pcounts, counts, windows)
                obs.slo.record("mq", (obs.now() - t_arr) / 1e6, n=n0)
            else:
                self._advance(batch, pcounts, counts, windows)

        t0 = time.perf_counter()
        drive_stream(stream, n_frames, self.micro_batch,
                     self._source_index, advance, labels_all)
        if flush:
            self._flush(counts, windows)
        wall = time.perf_counter() - t0
        return self._collect(wall, n_frames, labels_all, pcounts, counts,
                             windows, prefix_mllm_start, tail_mllm_start)

    # ------------------------------------------------------------------
    def _run_pipelined(self, stream, n_frames: int, warmup: int,
                       flush: bool) -> MultiQueryResult:
        """Dispatch-ahead serving through the SharedExtractServer: the
        prefix suspends at its extract, the forward runs asynchronously,
        and the next micro-batch's host work overlaps it.  ``max_pending``
        bounds outstanding continuations (backpressure); resume order is
        strict FIFO, so outputs match the synchronous path bitwise."""
        from repro.scheduler.extract_server import settle_fifo

        g = self._gexec
        g.begin_run()
        labels_all: List[Dict[str, Any]] = []
        pendings: List[tuple] = []

        def resume(lane, p):
            return lane.resume(p)

        def drain_pendings():
            nonlocal pendings
            while pendings:
                self.server.drain()
                pendings, _ = settle_fifo(pendings, resume)

        def warm_advance(batch):
            p = g.start(batch)
            if p is not None:
                pendings.append((g, p))
            drain_pendings()

        fresh = warmup and not self._restored
        self._begin_run(stream, warmup, warm_advance, self._all_ops())
        if fresh:
            g.reset_accumulators()
            if self.server.gate is not None:
                self.server.gate.reset("mq")   # no warmup keyframe leaks
            self.server.reset_stats()
        prefix_mllm_start = mllm_frames_of(self.shared.prefix)
        tail_mllm_start = [mllm_frames_of(tail)
                           for tail in self.shared.tails]

        def settle() -> int:
            nonlocal pendings
            pendings, resumed = settle_fifo(pendings, resume)
            return resumed

        base = self._source_index
        done = 0
        obs = self.obs
        t0 = time.perf_counter()
        while done < n_frames or pendings:
            progressed = False
            if done < n_frames and len(pendings) < self.max_pending:
                take = min(self.micro_batch, n_frames - done)
                t_pull = obs.now() if obs.enabled else 0
                frames, labels = stream.batch(take)
                labels_all.extend(labels)
                batch = {"frames": frames,
                         "idx": np.arange(base + done, base + done + take)}
                done += take
                self._stamp(batch)
                if obs.enabled:
                    t_arr = obs.now()
                    obs.tracer.span("ingest", "ingest", t_pull, t_arr,
                                    track="feed:mq", n=take)
                    batch["_obs_t0"] = t_arr
                    batch["_obs_n"] = take
                    g.arrival[0] = t_arr
                p = g.start(batch)
                if p is not None:
                    pendings.append((g, p))
                progressed = True
            self.server.pump(progressed, self.coalesce_frames, settle)
        drain_pendings()
        if flush:
            g.flush()
        wall = time.perf_counter() - t0
        return self._collect(wall, n_frames, labels_all, g.pcounts,
                             g.counts, g.windows, prefix_mllm_start,
                             tail_mllm_start)

    # ------------------------------------------------------------------
    def _collect(self, wall: float, n_frames: int, labels_all,
                 pcounts, counts, windows, prefix_mllm_start,
                 tail_mllm_start) -> MultiQueryResult:
        sinks = [tail[-1] for tail in self.shared.tails]
        n_q = len(self.shared.tails)
        if self.obs.enabled:
            self.obs.metrics.set_gauge("run/wall_s", wall)
            if self.server is not None:
                self.obs.metrics.ingest("server", self.server.stats)
        prefix_mllm = mllm_frames_of(self.shared.prefix) - prefix_mllm_start
        per_query: Dict[str, RunResult] = {}
        total_mllm = prefix_mllm
        for qi, (qid, tail) in enumerate(zip(self.shared.queries,
                                             self.shared.tails)):
            tail_mllm = mllm_frames_of(tail) - tail_mllm_start[qi]
            total_mllm += tail_mllm
            q_counts = dict(pcounts)
            q_counts.update(counts[qi])
            per_query[qid] = RunResult(
                fps=n_frames * n_q / wall,
                wall_s=wall / n_q,
                n_frames=n_frames,
                outputs=sinks[qi].collected,
                window_results=windows[qi],
                op_input_counts=q_counts,
                mllm_frames=prefix_mllm + tail_mllm,
                labels=labels_all,
            )
        return MultiQueryResult(
            fps=len(self.shared.tails) * n_frames / wall,
            wall_s=wall,
            n_frames=n_frames,
            n_queries=len(self.shared.tails),
            mllm_frames=total_mllm,
            shared_plan=self.shared.describe(),
            per_query=per_query,
        )
