"""Train the streaming operator models on the synthetic labeled streams.

Produces (and caches) the OpContext every plan runs with:
  * big StreamMLLM  — trained supervised on mixed preprocessing configs
    (full frame / crop / crop+downscale) so it stays accurate under any plan;
  * small StreamMLLM — *distilled* from the big one on the optimized
    preprocessing (the paper's model-specialization path);
  * pruned params    — structured head/FFN pruning of the big model
    (adaptive pruning's static half; rate selection is runtime);
  * TinyDet          — the cascade detector.

This is the offline "super-optimization pays off because queries are
long-running" investment the paper argues for.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tollbooth import (BRANDS, COLORS, PLATE_CHARS,
                                  TollBoothStream)
from repro.data.volleyball import ACTIONS, VolleyballStream
from repro.streaming.detector import TinyDet
from repro.streaming.mllm import MLLM_TASKS, PLATE_LEN, StreamMLLM
from repro.streaming.operators import OpContext
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         ".cache", "stream_models")

PATCH = 16
CROP = (64, 0, 64, 256)      # road region


# ---------------------------------------------------------------------------
# label encoding
# ---------------------------------------------------------------------------

def encode_tollbooth_labels(labels) -> Dict[str, np.ndarray]:
    n = len(labels)
    out = {
        "present": np.zeros(n, np.int32),
        "color": np.zeros(n, np.int32),
        "brand": np.zeros(n, np.int32),
        "plate": np.zeros((n, PLATE_LEN), np.int32),
        "mask_car": np.zeros(n, np.float32),
    }
    for i, l in enumerate(labels):
        out["present"][i] = int(bool(l["car_present"]))
        if l.get("car_readable"):
            out["mask_car"][i] = 1.0
            out["color"][i] = COLORS.index(l["color"])
            out["brand"][i] = BRANDS.index(l["brand"])
            out["plate"][i] = [PLATE_CHARS.index(c) for c in l["plate"]]
    return out


def encode_volleyball_labels(labels) -> Dict[str, np.ndarray]:
    n = len(labels)
    return {
        "action": np.asarray([ACTIONS.index(l["action"]) for l in labels],
                             np.int32),
        "n_jumping": np.asarray([min(l["n_jumping"], 6) for l in labels],
                                np.int32),
        "team": np.asarray([l["attack_team"] for l in labels], np.int32),
    }


def preprocess_np(frames: np.ndarray, crop=None, factor: int = 1
                  ) -> np.ndarray:
    x = frames.astype(np.float32)
    if crop is not None:
        y0, x0, h, w = crop
        x = x[:, :, y0:y0 + h, x0:x0 + w]
    if factor > 1:
        b, c, h, w = x.shape
        x = x.reshape(b, c, h // factor, factor, w // factor, factor
                      ).mean(axis=(3, 5))
    return (x / 255.0 - 0.5) / 0.25


# ---------------------------------------------------------------------------
# training loops (simple, jitted per input shape)
# ---------------------------------------------------------------------------

def _train(model_loss, params, batches, steps, lr=1e-3, log_every=50,
           label=""):
    opt_cfg = OptimizerConfig(lr=lr, warmup_steps=20, total_steps=steps,
                              weight_decay=0.01)
    state = adamw_init(params, opt_cfg)

    @jax.jit
    def step_fn(params, state, batch):
        loss, grads = jax.value_and_grad(model_loss)(params, batch)
        params, state, m = adamw_update(params, grads, state, opt_cfg)
        return params, state, loss

    losses = []
    for i in range(steps):
        batch = batches(i)
        params, state, loss = step_fn(params, state, batch)
        losses.append(float(loss))
        if log_every and (i + 1) % log_every == 0:
            print(f"  [{label}] step {i+1}/{steps} "
                  f"loss={np.mean(losses[-log_every:]):.4f}")
    return params, losses


def _make_mllm_batches(seed: int, batch: int = 16):
    """Mixed tollbooth/volleyball batches under mixed preprocessing.

    Booth-shot batches (every frame readable) carry the OCR signal; natural
    batches calibrate presence/empty statistics; mixed crops/downscales keep
    the operator accurate under any plan the optimizer produces.
    """
    tb = TollBoothStream(seed=seed, car_rate=0.03)
    vb = VolleyballStream(seed=seed)

    def gen(i: int):
        mode = i % 6
        if mode in (0, 1, 3):          # booth shots (plate/color/brand)
            frames, labels = tb.booth_batch(batch)
            enc = encode_tollbooth_labels(labels)
            crop, factor = (CROP, 1) if mode != 1 else (CROP, 2)
            x = preprocess_np(frames, crop, factor)
        elif mode == 2:                # natural full frame (naive plan)
            frames, labels = tb.batch(batch)
            enc = encode_tollbooth_labels(labels)
            x = preprocess_np(frames, None, 1)
        elif mode == 4:                # natural cropped
            frames, labels = tb.batch(batch)
            enc = encode_tollbooth_labels(labels)
            x = preprocess_np(frames, CROP, 1)
        else:                          # volleyball
            frames, labels = vb.batch(batch)
            enc = encode_volleyball_labels(labels)
            x = preprocess_np(frames, None, 2)
        b = {"frames": jnp.asarray(x)}
        b.update({k: jnp.asarray(v) for k, v in enc.items()})
        return b

    return gen


def quick_stream_models(verbose: bool = False) -> OpContext:
    """Tiny, un-cached stream models for smoke runs: enough to exercise
    every code path in seconds (accuracy is the full training's job) — the
    configuration examples use under ``--quick`` and the test suite's
    session fixture uses throughout."""
    return train_stream_models(steps_mllm=40, steps_small=20, steps_det=30,
                               cache_dir=None, verbose=verbose)


def stream_models(quick: bool = False) -> OpContext:
    """The examples' single entry point: cached full-quality stream
    models, or the tiny un-cached quick set under ``--quick`` (CI smoke).
    One implementation so the quick-mode setup cannot drift between
    examples."""
    if quick:
        print("quick mode: training tiny stream models…")
        return quick_stream_models(verbose=False)
    print("loading/training stream operator models (cached after "
          "first run)…")
    return train_stream_models(verbose=True)


def train_stream_models(steps_mllm: int = 1600, steps_small: int = 500,
                        steps_det: int = 250, seed: int = 0,
                        cache_dir: Optional[str] = CACHE_DIR,
                        force: bool = False, verbose: bool = True
                        ) -> OpContext:
    """Train (or load cached) streaming models; returns a ready OpContext."""
    big_cfg = get_config("samsara-stream-mllm")
    small_cfg = get_config("samsara-stream-mllm-small")
    mllm = StreamMLLM(big_cfg, patch=PATCH)
    small = StreamMLLM(small_cfg, patch=PATCH)
    det = TinyDet()

    ck = CheckpointManager(cache_dir, keep=1) if cache_dir else None
    if ck is not None and not force and ck.latest_step() is not None:
        tree = ck.restore(ck.latest_step())
        if verbose:
            print("[pretrain] loaded cached stream models")
        return OpContext(
            mllm=mllm, mllm_params=tree["mllm"],
            mllm_small=small, mllm_small_params=tree["small"],
            mllm_pruned_params=tree["pruned"],
            detector=det, detector_params=tree["det"])

    log = 50 if verbose else 0
    # ---- big MLLM ----
    params = mllm.init(jax.random.PRNGKey(seed))
    gen = _make_mllm_batches(seed)
    params, _ = _train(lambda p, b: mllm.loss(p, b), params, gen,
                       steps_mllm, lr=1e-3, log_every=log, label="mllm")

    # ---- distilled small MLLM (physical optimization) ----
    sparams = small.init(jax.random.PRNGKey(seed + 1))
    tb = TollBoothStream(seed=seed + 7, car_rate=0.04)
    vb = VolleyballStream(seed=seed + 7)

    @jax.jit
    def teacher_fwd(frames):
        return mllm.forward(params, frames)

    def distill_batches(i: int):
        if i % 3 < 2:
            frames, labels = tb.booth_batch(16) if i % 3 == 0 \
                else tb.batch(16)
            x = preprocess_np(frames, CROP, 2)      # the optimized preproc
            enc = encode_tollbooth_labels(labels)
        else:
            frames, labels = vb.batch(16)
            x = preprocess_np(frames, None, 2)
            enc = encode_volleyball_labels(labels)
        xj = jnp.asarray(x)
        t_out = teacher_fwd(xj)
        b = {"frames": xj,
             "teacher": {k: jax.lax.stop_gradient(v)
                         for k, v in t_out.items()}}
        b.update({k: jnp.asarray(v) for k, v in enc.items()})
        return b

    def distill_loss(p, b):
        s_out = small.forward(p, b["frames"])
        total = jnp.zeros((), jnp.float32)
        for name in s_out:
            p_t = jax.nn.softmax(b["teacher"][name] / 2.0, -1)
            logp = jax.nn.log_softmax(s_out[name] / 2.0, -1)
            total += -jnp.mean(jnp.sum(p_t * logp, -1)) * 4.0
        return total + 0.5 * small.loss(p, {k: v for k, v in b.items()
                                            if k != "teacher"})

    sparams, _ = _train(distill_loss, sparams, distill_batches, steps_small,
                        lr=1e-3, log_every=log, label="distill")

    # ---- structured pruning of the big model (adaptive pruning, static half)
    from repro.core.physical import structured_prune

    pruned = structured_prune(mllm, params, rate=0.5)

    # ---- TinyDet ----
    dparams = det.init(jax.random.PRNGKey(seed + 2))
    tb2 = TollBoothStream(seed=seed + 13, car_rate=0.02)

    def det_batches(i: int):
        frames, labels = tb2.batch(16)
        x = preprocess_np(frames, CROP, 2)
        return {"frames": jnp.asarray(x),
                "present": jnp.asarray(
                    [int(l["car_present"]) for l in labels], jnp.int32)}

    dparams, _ = _train(lambda p, b: det.loss(p, b), dparams, det_batches,
                        steps_det, lr=2e-3, log_every=log, label="tinydet")

    if ck is not None:
        ck.save(1, {"mllm": params, "small": sparams, "pruned": pruned,
                    "det": dparams})
    return OpContext(
        mllm=mllm, mllm_params=params,
        mllm_small=small, mllm_small_params=sparams,
        mllm_pruned_params=pruned,
        detector=det, detector_params=dparams)
