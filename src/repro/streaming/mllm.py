"""StreamMLLM: the multimodal LLM *operator* the streaming plans invoke.

A patch-embedding frontend + an LM backbone from the registry + per-task
readout heads.  This is the in-framework stand-in for the paper's
Qwen2.5-VL operator: `Extract(color, plate, brand, present, action)` runs
one batched forward over preprocessed frames and returns structured
attributes.  The physical optimizer swaps the backbone (big ↔ distilled
small ↔ int8-quantized ↔ pruned) behind the same interface.

Patchify: frames (B, C, h, w) -> non-overlapping p×p patches -> linear
projection to d_model; task queries are learned tokens appended after the
patches; heads read their task token's final hidden state.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig
from repro.data.tollbooth import BRANDS, COLORS, PLATE_CHARS
from repro.data.volleyball import ACTIONS
from repro.models import LM
from repro.models.param import ParamSpec, materialize
from repro.models.layers import apply_norm
from repro.models import blocks as blk

PLATE_LEN = 6
MLLM_TASKS = {
    "present": 2,
    "color": len(COLORS),
    "brand": len(BRANDS),
    "plate": PLATE_LEN * len(PLATE_CHARS),
    "action": len(ACTIONS),
    "n_jumping": 7,           # 0..6 jumping players
    "team": 2,                # attacking team (volleyball Q11)
}


SCALAR_TASKS = ("present", "color", "brand", "action", "n_jumping", "team")


class StreamMLLM:
    """Bundles backbone cfg + patchify + heads into one extract operator.

    Readout: one learned task token per scalar task + one per plate char
    position (a 6-char plate reads from 6 dedicated tokens)."""

    def __init__(self, cfg: ArchConfig, patch: int = 8, tp: int = 1):
        assert cfg.frontend == "patch"
        self.cfg = cfg
        self.patch = patch
        self.lm = LM(cfg, tp=tp, q_block=256)
        self.n_tasks = len(SCALAR_TASKS) + PLATE_LEN

    STEM_CH = 48  # conv-stem output channels (stride 4 total)

    # ------------------------------------------------------------------
    def spec(self, in_ch: int = 3, max_patches: int = 512) -> Dict[str, Any]:
        d = self.cfg.d_model
        p = self.patch // 4  # patch size on the stride-4 conv feature map
        heads = {
            name: ParamSpec((d, MLLM_TASKS[name]), ("embed", None))
            for name in SCALAR_TASKS
        }
        heads["plate"] = ParamSpec((d, len(PLATE_CHARS)), ("embed", None))
        c = self.STEM_CH
        spec = {
            "backbone": self.lm.spec(),
            # hybrid-ViT conv stem: two stride-2 convs (translation-
            # equivariant local features => sample-efficient glyph reading)
            "conv1": ParamSpec((3, 3, in_ch, c), (None, None, None, None)),
            "conv1_b": ParamSpec((c,), (None,), "zeros"),
            "conv2": ParamSpec((3, 3, c, c), (None, None, None, None)),
            "conv2_b": ParamSpec((c,), (None,), "zeros"),
            "patch_proj": ParamSpec((c * p * p, d), ("fsdp", "embed")),
            "patch_pos_emb": ParamSpec((max_patches, d), (None, "embed"),
                                       "small"),
            "task_tokens": ParamSpec((self.n_tasks, d), (None, "embed"),
                                     "small"),
            "heads": heads,
        }
        return spec

    def init(self, key: jax.Array, dtype=jnp.float32, in_ch: int = 3
             ) -> Dict[str, Any]:
        return materialize(self.spec(in_ch=in_ch), key, dtype)

    # ------------------------------------------------------------------
    def _stem(self, params, frames: jax.Array, dtype) -> jax.Array:
        """Conv stem: (B, C, h, w) -> (B, c, h/4, w/4)."""
        x = frames.astype(dtype).transpose(0, 2, 3, 1)       # NHWC
        for wk, bk in (("conv1", "conv1_b"), ("conv2", "conv2_b")):
            x = jax.lax.conv_general_dilated(
                x, params[wk].astype(dtype), (2, 2), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + params[bk].astype(dtype))
        return x.transpose(0, 3, 1, 2)                       # NCHW

    def _patchify(self, feats: jax.Array) -> jax.Array:
        """feature map (B, C, h, w) -> (B, P, C·p·p) with p = patch//4."""
        b, c, h, w = feats.shape
        p = self.patch // 4
        assert h % p == 0 and w % p == 0, (h, w, p)
        x = feats.reshape(b, c, h // p, p, w // p, p)
        x = x.transpose(0, 2, 4, 1, 3, 5).reshape(b, (h // p) * (w // p),
                                                  c * p * p)
        return x

    def forward(self, params: Dict[str, Any], frames: jax.Array,
                dtype=jnp.float32) -> Dict[str, jax.Array]:
        """frames (B, C, h, w) float (preprocessed) -> task logits dict."""
        cfg = self.cfg
        b = frames.shape[0]
        feats = self._stem(params, frames, dtype)
        patches = self._patchify(feats)
        n_p = patches.shape[1]
        x_p = patches @ params["patch_proj"].astype(dtype)
        x_p = x_p + params["patch_pos_emb"][:n_p].astype(dtype)[None]
        x_t = jnp.broadcast_to(params["task_tokens"].astype(dtype)[None],
                               (b, self.n_tasks, cfg.d_model))
        x = jnp.concatenate([x_p, x_t], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
        bp = params["backbone"]
        x, _, _ = blk.apply_stack(cfg, self.lm.tp, bp["stack"], x,
                                  mode="causal", positions=positions,
                                  q_block=self.lm.q_block, remat=cfg.remat)
        x = apply_norm(bp["final_norm"], x, cfg.norm)
        task_h = x[:, n_p:, :]                       # (B, n_tasks, d)
        out = {}
        for i, name in enumerate(SCALAR_TASKS):
            logits = task_h[:, i] @ params["heads"][name].astype(dtype)
            out[name] = logits.astype(jnp.float32)
        plate_h = task_h[:, len(SCALAR_TASKS):]      # (B, PLATE_LEN, d)
        out["plate"] = (plate_h @ params["heads"]["plate"].astype(dtype)
                        ).astype(jnp.float32)        # (B, PLATE_LEN, 36)
        return out

    # ------------------------------------------------------------------
    def loss(self, params: Dict[str, Any], batch: Dict[str, jax.Array],
             dtype=jnp.float32) -> jax.Array:
        """Supervised multi-task loss on labeled frames."""
        out = self.forward(params, batch["frames"], dtype)
        total = jnp.zeros((), jnp.float32)
        mask_car = batch.get("mask_car")

        def ce(logits, labels, mask=None):
            lse = jax.nn.logsumexp(logits, -1)
            ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
            nll = lse - ll
            if mask is not None:
                m = mask.astype(jnp.float32)
                while m.ndim < nll.ndim:
                    m = m[..., None]
                m = jnp.broadcast_to(m, nll.shape)
                return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
            return jnp.mean(nll)

        if "present" in batch:
            total += ce(out["present"], batch["present"])
        for key in ("color", "brand"):
            if key in batch:
                total += ce(out[key], batch[key], mask_car)
        if "plate" in batch:
            total += 2.0 * ce(out["plate"], batch["plate"], mask_car)
        for key in ("action", "n_jumping", "team"):
            if key in batch:
                total += ce(out[key], batch[key])
        return total

    def predict(self, out: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Logits -> integer predictions."""
        return {
            name: jnp.argmax(out[name], -1)
            for name in out
        }


def variant_models(ctx) -> Dict[str, Tuple["StreamMLLM", Any]]:
    """Physical-variant name -> (model, params) from an OpContext — THE
    resolution table, shared by ``MLLMExtractOp.open`` and the
    ``SharedExtractServer`` so the solo path and the server can never run
    different weights for the same variant string ("adaptive" is not a
    physical variant: the op's density tracker resolves it to big/pruned
    before any forward)."""
    return {
        "big": (ctx.mllm, ctx.mllm_params),
        "small": (ctx.mllm_small, ctx.mllm_small_params),
        "pruned": (ctx.mllm, ctx.mllm_pruned_params),
    }


def make_extract_fn(mllm: StreamMLLM, params):
    """Jitted batched union extract: frames -> argmax prediction per task.

    One forward computes *every* head (the union of any task subset costs
    the same as a single task), so callers serving heterogeneous task sets
    simply read the attributes they asked for.  Normalization is decided
    **per frame** (raw uint8-range vs already-normalized), not from the
    batch max: the SharedExtractServer coalesces frames from several
    streams — possibly at different preprocessing stages — into one padded
    forward, and each row must come out bitwise identical to a solo run.
    Zero padding rows classify as "normalized" and are sliced off by the
    caller, so they never perturb real rows.
    """

    @jax.jit
    def run(frames):
        x = frames.astype(jnp.float32)
        raw = x.reshape(x.shape[0], -1).max(axis=1) > 8.0
        x = jnp.where(raw[:, None, None, None],
                      (x / 255.0 - 0.5) / 0.25, x)
        out = mllm.forward(params, x)
        return {k: jnp.argmax(v, -1) for k, v in out.items()}

    return run


def distill_loss(student: StreamMLLM, teacher_out: Dict[str, jax.Array],
                 params, frames, temperature: float = 2.0) -> jax.Array:
    """Soft-label multi-head distillation (physical optimization)."""
    s_out = student.forward(params, frames)
    t = temperature
    total = jnp.zeros((), jnp.float32)
    for name in s_out:
        p_t = jax.nn.softmax(teacher_out[name] / t, -1)
        logp_s = jax.nn.log_softmax(s_out[name] / t, -1)
        total += -jnp.mean(jnp.sum(p_t * logp_s, -1)) * t * t
    return total
