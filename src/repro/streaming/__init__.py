from repro.streaming.mllm import StreamMLLM, MLLM_TASKS
from repro.streaming.detector import TinyDet
from repro.streaming.operators import (
    Op,
    SourceOp,
    SkipOp,
    CropOp,
    DownscaleOp,
    GreyscaleOp,
    FusedPreprocessOp,
    CheapColorFilterOp,
    DetectOp,
    MLLMExtractOp,
    FilterOp,
    WindowAggOp,
    SinkOp,
)
from repro.streaming.plan import Plan
from repro.streaming.runtime import StreamRuntime, RunResult
from repro.streaming.multiquery import MultiQueryRuntime, MultiQueryResult
