"""Micro-batch streaming runtime.

Drives a Plan over a frame stream: pulls micro-batches from the source,
pushes them through the operator chain (each op may drop rows — the runtime
simply forwards the compacted batch), collects sink outputs, and tracks
per-operator input counts + wall time (the paper's FPS / model-load
metrics).

Fault tolerance: ``snapshot()`` captures every operator's state + the source
frame index (an aligned checkpoint — between micro-batches all channels are
empty, so alignment is free); ``restore()`` resumes exactly-once by replaying
the source from the recorded offset.  Frame indices continue from the
restored offset, ``flush()`` is non-destructive (early firing), and the
first ``run()`` after ``restore()`` suppresses the warmup reset — so
tumbling windows tumble identically across a snapshot/resume boundary.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List

import numpy as np

from repro.faults import guard_stream
from repro.obs import resolve_obs
from repro.streaming.operators import (
    MLLMExtractOp,
    Op,
    OpContext,
    SinkOp,
)
from repro.streaming.plan import Plan


@dataclasses.dataclass
class RunResult:
    fps: float
    wall_s: float
    n_frames: int
    outputs: List[Dict[str, Any]]
    window_results: List[Dict[str, Any]]
    op_input_counts: Dict[str, int]
    mllm_frames: int
    labels: List[Dict[str, Any]]


# ---------------------------------------------------------------------------
# Shared warmup / end-of-stream protocol (used by StreamRuntime and
# MultiQueryRuntime — one implementation, so the two executors cannot drift
# and break the shared-vs-independent exact-match contract).
# ---------------------------------------------------------------------------

def warmup_ops(stream, micro_batch: int, advance, ops: List[Op]) -> None:
    """Push one untimed batch (negative indices, separate from the measured
    stream) through ``advance`` to trigger compilation, then rewind the
    stream and Op.reset() every operator so no warmup state leaks."""
    frames, labels = stream.batch(micro_batch)
    advance({"frames": frames,
             "idx": np.arange(len(labels)) - len(labels)})
    stream.reset()
    for op in ops:
        op.reset()


def mllm_frames_of(ops: List[Op]) -> int:
    """Lifetime MLLM model load of an op chain (frames through extracts)."""
    return sum(op.frames_processed for op in ops
               if isinstance(op, MLLMExtractOp))


class RunScaffold:
    """Run-lifecycle bookkeeping shared by every executor (StreamRuntime,
    MultiQueryRuntime, and the multi-stream group executors).

    One implementation of the three pieces that used to be duplicated and
    could drift: (1) warmup suppression after restore() — the first run on
    restored state must not warmup-reset it; (2) per-run (not lifetime)
    ``mllm_frames`` reporting — ``frames_processed`` accumulates across
    resumed segments, so runs diff against a baseline taken at run start;
    (3) per-micro-batch source-index advance, so a snapshot taken after a
    mid-run failure stays aligned with operator state.
    """

    def _init_scaffold(self, ctx: OpContext, micro_batch: int,
                       ops: List[Op]) -> None:
        self.ctx = dataclasses.replace(ctx, micro_batch=micro_batch)
        self.micro_batch = micro_batch
        #: observability handle (``ctx.obs`` or the inert NULL_OBS) — one
        #: resolution point for every scaffolded executor
        self.obs = resolve_obs(getattr(ctx, "obs", None))
        for op in ops:
            op.open(self.ctx)
        self._source_index = 0
        self._restored = False

    def _mark_restored(self) -> None:
        """The next run() must not warmup-reset the restored state."""
        self._restored = True

    def _begin_run(self, stream, warmup: int, advance, ops: List[Op],
                   ) -> int:
        """Warmup (unless suppressed by a preceding restore) and return the
        run's MLLM model-load baseline over ``ops``."""
        if warmup and not self._restored:
            warmup_ops(stream, self.micro_batch, advance, ops)
            self._source_index = 0
        self._restored = False
        return mllm_frames_of(ops)

    def _stamp(self, batch: Dict[str, Any]) -> None:
        """Advance the checkpoint offset past this micro-batch."""
        self._source_index = int(batch["idx"][-1]) + 1


def drive_stream(stream, n_frames: int, micro_batch: int, base: int,
                 advance, labels_all: List[Dict[str, Any]]) -> int:
    """The measured driver loop: pull micro-batches, stamp absolute frame
    indices continuing from ``base``, hand each batch to ``advance``.
    Returns the new source index."""
    done = 0
    while done < n_frames:
        take = min(micro_batch, n_frames - done)
        frames, labels = stream.batch(take)
        labels_all.extend(labels)
        advance({"frames": frames,
                 "idx": np.arange(base + done, base + done + take)})
        done += take
    return base + done


def flush_ops(ops: List[Op], emit, terminal=None) -> None:
    """End of stream: let every op in the chain emit buffered partials and
    push them through the downstream ops.  ``emit`` receives window
    results; ``terminal``, if given, receives each fully-propagated batch
    (the multi-query runtime fans it out to the per-query tails)."""
    for i, op in enumerate(ops):
        fb = op.flush()
        if fb is None:
            continue
        if "window_results" in fb:
            emit(fb.pop("window_results"))
        for nxt in ops[i + 1:]:
            fb = nxt.process(fb)
            if "window_results" in fb:
                emit(fb.pop("window_results"))
        if terminal is not None:
            terminal(fb)


class StreamRuntime(RunScaffold):
    def __init__(self, plan: Plan, ctx: OpContext, micro_batch: int = 16):
        self.plan = plan
        self._init_scaffold(ctx, micro_batch, plan.ops)

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {
            "source_index": self._source_index,
            "ops": [op.snapshot() for op in self.plan.ops],
        }

    def restore(self, st: Dict[str, Any]) -> None:
        self._source_index = st["source_index"]
        for op, s in zip(self.plan.ops, st["ops"]):
            op.restore(s)
        self._mark_restored()

    # ------------------------------------------------------------------
    def run(self, stream, n_frames: int, warmup: int = 1,
            flush: bool = True) -> RunResult:
        """``warmup=1`` (default) makes this a *fresh* measurement: the
        stream is rewound and every op reset.  Pass ``warmup=0`` to
        continue a previous segment; the first run after ``restore()``
        continues automatically."""
        sink = self.plan.ops[-1]
        assert isinstance(sink, SinkOp)
        sink.collected = []
        counts: Dict[str, int] = {op.name: 0 for op in self.plan.ops}
        window_results: List[Dict[str, Any]] = []
        labels_all: List[Dict[str, Any]] = []

        def warm_advance(batch):
            for op in self.plan.ops:
                batch = op.process(batch)

        mllm_start = self._begin_run(stream, warmup, warm_advance,
                                     self.plan.ops)

        obs = self.obs

        def advance(batch):
            self._stamp(batch)
            t_b = obs.now() if obs.enabled else 0
            n0 = len(batch["idx"])
            for op in self.plan.ops:
                counts[op.name] += len(batch["idx"])
                if obs.enabled:
                    t_op = obs.now()
                    batch = op.process(batch)
                    obs.tracer.span(f"op:{op.name}", "prefix", t_op,
                                    obs.now(), track="stream",
                                    n=len(batch["idx"]))
                else:
                    batch = op.process(batch)
                if "window_results" in batch:
                    window_results.extend(batch.pop("window_results"))
            if obs.enabled:
                obs.slo.record("stream", (obs.now() - t_b) / 1e6, n=n0)

        # solo ingest rides the same transport-fault protocol as the
        # multi-feed runtime: validation + bounded redelivery when a
        # fault injector is live, the bare stream otherwise (zero cost).
        # Warmup above ran unguarded — it must not consume schedule
        # events the measured stream would then never see.
        guarded = guard_stream(stream, getattr(self.ctx, "faults", None))

        t0 = time.perf_counter()
        drive_stream(guarded, n_frames, self.micro_batch,
                     self._source_index, advance, labels_all)
        if flush:
            flush_ops(self.plan.ops, window_results.extend)
        wall = time.perf_counter() - t0
        if obs.enabled:
            obs.metrics.set_gauge("run/wall_s", wall)

        mllm_frames = mllm_frames_of(self.plan.ops) - mllm_start
        return RunResult(
            fps=n_frames / wall,
            wall_s=wall,
            n_frames=n_frames,
            outputs=sink.collected,
            window_results=window_results,
            op_input_counts=counts,
            mllm_frames=mllm_frames,
            labels=labels_all,
        )
