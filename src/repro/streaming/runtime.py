"""Micro-batch streaming runtime.

Drives a Plan over a frame stream: pulls micro-batches from the source,
pushes them through the operator chain (each op may drop rows — the runtime
simply forwards the compacted batch), collects sink outputs, and tracks
per-operator input counts + wall time (the paper's FPS / model-load
metrics).

Fault tolerance: ``snapshot()`` captures every operator's state + the source
frame index (an aligned checkpoint — between micro-batches all channels are
empty, so alignment is free); ``restore()`` resumes exactly-once by replaying
the source from the recorded offset.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.streaming.operators import (
    MLLMExtractOp,
    Op,
    OpContext,
    SinkOp,
    SourceOp,
)
from repro.streaming.plan import Plan


@dataclasses.dataclass
class RunResult:
    fps: float
    wall_s: float
    n_frames: int
    outputs: List[Dict[str, Any]]
    window_results: List[Dict[str, Any]]
    op_input_counts: Dict[str, int]
    mllm_frames: int
    labels: List[Dict[str, Any]]


class StreamRuntime:
    def __init__(self, plan: Plan, ctx: OpContext, micro_batch: int = 16):
        self.plan = plan
        self.ctx = ctx
        self.micro_batch = micro_batch
        for op in plan.ops:
            op.open(ctx)
        self._source_index = 0

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {
            "source_index": self._source_index,
            "ops": [op.snapshot() for op in self.plan.ops],
        }

    def restore(self, st: Dict[str, Any]) -> None:
        self._source_index = st["source_index"]
        for op, s in zip(self.plan.ops, st["ops"]):
            op.restore(s)

    # ------------------------------------------------------------------
    def run(self, stream, n_frames: int, warmup: int = 1) -> RunResult:
        sink = self.plan.ops[-1]
        assert isinstance(sink, SinkOp)
        sink.collected = []
        counts: Dict[str, int] = {op.name: 0 for op in self.plan.ops}
        window_results: List[Dict[str, Any]] = []
        labels_all: List[Dict[str, Any]] = []

        # warmup batch to trigger compilation (not timed, separate stream)
        if warmup:
            frames, labels = stream.batch(self.micro_batch)
            batch = {"frames": frames,
                     "idx": np.arange(len(labels)) - len(labels)}
            for op in self.plan.ops:
                batch = op.process(batch)
            # reset state polluted by warmup
            stream.reset()
            for op in self.plan.ops:
                if hasattr(op, "_prev"):
                    op._prev = None
                if hasattr(op, "_skip_left"):
                    op._skip_left = 0
                if hasattr(op, "_buf"):
                    op._buf = []
                    op._window_start = 0
                if isinstance(op, MLLMExtractOp):
                    op.frames_processed = 0
            sink.collected = []

        done = 0
        t0 = time.perf_counter()
        while done < n_frames:
            take = min(self.micro_batch, n_frames - done)
            frames, labels = stream.batch(take)
            labels_all.extend(labels)
            batch = {"frames": frames,
                     "idx": np.arange(done, done + take)}
            done += take
            self._source_index = done
            for op in self.plan.ops:
                counts[op.name] += len(batch["idx"])
                batch = op.process(batch)
                if "window_results" in batch:
                    window_results.extend(batch.pop("window_results"))
        wall = time.perf_counter() - t0

        mllm_frames = sum(op.frames_processed for op in self.plan.ops
                          if isinstance(op, MLLMExtractOp))
        return RunResult(
            fps=n_frames / wall,
            wall_s=wall,
            n_frames=n_frames,
            outputs=sink.collected,
            window_results=window_results,
            op_input_counts=counts,
            mllm_frames=mllm_frames,
            labels=labels_all,
        )
