"""Micro-batch streaming runtime.

Drives a Plan over a frame stream: pulls micro-batches from the source,
pushes them through the operator chain (each op may drop rows — the runtime
simply forwards the compacted batch), collects sink outputs, and tracks
per-operator input counts + wall time (the paper's FPS / model-load
metrics).

Fault tolerance: ``snapshot()`` captures every operator's state + the source
frame index (an aligned checkpoint — between micro-batches all channels are
empty, so alignment is free); ``restore()`` resumes exactly-once by replaying
the source from the recorded offset.  Frame indices continue from the
restored offset, ``flush()`` is non-destructive (early firing), and the
first ``run()`` after ``restore()`` suppresses the warmup reset — so
tumbling windows tumble identically across a snapshot/resume boundary.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List

import numpy as np

from repro.streaming.operators import (
    MLLMExtractOp,
    Op,
    OpContext,
    SinkOp,
)
from repro.streaming.plan import Plan


@dataclasses.dataclass
class RunResult:
    fps: float
    wall_s: float
    n_frames: int
    outputs: List[Dict[str, Any]]
    window_results: List[Dict[str, Any]]
    op_input_counts: Dict[str, int]
    mllm_frames: int
    labels: List[Dict[str, Any]]


# ---------------------------------------------------------------------------
# Shared warmup / end-of-stream protocol (used by StreamRuntime and
# MultiQueryRuntime — one implementation, so the two executors cannot drift
# and break the shared-vs-independent exact-match contract).
# ---------------------------------------------------------------------------

def warmup_ops(stream, micro_batch: int, advance, ops: List[Op]) -> None:
    """Push one untimed batch (negative indices, separate from the measured
    stream) through ``advance`` to trigger compilation, then rewind the
    stream and Op.reset() every operator so no warmup state leaks."""
    frames, labels = stream.batch(micro_batch)
    advance({"frames": frames,
             "idx": np.arange(len(labels)) - len(labels)})
    stream.reset()
    for op in ops:
        op.reset()


def drive_stream(stream, n_frames: int, micro_batch: int, base: int,
                 advance, labels_all: List[Dict[str, Any]]) -> int:
    """The measured driver loop: pull micro-batches, stamp absolute frame
    indices continuing from ``base``, hand each batch to ``advance``.
    Returns the new source index."""
    done = 0
    while done < n_frames:
        take = min(micro_batch, n_frames - done)
        frames, labels = stream.batch(take)
        labels_all.extend(labels)
        advance({"frames": frames,
                 "idx": np.arange(base + done, base + done + take)})
        done += take
    return base + done


def flush_ops(ops: List[Op], emit, terminal=None) -> None:
    """End of stream: let every op in the chain emit buffered partials and
    push them through the downstream ops.  ``emit`` receives window
    results; ``terminal``, if given, receives each fully-propagated batch
    (the multi-query runtime fans it out to the per-query tails)."""
    for i, op in enumerate(ops):
        fb = op.flush()
        if fb is None:
            continue
        if "window_results" in fb:
            emit(fb.pop("window_results"))
        for nxt in ops[i + 1:]:
            fb = nxt.process(fb)
            if "window_results" in fb:
                emit(fb.pop("window_results"))
        if terminal is not None:
            terminal(fb)


class StreamRuntime:
    def __init__(self, plan: Plan, ctx: OpContext, micro_batch: int = 16):
        self.plan = plan
        self.ctx = dataclasses.replace(ctx, micro_batch=micro_batch)
        self.micro_batch = micro_batch
        for op in plan.ops:
            op.open(self.ctx)
        self._source_index = 0
        self._restored = False

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {
            "source_index": self._source_index,
            "ops": [op.snapshot() for op in self.plan.ops],
        }

    def restore(self, st: Dict[str, Any]) -> None:
        self._source_index = st["source_index"]
        for op, s in zip(self.plan.ops, st["ops"]):
            op.restore(s)
        # the next run() must not warmup-reset the restored state
        self._restored = True

    # ------------------------------------------------------------------
    def _warmup(self, stream) -> None:
        def advance(batch):
            for op in self.plan.ops:
                batch = op.process(batch)

        warmup_ops(stream, self.micro_batch, advance, self.plan.ops)
        self._source_index = 0

    def run(self, stream, n_frames: int, warmup: int = 1,
            flush: bool = True) -> RunResult:
        """``warmup=1`` (default) makes this a *fresh* measurement: the
        stream is rewound and every op reset.  Pass ``warmup=0`` to
        continue a previous segment; the first run after ``restore()``
        continues automatically."""
        sink = self.plan.ops[-1]
        assert isinstance(sink, SinkOp)
        sink.collected = []
        counts: Dict[str, int] = {op.name: 0 for op in self.plan.ops}
        window_results: List[Dict[str, Any]] = []
        labels_all: List[Dict[str, Any]] = []

        if warmup and not self._restored:
            self._warmup(stream)
        self._restored = False
        # report per-run (not lifetime) model load: frames_processed keeps
        # accumulating across resumed segments, so diff against the start
        mllm_start = sum(op.frames_processed for op in self.plan.ops
                         if isinstance(op, MLLMExtractOp))

        def advance(batch):
            # advance the checkpoint offset per micro-batch so a snapshot
            # taken after a mid-run failure stays aligned with op state
            self._source_index = int(batch["idx"][-1]) + 1
            for op in self.plan.ops:
                counts[op.name] += len(batch["idx"])
                batch = op.process(batch)
                if "window_results" in batch:
                    window_results.extend(batch.pop("window_results"))

        t0 = time.perf_counter()
        drive_stream(stream, n_frames, self.micro_batch,
                     self._source_index, advance, labels_all)
        if flush:
            flush_ops(self.plan.ops, window_results.extend)
        wall = time.perf_counter() - t0

        mllm_frames = sum(op.frames_processed for op in self.plan.ops
                          if isinstance(op, MLLMExtractOp)) - mllm_start
        return RunResult(
            fps=n_frames / wall,
            wall_s=wall,
            n_frames=n_frames,
            outputs=sink.collected,
            window_results=window_results,
            op_input_counts=counts,
            mllm_frames=mllm_frames,
            labels=labels_all,
        )
