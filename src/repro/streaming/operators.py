"""Streaming operators: declarative descriptors + jitted implementations.

An operator is a *descriptor* dataclass (the unit the Saṃsāra optimizer
rewrites) plus an ``open(ctx)``/``process(batch)`` runtime implementation.
Batches flow host-side as dicts of numpy arrays (frames, indices, attrs);
the compute inside each operator is jitted JAX.  Operators may drop rows
(Skip / filters) — the runtime compacts and re-buckets between stages, which
is what converts "fewer frames reach the MLLM" into real wall-clock FPS.

State (skip counters, previous frame, window buffers) is explicit and
snapshottable — the streaming analogue of Flink's aligned checkpoints.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tollbooth import BRANDS, COLORS, COLOR_RGB, PLATE_CHARS
from repro.data.volleyball import ACTIONS
from repro.kernels.frame_diff.ops import frame_diff
from repro.kernels.fused_preprocess.ops import fused_preprocess
from repro.streaming.mllm import (MLLM_TASKS, PLATE_LEN, StreamMLLM,
                                  make_extract_fn, variant_models)

Batch = Dict[str, Any]


def _bucket_pad(n: int, lo: int = 4) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


# ===========================================================================
# Descriptor base
# ===========================================================================

@dataclasses.dataclass
class Op:
    """Base descriptor. Subclasses add parameters; runtime calls open()."""

    #: measured *marginal* cost per input frame (µs) — stamped by the cost
    #: catalog (``repro.core.costs``).  Negative means *uncalibrated*: 0.0
    #: is a legitimate measurement for a free op, so the sentinel is < 0.
    cost_us: float = dataclasses.field(default=-1.0, init=False)

    #: measured fixed cost per invocation (µs): dispatch + compile-cache
    #: lookup + padding overhead, paid once per processed batch however few
    #: frames it holds.  Sharing amortizes exactly this term — a union
    #: extract pays it once where k independent extracts pay it k times.
    overhead_us: float = dataclasses.field(default=0.0, init=False)

    #: measured survivor fraction (output rows / input rows) on the
    #: calibration sample — 1.0 for pure transforms, < 1.0 for filters.
    #: Stamped alongside ``cost_us``; chain cost estimates downstream load
    #: through it (the logical optimizer's pushdown gate, fleet-wide).
    pass_rate: float = dataclasses.field(default=1.0, init=False)

    name: str = dataclasses.field(default="", init=False)

    def open(self, ctx: "OpContext") -> None:  # pragma: no cover - interface
        pass

    def process(self, batch: Batch) -> Batch:  # pragma: no cover - interface
        raise NotImplementedError

    def reset(self) -> None:
        """Return all mutable runtime state to its just-opened value.

        The runtime calls this after the (untimed) warmup batch and when a
        shared executor re-arms a plan; every stateful subclass must
        override it — warmup must not leak into the measured stream."""

    def flush(self) -> Optional[Batch]:
        """End-of-stream: emit any buffered partial results (e.g. the last
        tumbling window) as a batch to push through downstream operators,
        or None if there is nothing pending."""
        return None

    def signature(self) -> Tuple:
        """Structural identity (class + init parameters, no runtime state)
        — the unit of common-subplan factoring across queries."""
        params = tuple(
            (f.name, getattr(self, f.name))
            for f in dataclasses.fields(self) if f.init)
        return (type(self).__name__,) + params

    # -- state snapshot (aligned checkpoint) --------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {}

    def restore(self, st: Dict[str, Any]) -> None:
        pass


@dataclasses.dataclass
class OpContext:
    """Models/params every plan may reference."""

    mllm: Optional[StreamMLLM] = None
    mllm_params: Any = None
    mllm_small: Optional[StreamMLLM] = None
    mllm_small_params: Any = None
    mllm_pruned_params: Any = None
    detector: Any = None
    detector_params: Any = None
    #: optional ``repro.semantic.SemanticGate`` — the temporal-redundancy
    #: extract cache.  None (default) keeps every extract path exactly as
    #: it was; an *inactive* gate (threshold 0) is equally inert.
    gate: Any = None
    #: optional ``repro.obs.Observability`` — frame-lifecycle tracing +
    #: metrics + SLO accounting.  None (default) resolves to the inert
    #: ``NULL_OBS``: instrumented paths pay only no-op calls and stay
    #: bitwise identical to un-instrumented serving.
    obs: Any = None
    #: optional ``repro.faults.FaultInjector`` — deterministic fault
    #: injection + the retry/breaker machinery it exercises.  None
    #: (default) resolves to the inert ``NULL_FAULTS``: every fault call
    #: site is guarded by ``if faults.enabled:`` and the un-faulted
    #: stack stays bitwise identical.
    faults: Any = None
    frame_shape: Tuple[int, int, int] = (3, 128, 256)
    #: micro-batch size the driving runtime uses — operators that estimate
    #: stream density (adaptive pruning) read it instead of guessing
    micro_batch: int = 16


# ===========================================================================
# Source / Sink
# ===========================================================================

@dataclasses.dataclass
class SourceOp(Op):
    stream_name: str = "tollbooth"

    def __post_init__(self):
        self.name = f"source[{self.stream_name}]"

    def process(self, batch: Batch) -> Batch:
        return batch


@dataclasses.dataclass
class SinkOp(Op):
    def __post_init__(self):
        self.name = "sink"
        self.collected: List[Dict[str, Any]] = []

    def process(self, batch: Batch) -> Batch:
        n = len(batch["idx"])
        if not batch.get("_suppress_sink"):
            # quarantine-recovery replay: frames re-driven to rebuild
            # operator state were already accounted (served before the
            # trip, or degraded/dropped during it) — re-collecting their
            # records would serve them twice
            for i in range(n):
                rec = {"idx": int(batch["idx"][i])}
                for k, v in batch.get("attrs", {}).items():
                    rec[k] = np.asarray(v[i]).tolist()
                self.collected.append(rec)
        if "window_results" in batch:
            self.collected.extend(batch["window_results"])
        return batch

    def reset(self):
        self.collected = []

    def snapshot(self):
        return {"n": len(self.collected)}


# ===========================================================================
# Semantic data-reduction operators (the paper's catalog)
# ===========================================================================

@dataclasses.dataclass
class SkipOp(Op):
    """Skip(Amount, Condition): after an "empty" frame, drop the next
    ``amount`` frames without any further compute.  Emptiness = mean region
    frame-diff against the last kept frame below ``threshold`` inside the
    region of interest (cross-frame reasoning: cars cannot appear faster
    than v_max allows)."""

    amount: int = 3
    condition: str = "no_car"
    threshold: float = 0.02
    roi: Optional[Tuple[int, int, int, int]] = None   # y0,x0,h,w region
    regions: Tuple[int, int] = (4, 8)

    def __post_init__(self):
        self.name = f"skip[{self.amount},{self.condition}]"
        self._prev: Optional[np.ndarray] = None
        self._skip_left = 0

    def open(self, ctx: OpContext) -> None:
        self._diff = functools.partial(frame_diff, regions=self.regions)

    def prev_frames(self, frames: np.ndarray) -> np.ndarray:
        """The per-row predecessors one batched diff call compares
        against: frame i vs frame i-1, the first vs the carried state."""
        prev0 = self._prev if self._prev is not None else frames[0]
        return np.concatenate([prev0[None], frames[:-1]], axis=0)

    def keep_from_diff(self, frames: np.ndarray,
                       d: np.ndarray) -> np.ndarray:
        """Advance the skip state over one batch given its (n, ry, rx)
        diff grid and return the keep mask.  Split from ``process`` so
        ``FusedPrefixOp`` can feed the diff its own single device pass
        produced — the host-side stateful loop stays the one
        implementation either way."""
        n = frames.shape[0]
        keep = np.ones(n, bool)
        if self.roi is not None:
            y0, x0, hh, ww = self.roi
            ry, rx = self.regions
            rh, rw = frames.shape[2] // ry, frames.shape[3] // rx
            d = d[:, y0 // rh:(y0 + hh + rh - 1) // rh,
                  x0 // rw:(x0 + ww + rw - 1) // rw]
        act = d.reshape(n, -1).max(axis=1)             # per-frame activity
        for i in range(n):                             # cheap host loop
            if self._skip_left > 0:
                self._skip_left -= 1
                keep[i] = False
                continue
            if self._prev is None:
                self._prev = frames[i]
                continue
            if act[i] < self.threshold:
                keep[i] = False
                self._skip_left = self.amount
        self._prev = frames[-1]
        return keep

    def process(self, batch: Batch) -> Batch:
        frames = batch["frames"]
        n = frames.shape[0]
        if n == 0:
            return batch
        # one batched kernel call: frame i vs frame i-1 (first vs carry)
        d = np.asarray(self._diff(frames, self.prev_frames(frames)))
        return _mask_batch(batch, self.keep_from_diff(frames, d))

    def reset(self):
        self._prev = None
        self._skip_left = 0

    def snapshot(self):
        return {"prev": self._prev, "skip_left": self._skip_left}

    def restore(self, st):
        self._prev = st["prev"]
        self._skip_left = st["skip_left"]


@dataclasses.dataclass
class CropOp(Op):
    """Crop(region): spatial projection (logical: projection pushdown)."""

    region: Tuple[int, int, int, int] = (64, 0, 64, 256)  # y0,x0,h,w

    def __post_init__(self):
        self.name = f"crop{self.region}"

    def process(self, batch: Batch) -> Batch:
        y0, x0, h, w = self.region
        batch = dict(batch)
        batch["frames"] = batch["frames"][:, :, y0:y0 + h, x0:x0 + w]
        return batch


@dataclasses.dataclass
class DownscaleOp(Op):
    """Downscale(resolution): area-mean pooling (logical: aggregation)."""

    factor: int = 2

    def __post_init__(self):
        self.name = f"downscale[{self.factor}]"

    def process(self, batch: Batch) -> Batch:
        f = self.factor
        x = batch["frames"]
        b, c, h, w = x.shape
        x = x.reshape(b, c, h // f, f, w // f, f).astype(np.float32)
        x = x.mean(axis=(3, 5))
        batch = dict(batch)
        batch["frames"] = x.astype(batch["frames"].dtype) \
            if batch["frames"].dtype == np.uint8 else x
        return batch


@dataclasses.dataclass
class GreyscaleOp(Op):
    def __post_init__(self):
        self.name = "greyscale"

    def process(self, batch: Batch) -> Batch:
        x = batch["frames"].astype(np.float32)
        g = 0.299 * x[:, 0] + 0.587 * x[:, 1] + 0.114 * x[:, 2]
        batch = dict(batch)
        batch["frames"] = np.repeat(g[:, None], 3, axis=1).astype(
            batch["frames"].dtype)
        return batch


@dataclasses.dataclass
class FusedPreprocessOp(Op):
    """Crop+Downscale+Normalize(+Greyscale) in one pass — produced by the
    logical optimizer's fusion rule; maps to the Pallas kernel on TPU."""

    crop: Tuple[int, int, int, int] = (0, 0, 128, 256)
    factor: int = 1
    grey: bool = False

    def __post_init__(self):
        self.name = f"fused_preprocess[{self.crop},/{self.factor}" + \
            (",grey]" if self.grey else "]")

    def open(self, ctx: OpContext) -> None:
        self._fn = jax.jit(functools.partial(
            fused_preprocess, crop=self.crop, factor=self.factor,
            grey=self.grey))

    def process(self, batch: Batch) -> Batch:
        batch = dict(batch)
        out = np.asarray(self._fn(jnp.asarray(batch["frames"])))
        if self.grey:
            out = np.repeat(out, 3, axis=1)
        batch["frames"] = out
        batch["normalized"] = True
        return batch


# ===========================================================================
# Logical-phase cheap filters / physical-phase cascade
# ===========================================================================

@dataclasses.dataclass
class CheapColorFilterOp(Op):
    """Pixel-statistics filter: keep frames whose ROI contains at least
    ``min_frac`` pixels near the target color (the paper's 'red-ish pixels'
    pushdown filter, realized without any model)."""

    color: str = "red"
    min_frac: float = 0.01
    roi: Optional[Tuple[int, int, int, int]] = None

    def __post_init__(self):
        self.name = f"cheap_color[{self.color}]"

    def open(self, ctx: OpContext) -> None:
        rgb = np.asarray(COLOR_RGB[self.color], np.float32)

        @jax.jit
        def frac(frames):
            x = frames.astype(jnp.float32)
            # raw vs normalized is a *per-frame* property — the same
            # convention as make_extract_fn: a batch-global max would
            # mis-normalize every row of a mixed-stage batch
            norm = x.reshape(x.shape[0], -1).max(axis=1) <= 8.0
            x = jnp.where(norm[:, None, None, None],
                          (x * 0.25 + 0.5) * 255.0, x)
            d = jnp.linalg.norm(x.transpose(0, 2, 3, 1) - rgb, axis=-1)
            near = (d < 70.0).astype(jnp.float32)
            return near.mean(axis=(1, 2))

        self._frac = frac

    def process(self, batch: Batch) -> Batch:
        if batch["frames"].shape[0] == 0:
            return batch
        roi_frames = batch["frames"]
        if self.roi is not None:
            y0, x0, h, w = self.roi
            roi_frames = roi_frames[:, :, y0:y0 + h, x0:x0 + w]
        frac = np.asarray(self._frac(jnp.asarray(roi_frames)))
        return _mask_batch(batch, frac >= self.min_frac)


@dataclasses.dataclass
class DetectOp(Op):
    """TinyDet cascade: drop frames without the object (physical phase)."""

    threshold: float = 0.5

    def __post_init__(self):
        self.name = "tinydet"

    def open(self, ctx: OpContext) -> None:
        det, params = ctx.detector, ctx.detector_params

        @jax.jit
        def run(frames):
            x = frames.astype(jnp.float32)
            # per-frame raw detection (the make_extract_fn convention):
            # the batch max would mis-normalize mixed-stage batches
            raw = x.reshape(x.shape[0], -1).max(axis=1) > 8.0
            x = jnp.where(raw[:, None, None, None], x / 255.0 - 0.5, x)
            out = det.forward(params, x)
            return jax.nn.softmax(out["present"], -1)[:, 1]

        self._run = run

    def process(self, batch: Batch) -> Batch:
        if batch["frames"].shape[0] == 0:
            return batch
        p = np.asarray(self._run(jnp.asarray(batch["frames"])))
        return _mask_batch(batch, p >= self.threshold)


# ===========================================================================
# The MLLM operator
# ===========================================================================

@dataclasses.dataclass
class MLLMExtractOp(Op):
    """Extract(tasks) with a selectable physical implementation.

    model="adaptive" realizes the paper's *adaptive pruning*: the runtime
    switches between the full and the pruned variant per micro-batch from
    the observed stream density (aggressive pruning is safe in low-traffic
    periods, risky in high-traffic ones)."""

    tasks: Tuple[str, ...] = ("present", "color", "plate")
    model: str = "big"          # big | small | pruned | adaptive
    density_threshold: float = 0.35

    def __post_init__(self):
        self.name = f"mllm[{self.model}:{','.join(self.tasks)}]"
        self.frames_processed = 0
        self.forwards = 0            # jitted extract invocations this run
        self._density_ema = 0.5

    def open(self, ctx: OpContext) -> None:
        self._micro_batch_hint = ctx.micro_batch
        # jax.jit is lazy, so building both adaptive variants (or a variant
        # the SharedExtractServer route never invokes) costs nothing until
        # the first solo process() call actually traces it
        variants = variant_models(ctx)
        wanted = ("big", "pruned") if self.model == "adaptive" \
            else (self.model,)
        self._runs = {v: make_extract_fn(*variants[v]) for v in wanted}
        # semantic gating (solo path): the server route consults the gate
        # inside SharedExtractServer.submit instead, keyed by feed name
        self._gate = ctx.gate
        self._gate_feed = f"op:{id(self)}"
        if self._gate is not None and ctx.obs is not None:
            # the gate emits its own consult spans / hit-miss events
            self._gate.obs = ctx.obs

    def resolve_variant(self, n: int) -> str:
        """Pick the physical variant for a batch of ``n`` surviving frames.

        For model="adaptive" this *advances* the density EMA (the paper's
        adaptive pruning: aggressive pruning is safe in low-traffic
        periods) — call exactly once per processed batch."""
        if self.model != "adaptive":
            return self.model
        density = n / max(self._micro_batch_hint, 1)
        self._density_ema = 0.8 * self._density_ema + 0.2 * density
        return "big" if self._density_ema >= self.density_threshold \
            else "pruned"

    def begin_extract(self, n: int) -> str:
        """Account ``n`` frames of model load and resolve the variant —
        the shared half of process(); the SharedExtractServer route calls
        this then ships the un-padded frames to the server instead of
        running the op's own jitted program.

        ``frames_processed`` (and hence every runtime's ``mllm_frames``)
        counts frames *reaching* the extract — the logical model load the
        plan-level optimizations are scored on.  With semantic gating the
        cache tier absorbs part of it downstream: the frames that
        actually paid a forward are the gate/server counters
        (``cache_misses + revalidations``, the server's ``frames``), so
        gated and ungated runs stay comparable on both axes."""
        self.frames_processed += n
        return self.resolve_variant(n)

    def apply_preds(self, batch: Batch, preds: Dict[str, Any],
                    n: int) -> Batch:
        """Merge per-task predictions (first ``n`` rows are real) into the
        batch's attrs — shared by the solo and the server-routed path."""
        batch = dict(batch)
        attrs = dict(batch.get("attrs", {}))
        for k, v in preds.items():
            attrs[k] = np.asarray(v)[:n]
        batch["attrs"] = attrs
        return batch

    def _forward(self, variant: str, frames: np.ndarray, n: int):
        """One bucket-padded jitted forward over ``frames[:n]``."""
        bucket = _bucket_pad(n)
        if bucket != n:
            pad = np.zeros((bucket - n,) + frames.shape[1:], frames.dtype)
            frames = np.concatenate([frames, pad], 0)
        self.forwards += 1
        return self._runs[variant](jnp.asarray(frames))

    def process(self, batch: Batch) -> Batch:
        # a FusedPrefixOp immediately upstream computed the gate
        # signature in its single device pass; consume it here so it
        # never leaks past the extract into tails or sink records
        sig = None
        if "_sig" in batch:
            batch = dict(batch)        # copy-on-write, like every op
            sig = batch.pop("_sig")
        n = batch["frames"].shape[0]
        if n == 0:
            return batch
        variant = self.begin_extract(n)
        gate = self._gate
        if gate is not None and gate.active:
            # cache-consult stage: near-duplicates of a recent keyframe
            # are answered from the semantic cache; only novel frames and
            # revalidation hits pay the forward
            adm = gate.admit(self._gate_feed, variant, batch["frames"],
                             sig=sig)
            if adm.n_model:
                mf = adm.model_frames(batch["frames"])
                preds = self._forward(variant, mf, adm.n_model)
                adm.bind({k: np.asarray(v)[:adm.n_model]
                          for k, v in preds.items()})
            else:
                adm.bind(None)
            return self.apply_preds(batch, adm.assemble(), n)
        preds = self._forward(variant, batch["frames"], n)
        return self.apply_preds(batch, preds, n)

    def reset(self):
        self.frames_processed = 0
        self.forwards = 0
        self._density_ema = 0.5
        if getattr(self, "_gate", None) is not None:
            self._gate.reset(self._gate_feed)

    def snapshot(self):
        st = {"frames_processed": self.frames_processed,
              "forwards": self.forwards,
              "density_ema": self._density_ema}
        if getattr(self, "_gate", None) is not None and self._gate.active:
            st["gate"] = self._gate.snapshot_feed(self._gate_feed)
        return st

    def restore(self, st):
        self.frames_processed = st["frames_processed"]
        self.forwards = st.get("forwards", 0)
        self._density_ema = st.get("density_ema", 0.5)
        if st.get("gate") is not None \
                and getattr(self, "_gate", None) is not None:
            self._gate.restore_feed(self._gate_feed, st["gate"])


# ===========================================================================
# Relational tail: Filter / Window-Aggregate
# ===========================================================================

@dataclasses.dataclass
class FilterOp(Op):
    """Predicate on extracted attrs. Predicates are small s-expr tuples:
      ("eq", "color", "red") | ("prefix", "plate", "MTT")
      | ("and", p1, p2) | ("or", p1, p2) | ("eq", "action", "spike")
    """

    pred: Tuple = ("eq", "present", 1)

    def __post_init__(self):
        self.name = f"filter{self.pred}"

    def _eval(self, pred, attrs, n) -> np.ndarray:
        kind = pred[0]
        if kind in ("and", "or"):
            a = self._eval(pred[1], attrs, n)
            b = self._eval(pred[2], attrs, n)
            return (a & b) if kind == "and" else (a | b)
        if kind == "eq":
            _, field, val = pred
            vocab = {"color": COLORS, "brand": BRANDS, "action": ACTIONS}
            iv = vocab[field].index(val) if isinstance(val, str) else val
            return np.asarray(attrs[field]) == iv
        if kind == "ge":
            _, field, val = pred
            return np.asarray(attrs[field]) >= val
        if kind == "prefix":
            _, field, val = pred
            chars = np.asarray(attrs[field])   # (B, PLATE_LEN)
            want = [PLATE_CHARS.index(c) for c in val]
            ok = np.ones(n, bool)
            for i, w in enumerate(want):
                ok &= chars[:, i] == w
            return ok
        raise ValueError(pred)

    def process(self, batch: Batch) -> Batch:
        n = len(batch["idx"])
        if n == 0:
            return batch
        keep = self._eval(self.pred, batch["attrs"], n)
        return _mask_batch(batch, keep)


@dataclasses.dataclass
class WindowAggOp(Op):
    """Tumbling-window aggregation over extracted attrs.

    kinds: top_color | top_brand | top_brand_color | count_distinct_plates |
           repeated_plates | count_jumping | top_team | top3_actions
    """

    kind: str = "top_color"
    window: int = 128            # frames per tumbling window (by index)

    def __post_init__(self):
        self.name = f"window[{self.kind},{self.window}]"
        self._buf: List[Dict[str, Any]] = []
        self._window_start = 0

    def process(self, batch: Batch) -> Batch:
        n = len(batch["idx"])
        attrs = batch.get("attrs", {})
        for i in range(n):
            rec = {"idx": int(batch["idx"][i])}
            for k, v in attrs.items():
                rec[k] = np.asarray(v[i])
            self._buf.append(rec)
        out_results = []
        # tumble on frame index (event time)
        max_idx = int(batch["idx"][-1]) if n else None
        while max_idx is not None and \
                max_idx >= self._window_start + self.window:
            w_end = self._window_start + self.window
            in_win = [r for r in self._buf if r["idx"] < w_end]
            self._buf = [r for r in self._buf if r["idx"] >= w_end]
            out_results.append(self._aggregate(in_win,
                                               self._window_start, w_end))
            self._window_start = w_end
        batch = dict(batch)
        if out_results:
            batch["window_results"] = batch.get("window_results", []) \
                + out_results
        return batch

    def _aggregate(self, recs, w0, w1) -> Dict[str, Any]:
        from collections import Counter

        res: Dict[str, Any] = {"window": (w0, w1), "kind": self.kind,
                               "n": len(recs)}
        if self.kind in ("top_color", "top_brand", "top_brand_color"):
            if self.kind != "top_brand":
                c = Counter(int(r["color"]) for r in recs if "color" in r)
                res["top_color"] = COLORS[c.most_common(1)[0][0]] if c else None
            if self.kind != "top_color":
                c = Counter(int(r["brand"]) for r in recs if "brand" in r)
                res["top_brand"] = BRANDS[c.most_common(1)[0][0]] if c else None
        elif self.kind == "count_distinct_plates":
            plates = set(tuple(int(x) for x in r["plate"]) for r in recs
                         if "plate" in r)
            res["distinct_plates"] = len(plates)
        elif self.kind == "repeated_plates":
            c = Counter(tuple(int(x) for x in r["plate"]) for r in recs
                        if "plate" in r)
            res["repeated"] = ["".join(PLATE_CHARS[i] for i in p)
                               for p, k in c.items() if k >= 2]
        elif self.kind == "count_jumping":
            res["total_jumping"] = sum(int(r.get("n_jumping", 0))
                                       for r in recs)
        elif self.kind == "top_team":
            # offense proxy: most spike actions => attacking team majority
            c = Counter(int(r["action"]) for r in recs if "action" in r)
            res["spikes"] = c.get(ACTIONS.index("spike"), 0)
        elif self.kind == "top3_actions":
            c = Counter(int(r["action"]) for r in recs if "action" in r)
            res["top3"] = [ACTIONS[a] for a, _ in c.most_common(3)]
        return res

    def reset(self):
        self._buf = []
        self._window_start = 0

    def flush(self) -> Optional[Batch]:
        """Emit the open (partial) tumbling window, marked ``partial``.

        Non-destructive early firing: buffer and window position are kept,
        so a run segmented by snapshot/resume keeps tumbling identically —
        if the stream continues, the window later closes normally and the
        closed result supersedes the partial one (consumers dedup by window
        span, see ``queries.catalog._window_results``)."""
        if not self._buf:
            return None
        w0 = self._window_start
        res = self._aggregate(self._buf, w0, w0 + self.window)
        res["partial"] = True
        return {"frames": np.zeros((0, 1, 1, 1), np.float32),
                "idx": np.zeros((0,), np.int64),
                "window_results": [res]}

    def snapshot(self):
        return {"buf": list(self._buf), "window_start": self._window_start}

    def restore(self, st):
        self._buf = list(st["buf"])
        self._window_start = st["window_start"]


# ===========================================================================
def _mask_batch(batch: Batch, keep: np.ndarray) -> Batch:
    out = dict(batch)
    out["frames"] = batch["frames"][keep]
    out["idx"] = batch["idx"][keep]
    if "attrs" in batch:
        out["attrs"] = {k: np.asarray(v)[keep]
                        for k, v in batch["attrs"].items()}
    return out
