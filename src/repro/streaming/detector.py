"""TinyDet: the cheap object detector the physical optimizer cascades before
the MLLM (the paper's YOLOv8 role, built in-framework).

A 3-conv stride-4 network over downscaled frames -> car-present logit +
coarse occupancy grid (used by the semantic optimizer to locate the region
of interest).  ~50k params => ~1000x cheaper than the stream MLLM.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.param import ParamSpec, materialize


def tinydet_spec(in_ch: int = 3) -> Dict[str, Any]:
    return {
        "conv1": ParamSpec((4, 4, in_ch, 16), (None, None, None, None)),
        "b1": ParamSpec((16,), (None,), "zeros"),
        "conv2": ParamSpec((4, 4, 16, 32), (None, None, None, None)),
        "b2": ParamSpec((32,), (None,), "zeros"),
        "conv3": ParamSpec((3, 3, 32, 32), (None, None, None, None)),
        "b3": ParamSpec((32,), (None,), "zeros"),
        "head_present": ParamSpec((32, 2), (None, None)),
        "head_grid": ParamSpec((32, 1), (None, None)),
    }


class TinyDet:
    def __init__(self, in_ch: int = 3):
        self.in_ch = in_ch

    def init(self, key: jax.Array) -> Dict[str, Any]:
        return materialize(tinydet_spec(self.in_ch), key, jnp.float32)

    def forward(self, params: Dict[str, Any], frames: jax.Array
                ) -> Dict[str, jax.Array]:
        """frames (B, C, h, w) float -> {present (B,2), grid (B, gh, gw)}."""
        x = frames.transpose(0, 2, 3, 1)             # NHWC
        for w_key, b_key, stride in (("conv1", "b1", 4), ("conv2", "b2", 4),
                                     ("conv3", "b3", 1)):
            x = jax.lax.conv_general_dilated(
                x, params[w_key], (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + params[b_key])
        grid = (x @ params["head_grid"])[..., 0]     # (B, gh, gw)
        pooled = x.mean(axis=(1, 2))                 # (B, 32)
        present = pooled @ params["head_present"]    # (B, 2)
        return {"present": present, "grid": grid}

    def loss(self, params: Dict[str, Any], batch: Dict[str, jax.Array]
             ) -> jax.Array:
        out = self.forward(params, batch["frames"])
        logits = out["present"]
        labels = batch["present"]
        lse = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
        return jnp.mean(lse - ll)
