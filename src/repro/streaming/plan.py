"""Plan IR: an ordered operator chain + metadata the optimizer rewrites."""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.streaming.operators import Op, SinkOp, SourceOp


@dataclasses.dataclass
class Plan:
    ops: List[Op]
    query: str = ""
    notes: List[str] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        assert isinstance(self.ops[0], SourceOp), "plan starts with Source"
        assert isinstance(self.ops[-1], SinkOp), "plan ends with Sink"

    # -- rewriting helpers ---------------------------------------------------
    def clone(self) -> "Plan":
        return Plan([copy.deepcopy(o) for o in self.ops], self.query,
                    list(self.notes))

    def index_of(self, cls) -> Optional[int]:
        for i, op in enumerate(self.ops):
            if isinstance(op, cls):
                return i
        return None

    def insert_before(self, cls, op: Op, note: str = "") -> "Plan":
        i = self.index_of(cls)
        assert i is not None, f"no {cls.__name__} in plan"
        self.ops.insert(i, op)
        if note:
            self.notes.append(note)
        return self

    def insert_after_source(self, op: Op, note: str = "") -> "Plan":
        self.ops.insert(1, op)
        if note:
            self.notes.append(note)
        return self

    def remove(self, op: Op) -> "Plan":
        self.ops.remove(op)
        return self

    # -- shared-execution helpers --------------------------------------------
    def split_at(self, i: int) -> Tuple[List[Op], List[Op]]:
        """Split the chain into (prefix ops[:i], suffix ops[i:])."""
        assert 0 <= i <= len(self.ops)
        return list(self.ops[:i]), list(self.ops[i:])

    def common_prefix(self, other: "Plan") -> int:
        """Length of the longest structurally-identical leading op chain
        shared with ``other`` (never absorbs a Sink — the tail stays
        per-query even for identical plans)."""
        n = 0
        for a, b in zip(self.ops, other.ops):
            if isinstance(a, SinkOp) or isinstance(b, SinkOp):
                break
            if a.signature() != b.signature():
                break
            n += 1
        return n

    def describe(self) -> str:
        return " -> ".join(op.name for op in self.ops)
