"""FusedPrefixOp: a plan's surviving-frame prefix as one device pass.

The streaming prefix of an optimized plan — Skip's frame diff, cheap
color filters, crop/downscale/greyscale/normalize, the TinyDet cascade,
and the semantic gate's ``TemporalSignature`` — normally executes as 3–5
separate jitted calls per micro-batch, each paying dispatch overhead and
a host round trip.  ``FusedPrefixOp`` wraps that whole segment in one
descriptor whose ``process`` makes a **single** compiled call:
``kernels/fused_prefix`` (Pallas on TPU, inlined pure-jnp composite on
CPU) produces every per-row statistic plus the transformed frames and
the gate signature, and the host then replays the stage *decisions*
(mask composition and Skip's stateful loop) exactly as the unfused ops
would.

Bitwise-identity contract: filters never transform frames, so their
per-row statistics computed on the full batch equal the unfused values
computed on compacted survivor batches (the per-row determinism the
serving tier already relies on for coalesced-vs-solo equality), and
transforms are applied to all rows in chain order.  The physical phase
(``core/physical.py``) decides fused-vs-unfused per plan from
``CostCatalog`` calibration; this op never self-selects.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tollbooth import COLOR_RGB
from repro.kernels.fused_prefix.kernel import out_frame_shape
from repro.kernels.fused_prefix.ops import fused_prefix
from repro.streaming.operators import (
    Batch,
    CheapColorFilterOp,
    CropOp,
    DetectOp,
    FusedPreprocessOp,
    Op,
    OpContext,
    SkipOp,
    _mask_batch,
)

#: operator classes the fused pass can absorb.  Downscale/Greyscale are
#: deliberately absent: their host-numpy math is not guaranteed to match
#: a jnp replica bit for bit, and the logical optimizer already folds
#: them into ``FusedPreprocessOp`` (rule R3) in every optimized plan.
FUSABLE = (SkipOp, CheapColorFilterOp, CropOp, FusedPreprocessOp,
           DetectOp)


def fusable_segment(ops: List[Op]) -> bool:
    """True when ``ops`` is a chain the fused pass can execute: only
    FUSABLE classes, any Skip first (its diff reads the raw input), any
    Detect last (it scores the fully-transformed frames)."""
    if not ops or not all(isinstance(o, FUSABLE) for o in ops):
        return False
    if any(isinstance(o, SkipOp) for o in ops[1:]):
        return False
    if any(isinstance(o, DetectOp) for o in ops[:-1]):
        return False
    return sum(isinstance(o, SkipOp) for o in ops) <= 1 \
        and sum(isinstance(o, DetectOp) for o in ops) <= 1


@dataclasses.dataclass
class FusedPrefixOp(Op):
    """One-device-pass execution of a fusable prefix segment.

    ``stage_ops`` are the original descriptors in plan order — they stay
    the single source of truth for every threshold, region, and Skip's
    runtime state (``keep_from_diff`` advances the member SkipOp
    itself, so a fused plan snapshots/restores like the unfused one).
    ``sig=True`` additionally emits the semantic-gate signature for the
    surviving rows as ``batch["_sig"]``, consumed by the extract
    immediately downstream."""

    stage_ops: Tuple[Op, ...] = ()
    sig: bool = True

    def __post_init__(self):
        assert fusable_segment(list(self.stage_ops)), \
            f"not a fusable segment: {[o.name for o in self.stage_ops]}"
        self.name = "fused_prefix[" + \
            "+".join(o.name for o in self.stage_ops) + "]"
        self._fns: Dict[Tuple, Any] = {}
        #: per-stage (name, rows_in, rows_out) of the last processed
        #: batch — the runtimes' per-stage attribution gauges
        self.last_stage_counts: List[Tuple[str, int, int]] = []

    # ------------------------------------------------------------------
    def signature(self) -> Tuple:
        # the default dataclass signature would embed unhashable Op
        # instances; flatten to nested primitive tuples so share_key
        # grouping and planner dicts keep working
        return ("FusedPrefixOp",
                tuple(o.signature() for o in self.stage_ops),
                ("sig", self.sig))

    def unfuse(self) -> List[Op]:
        """Fresh, stateless copies of the member descriptors — the
        unfused chain this op replaces (fleet canonicalization joins
        prefixes at this granularity)."""
        out = []
        for o in self.stage_ops:
            kw = {f.name: getattr(o, f.name)
                  for f in dataclasses.fields(o) if f.init}
            out.append(type(o)(**kw))
        return out

    # ------------------------------------------------------------------
    def open(self, ctx: OpContext) -> None:
        self._skip: Optional[SkipOp] = None
        self._detect: Optional[DetectOp] = None
        pix: List[Tuple] = []
        for o in self.stage_ops:
            if isinstance(o, SkipOp):
                self._skip = o
                pix.append(("diff", o.regions))
            elif isinstance(o, CheapColorFilterOp):
                pix.append(("color", tuple(COLOR_RGB[o.color]), o.roi))
            elif isinstance(o, CropOp):
                pix.append(("crop", o.region))
            elif isinstance(o, FusedPreprocessOp):
                pix.append(("preprocess", o.crop, o.factor, o.grey))
            else:
                self._detect = o
        self._pix_spec = tuple(pix)
        self._normalizes = any(isinstance(o, FusedPreprocessOp)
                               for o in self.stage_ops)
        self._det_model = ctx.detector
        self._det_params = ctx.detector_params
        self._fns = {}

    def _fn(self, shape: Tuple[int, ...], dtype_str: str):
        key = tuple(shape) + (dtype_str,)
        if key in self._fns:
            return self._fns[key]
        spec = self._pix_spec
        proj = None
        if self.sig:
            # the gate's layout for the *final* frame shape — shared
            # source of truth, so fused and unfused signatures agree
            from repro.semantic.signature import signature_layout

            out_shape = out_frame_shape(spec, tuple(shape))
            gy, gx, _, proj_np = signature_layout(out_shape)
            spec = spec + (("signature", (gy, gx)),)
            proj = jnp.asarray(proj_np)
        det, params = self._det_model, self._det_params
        run_det = self._detect is not None

        @jax.jit
        def run(frames, prevs):
            # nested jit inlines: the pixel stages, the detect forward,
            # and the signature matmul compile to ONE XLA program — one
            # dispatch per micro-batch however long the chain is
            d, fracs, x, feats, emb = fused_prefix(frames, prevs, proj,
                                                   spec=spec)
            p = None
            if run_det:
                xx = x.astype(jnp.float32)
                # DetectOp's jitted body, verbatim (per-frame raw detect)
                raw = xx.reshape(xx.shape[0], -1).max(axis=1) > 8.0
                xx = jnp.where(raw[:, None, None, None],
                               xx / 255.0 - 0.5, xx)
                out = det.forward(params, xx)
                p = jax.nn.softmax(out["present"], -1)[:, 1]
            return d, fracs, x, p, feats, emb

        self._fns[key] = run
        return run

    # ------------------------------------------------------------------
    def process(self, batch: Batch) -> Batch:
        frames = batch["frames"]
        n = frames.shape[0]
        if n == 0:
            return batch
        prevs = self._skip.prev_frames(frames) \
            if self._skip is not None else None
        run = self._fn(frames.shape[1:], frames.dtype.str)
        d, fracs, x, p, feats, emb = run(
            jnp.asarray(frames),
            jnp.asarray(prevs) if prevs is not None else None)

        # host side: replay each stage's *decision* in chain order —
        # Skip's stateful loop advances the member op itself
        keep = np.ones(n, bool)
        self.last_stage_counts = []
        ci = 0
        for o in self.stage_ops:
            rows_in = int(keep.sum())
            if isinstance(o, SkipOp):
                keep &= o.keep_from_diff(frames, np.asarray(d))
            elif isinstance(o, CheapColorFilterOp):
                keep &= np.asarray(fracs[ci]) >= o.min_frac
                ci += 1
            elif isinstance(o, DetectOp):
                keep &= np.asarray(p) >= o.threshold
            self.last_stage_counts.append(
                (o.name, rows_in, int(keep.sum())))

        batch = dict(batch)
        batch["frames"] = np.asarray(x)
        if self._normalizes:
            batch["normalized"] = True
        batch = _mask_batch(batch, keep)
        if self.sig:
            batch["_sig"] = (np.asarray(feats)[keep],
                             np.asarray(emb)[keep])
        return batch

    # ------------------------------------------------------------------
    def reset(self) -> None:
        for o in self.stage_ops:
            o.reset()
        self.last_stage_counts = []

    def snapshot(self) -> Dict[str, Any]:
        return {"stages": [o.snapshot() for o in self.stage_ops]}

    def restore(self, st: Dict[str, Any]) -> None:
        for o, s in zip(self.stage_ops, st["stages"]):
            o.restore(s)
