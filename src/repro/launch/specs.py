"""ShapeDtypeStruct input specs + shardings for every (arch × shape) cell.

No device allocation ever happens here — everything is abstract (the same
pattern the dry-run brief describes).  ``cell_spec`` returns:

  step_kind      "train" | "prefill" | "decode"
  args           tuple of abstract args for the step function
  in_shardings   matching tree of NamedShardings
  out_shardings  None (inferred) — constraints inside the model pin layouts
  rules          logical-rule overrides active for this cell
  donate         indices of donated args
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.common.config import ArchConfig, ShapeCell, SHAPE_CELLS
from repro.common.sharding import (
    logical_to_mesh,
    named_sharding,
    param_sharding_tree,
    rules_scope,
)
from repro.models import LM, abstract, axes_tree
from repro.models.model import is_shape_leaf
from repro.training.optimizer import OptimizerConfig, adamw_init

# multimodal stub sizes
N_PATCHES = 1024        # pixtral patch embeddings per sample
T_SRC_CAP = 4096        # seamless encoder frames cap


@dataclasses.dataclass
class CellSpec:
    arch: str
    cell: str
    step_kind: str
    args: Tuple
    in_shardings: Tuple
    rules: Dict[str, Any]
    donate: Tuple[int, ...]
    param_bytes: int
    notes: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_inputs(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
                 dtype=jnp.bfloat16) -> Tuple[Dict, Dict]:
    """Token/extra inputs for a full-sequence step (train or prefill)."""
    b, s = cell.global_batch, cell.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    shard = {"tokens": named_sharding(("batch", "seq"), mesh)}
    if cell.kind == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
        shard["labels"] = named_sharding(("batch", "seq"), mesh)
    if cfg.frontend == "patch":
        batch["patch_embeds"] = _sds((b, N_PATCHES, cfg.d_model), dtype)
        batch["patch_pos"] = _sds((b, N_PATCHES), jnp.int32)
        shard["patch_embeds"] = named_sharding(("batch", None, "embed"), mesh)
        shard["patch_pos"] = named_sharding(("batch", None), mesh)
    if cfg.frontend == "audio":
        t_src = min(s, T_SRC_CAP)
        batch["frames"] = _sds((b, t_src, cfg.d_model), dtype)
        shard["frames"] = named_sharding(("batch", None, "embed"), mesh)
    return batch, shard


def cache_abstract(lm: LM, batch: int, s_max: int, mesh: Mesh,
                   t_src: int = 0, dtype=jnp.bfloat16):
    shapes = lm.cache_shapes(batch, s_max, t_src)

    def mk(leaf):
        shape, axes = leaf
        return _sds(shape, dtype)

    def mk_shard(leaf):
        shape, axes = leaf
        return named_sharding(axes, mesh)

    cache = jax.tree_util.tree_map(mk, shapes, is_leaf=is_shape_leaf)
    shard = jax.tree_util.tree_map(mk_shard, shapes, is_leaf=is_shape_leaf)
    return cache, shard


def quantized_opt(cfg: ArchConfig) -> bool:
    """int8 Adam moments for archs whose fp32 state wouldn't fit one pod."""
    return cfg.n_params_dense_equiv() > 3e10


def cell_rules(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Logical-rule overrides for a cell."""
    rules: Dict[str, Any] = {}
    if cell.is_decode and cell.global_batch == 1:
        # long-context decode: batch unshardable; shard the KV sequence
        # (sequence parallelism over "data")
        rules.update({"batch": None, "kv_seq": ("data",)})
    if cell.is_decode and os.environ.get("REPRO_DECODE_2DTP") == "1" \
            and cfg.d_ff % 256 == 0:
        # §Perf: weight-stationary decode — dense weights fully sharded
        # over BOTH mesh axes (d_ff 2D-TP); no ZeRO gathers per token, the
        # FFN output psum is O(d_model) per token.  Experts keep their
        # expert_fsdp rows (handled by the MoE partial-sum path).
        rules.update({"fsdp": None, "mlp": ("model", "data")})
    if cell.kind == "train" and os.environ.get("REPRO_FSDP_ONLY") == "1" \
            and not cfg.has_moe:
        # §Perf: small dense archs don't want TP at all — batch shards over
        # every axis (1 seq/chip), weights ZeRO-3 over both axes; the TP
        # activation all-reduces disappear and the only collectives left
        # are the (tiny per-partition) weight gathers + grad scatters.
        rules.update({
            "batch": ("pod", "data", "model"),
            "fsdp": ("data", "model"),
            "mlp": None, "heads": None, "kv_heads": None, "vocab": None,
            "ssm_heads": None,
        })
    return rules


def cell_spec(cfg: ArchConfig, cell_name: str, mesh: Mesh,
              opt_cfg: Optional[OptimizerConfig] = None,
              batch_override: Optional[int] = None) -> CellSpec:
    cell = SHAPE_CELLS[cell_name]
    if batch_override is not None:
        cell = ShapeCell(cell.name, cell.seq_len, batch_override, cell.kind)
    tp = mesh.shape["model"]
    if cell.kind == "train" and os.environ.get("REPRO_FSDP_ONLY") == "1" \
            and not cfg.has_moe:
        tp = 1  # no TP: no head padding/replication needed
    lm = LM(cfg, tp=tp)
    spec = lm.spec()
    rules = cell_rules(cfg, cell)

    with rules_scope(**rules):
        p_axes = axes_tree(spec)
        if cell.kind == "train":
            params = abstract(spec, jnp.float32)
            p_shard = param_sharding_tree(p_axes, mesh)
            opt_cfg = opt_cfg or OptimizerConfig(
                quantized_state=quantized_opt(cfg))
            opt_state = jax.eval_shape(
                lambda p: adamw_init(p, opt_cfg), params)
            opt_shard = _opt_sharding(opt_state, p_shard, mesh)
            batch, b_shard = batch_inputs(cfg, cell, mesh)
            args = (params, opt_state, batch)
            shardings = (p_shard, opt_shard, b_shard)
            donate = (0, 1)
            pb = _tree_bytes(params) + _tree_bytes(opt_state)
        elif cell.kind == "prefill":
            params = abstract(spec, jnp.bfloat16)
            p_shard = param_sharding_tree(p_axes, mesh)
            batch, b_shard = batch_inputs(cfg, cell, mesh)
            t_src = min(cell.seq_len, T_SRC_CAP) if cfg.encoder_decoder else 0
            cache, c_shard = cache_abstract(lm, cell.global_batch,
                                            cell.seq_len, mesh, t_src)
            args = (params, batch, cache)
            shardings = (p_shard, b_shard, c_shard)
            donate = (2,)
            pb = _tree_bytes(params)
        else:  # decode
            params = abstract(spec, jnp.bfloat16)
            p_shard = param_sharding_tree(p_axes, mesh)
            tokens = _sds((cell.global_batch, 1), jnp.int32)
            tok_shard = named_sharding(("batch", None), mesh)
            t_src = T_SRC_CAP if cfg.encoder_decoder else 0
            cache, c_shard = cache_abstract(lm, cell.global_batch,
                                            cell.seq_len, mesh, t_src)
            cur = _sds((), jnp.int32)
            cur_shard = NamedSharding(mesh, logical_to_mesh((), mesh))
            args = (params, tokens, cache, cur)
            shardings = (p_shard, tok_shard, c_shard, cur_shard)
            donate = (2,)
            pb = _tree_bytes(params)

    return CellSpec(arch=cfg.name, cell=cell_name, step_kind=cell.kind,
                    args=args, in_shardings=shardings, rules=rules,
                    donate=donate, param_bytes=pb)


def _opt_sharding(opt_state, p_shard, mesh):
    """Moments shard like their params; scale rows drop the last axis."""
    rep = NamedSharding(mesh, logical_to_mesh((), mesh))

    def moment_shard(psh, mom):
        out = {}
        for k, v in mom.items():
            if k in ("m", "v", "m_q", "v_q"):
                out[k] = psh
            else:  # m_s / v_s: param shape with last dim 1
                spec = psh.spec
                out[k] = NamedSharding(mesh, type(spec)(
                    *(list(spec[:v.ndim - 1]) + [None]))) \
                    if len(spec) >= v.ndim else psh
        return out

    moments = jax.tree_util.tree_map(
        moment_shard, p_shard, opt_state["moments"],
        is_leaf=lambda x: isinstance(x, NamedSharding))
    return {"moments": moments, "step": rep}


def _tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
