import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); they give this process 512 placeholder host devices so
``make_production_mesh`` can build the real 16×16 and 2×16×16 meshes.

For every applicable cell this script:
  1. builds abstract inputs + shardings (launch/specs.py — no allocation),
  2. jit-lowers the train/prefill/decode step under the production mesh,
  3. compiles, prints memory_analysis() (proves it fits) and
     cost_analysis() (FLOPs/bytes for the roofline),
  4. parses the post-SPMD HLO for collective bytes,
  5. writes reports/dryrun/<arch>__<cell>__<mesh>.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--quick]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.config import SHAPE_CELLS, applicable_cells
from repro.common.sharding import mesh_scope, rules_scope
from repro.configs import ASSIGNED, get_config
from repro.launch.mesh import chips, make_production_mesh
from repro.launch.specs import cell_spec, quantized_opt
from repro.models import LM
from repro.training.optimizer import OptimizerConfig, adamw_update
from repro.training.trainer import make_train_step

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective in the post-SPMD HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        shapes = SHAPE_RE.findall(line)
        if not shapes:
            continue
        # first TYPE[dims] is the output; operands follow when printed.
        # convention (documented in EXPERIMENTS.md): use operand shapes when
        # present, else the output shape.
        use = shapes[1:] if len(shapes) > 1 else shapes[:1]
        nbytes = sum(_shape_bytes(t, d) for t, d in use)
        out[kind] = out.get(kind, 0.0) + float(nbytes)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def build_step(cfg, cell_name: str, mesh, batch_override=None):
    """Returns (step_fn, spec) for the cell."""
    cell = SHAPE_CELLS[cell_name]
    if cell.kind == "train" and os.environ.get("REPRO_FSDP_ONLY") == "1" \
            and not cfg.has_moe:
        # 1 seq/chip needs the full global batch in one microbatch
        cfg = cfg.replace(grad_accum=1)
        tp = 1  # no TP: no head padding
    else:
        tp = mesh.shape["model"]
    lm = LM(cfg, tp=tp)
    spec = cell_spec(cfg, cell_name, mesh, batch_override=batch_override)

    if cell.kind == "train":
        opt_cfg = OptimizerConfig(quantized_state=quantized_opt(cfg))
        step = make_train_step(
            lambda p, b: lm.loss(p, b, jnp.bfloat16), opt_cfg,
            grad_accum=cfg.grad_accum, donate=False, jit=False)
    elif cell.kind == "prefill":
        def step(params, batch, cache):
            return lm.prefill(params, batch, cache, dtype=jnp.bfloat16)
    else:
        def step(params, tokens, cache, cur_len):
            return lm.decode(params, tokens, cache, cur_len,
                             dtype=jnp.bfloat16)
    return step, spec


def _analyze(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
        "n_collectives": {
            k: hlo.count(k + "(") + hlo.count(k + "-start(")
            for k in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute")},
    }


def _compile_cell(cfg, cell_name, mesh, unroll: bool, batch_override=None):
    """Lower + compile one cell; optionally fully unrolled scans."""
    prev = os.environ.get("REPRO_UNROLL_SCANS")
    if unroll:
        os.environ["REPRO_UNROLL_SCANS"] = "1"
    try:
        with mesh_scope(mesh):
            step, spec = build_step(cfg, cell_name, mesh,
                                    batch_override=batch_override)
            with rules_scope(**spec.rules):
                jitted = jax.jit(step, in_shardings=spec.in_shardings,
                                 donate_argnums=spec.donate)
                lowered = jitted.lower(*spec.args)
                compiled = lowered.compile()
        return compiled, spec
    finally:
        if unroll:
            if prev is None:
                os.environ.pop("REPRO_UNROLL_SCANS", None)
            else:
                os.environ["REPRO_UNROLL_SCANS"] = prev


def run_cell(arch: str, cell_name: str, multi_pod: bool = False,
             verbose: bool = True, save: bool = True,
             costs: bool = True, tag: str = "") -> Dict[str, Any]:
    """Compile the full scanned step (memory + sharding proof) and, on the
    single-pod mesh, two reduced unrolled variants (1 and 2 periods) whose
    difference gives the *exact* per-period FLOP/byte/collective counts —
    XLA's cost_analysis counts while bodies once, so the scanned module
    alone undercounts by the trip counts (verified; see EXPERIMENTS.md).
        total = overhead + n_periods · (f₂ − f₁)   with overhead = f₁ − (f₂ − f₁)
    """
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: Dict[str, Any] = {
        "arch": arch, "cell": cell_name, "mesh": mesh_name,
        "chips": chips(mesh), "status": "ok",
    }
    t0 = time.perf_counter()
    try:
        compiled, spec = _compile_cell(cfg, cell_name, mesh, unroll=False)
        t_full = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        full = _analyze(compiled)

        result.update({
            "compile_s": round(t_full, 1),
            "param_bytes_global": spec.param_bytes,
            "memory_analysis": {
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes",
                                               None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes",
                                             None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "scanned_module": full,
        })

        if costs and not multi_pod:
            # Period decomposition on reduced configs (exact for scan
            # stacks).  For train cells the reduced compiles run ONE
            # microbatch (grad_accum=1, batch/accum) — fwd/bwd FLOPs and
            # collectives scale exactly linearly in the microbatch count;
            # the once-per-step optimizer update (~30 FLOPs/param, <0.1% of
            # any cell) is accordingly over-counted accum× — noted in
            # EXPERIMENTS.md.
            plen = len(cfg.block_pattern)
            accum = cfg.grad_accum if SHAPE_CELLS[cell_name].kind == "train" \
                else 1
            if os.environ.get("REPRO_FSDP_ONLY") == "1" and not cfg.has_moe:
                accum = 1  # FSDP-only mode runs one full-batch microbatch
            b_over = (SHAPE_CELLS[cell_name].global_batch // accum
                      if accum > 1 else None)
            enc1, enc2 = {}, {}
            if cfg.encoder_decoder:
                enc1 = {"n_encoder_layers": plen}
                enc2 = {"n_encoder_layers": 2 * plen}
            cfg1 = cfg.replace(n_layers=plen, grad_accum=1, **enc1)
            cfg2 = cfg.replace(n_layers=2 * plen, grad_accum=1, **enc2)
            c1, _ = _compile_cell(cfg1, cell_name, mesh, unroll=True,
                                  batch_override=b_over)
            a1 = _analyze(c1)
            c2, _ = _compile_cell(cfg2, cell_name, mesh, unroll=True,
                                  batch_override=b_over)
            a2 = _analyze(c2)
            n_p = cfg.n_periods

            def extrap(k1, k2):
                core = k2 - k1
                return (k1 + (n_p - 1) * core) * accum

            coll_tot = extrap(a1["coll"]["total"], a2["coll"]["total"])
            per_coll = {
                k: extrap(a1["coll"].get(k, 0.0), a2["coll"].get(k, 0.0))
                for k in set(a1["coll"]) | set(a2["coll"]) if k != "total"}
            result.update({
                "flops_per_partition": extrap(a1["flops"], a2["flops"]),
                "bytes_accessed_per_partition": extrap(a1["bytes"],
                                                       a2["bytes"]),
                "collective_bytes_per_partition": {
                    **per_coll, "total": coll_tot},
                "decomposition": {"period_flops": a2["flops"] - a1["flops"],
                                  "one_period": a1, "two_period": a2,
                                  "n_periods": n_p, "accum_scale": accum},
            })
        if verbose:
            ma = result["memory_analysis"]
            arg_gb = (ma["argument_size_bytes"] or 0) / 2**30
            tmp_gb = (ma["temp_size_bytes"] or 0) / 2**30
            fl = result.get("flops_per_partition", full["flops"])
            cl = result.get("collective_bytes_per_partition",
                            full["coll"])["total"]
            print(f"[OK] {arch:24s} {cell_name:12s} {mesh_name:10s} "
                  f"args/dev={arg_gb:7.2f}GiB temp/dev={tmp_gb:7.2f}GiB "
                  f"flops/part={fl:.3e} coll/part={cl/2**30:.3f}GiB "
                  f"compile={t_full:.0f}s total={time.perf_counter()-t0:.0f}s",
                  flush=True)
    except Exception as e:  # noqa: BLE001 - report and continue
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} {cell_name} {mesh_name}: "
                  f"{result['error'][:200]}", flush=True)
    if save:
        out_dir = REPORT_DIR if not tag else REPORT_DIR + "_" + tag
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch}__{cell_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tag", default="",
                    help="save reports under reports/dryrun_<tag>/ "
                         "(perf-iteration A/B runs)")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    if args.all:
        for arch in ASSIGNED:
            cfg = get_config(arch)
            for cell in applicable_cells(cfg):
                for mp in meshes:
                    r = run_cell(arch, cell, multi_pod=mp, tag=args.tag)
                    failures += r["status"] != "ok"
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        for mp in meshes:
            r = run_cell(args.arch, args.shape, multi_pod=mp, tag=args.tag)
            failures += r["status"] != "ok"
    print(f"dry-run complete; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
