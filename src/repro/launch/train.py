"""Training launcher: end-to-end driver for any registry arch.

Runs a real (CPU-scale, reduced-config by default) training job with the
full production substrate: sharded params on a mesh, microbatched train
step, int8-Adam option, atomic checkpoints, preemption handling, elastic
restore, straggler logging.

  PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --smoke \
      --steps 200 --batch 16 --seq 64 --ckpt-dir /tmp/ckpt

``--resume`` restores the latest checkpoint (possibly on a different mesh —
elastic restore is exercised by tests/test_training.py).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.common.sharding import mesh_scope, param_sharding_tree
from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_test_mesh
from repro.models import LM, materialize
from repro.models.param import axes_tree
from repro.training import (
    CheckpointManager,
    OptimizerConfig,
    TokenStream,
    TrainConfig,
    Trainer,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_test_mesh(args.data_axis, args.model_axis)
    lm = LM(cfg, tp=args.model_axis)

    with mesh_scope(mesh):
        spec = lm.spec()
        params = materialize(spec, jax.random.PRNGKey(0), jnp.float32)
        shardings = param_sharding_tree(axes_tree(spec), mesh)
        params = jax.device_put(params, shardings)

        data = TokenStream(cfg.vocab_size, args.batch, args.seq)
        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        trainer = Trainer(
            lambda p, b: lm.loss(p, b, jnp.float32), params,
            OptimizerConfig(lr=args.lr, warmup_steps=20,
                            total_steps=args.steps,
                            quantized_state=args.int8_opt),
            TrainConfig(steps=args.steps, grad_accum=args.grad_accum,
                        ckpt_every=max(args.steps // 4, 10)),
            data, ckpt, param_shardings=shardings)
        trainer.install_signal_handlers()
        if args.resume and trainer.restore():
            print(f"resumed from step {trainer.step}")
        out = trainer.train()
        print(f"done: step={out['step']} final_loss={out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
