"""Roofline analysis over the dry-run reports.

For each (arch × shape) cell on the single-pod mesh, computes the three
roofline terms from the compiled-artifact measurements (launch/dryrun.py):

  compute_s    = global_FLOPs      / (chips · 197e12  bf16 FLOP/s)
  memory_s     = global_HBM_bytes  / (chips · 819e9   B/s)
  collective_s = global_coll_bytes / (chips · 50e9    B/s ICI per link)

All per-partition numbers from cost_analysis / the HLO parser are multiplied
by `chips` to get globals (verified per-partition semantics; equivalently
term = per_partition / per_chip_peak).  MODEL_FLOPS uses the standard
6·N_active·D (train) / 2·N_active·D (prefill/decode) estimator, so
MODEL_FLOPS / HLO_FLOPs exposes remat + masking + padding waste.

Usage: python -m repro.launch.roofline [--json out.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

from repro.common.config import SHAPE_CELLS
from repro.configs import ASSIGNED, get_config

PEAK_FLOPS = 197e12          # bf16 per chip (v5e-class)
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def model_flops(arch: str, cell_name: str) -> float:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[cell_name]
    n_active = cfg.n_params_active()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def memory_floor_bytes(arch: str, cell_name: str) -> float:
    """Analytic minimum HBM traffic (global bytes) for one step.

    cost_analysis' "bytes accessed" counts every unfused HLO operand — an
    upper bound that a fused TPU program never pays.  The floor is what a
    perfectly-fused program must still move:
      train:   params (fp32 r+w) + moments r+w + grads (bf16 w+r) +
               layer-boundary activations per microbatch (save+read)
      prefill: params (bf16) + KV cache write + activations once
      decode:  params (bf16) + full KV-cache read + O(1) writes
    """
    from repro.launch.specs import quantized_opt
    from repro.models import LM
    from repro.models.model import param_count_estimate

    cfg = get_config(arch)
    cell = SHAPE_CELLS[cell_name]
    n = param_count_estimate(cfg)
    d = cfg.d_model
    if cell.kind == "train":
        mstate = 2.0 if quantized_opt(cfg) else 8.0
        pbytes = n * (4 + 4 + mstate * 2 + 2 + 2)  # p r/w, m+v r/w, g w+r
        mb = cell.global_batch // cfg.grad_accum
        act = (mb * cell.seq_len * d * 2) * cfg.n_layers * 2 * cfg.grad_accum
        return pbytes + act
    # serving cells: bf16 params
    pbytes = 2 * n
    if not cfg.has_attention:
        kv = 0.0
    else:
        from repro.models.attention import head_layout

        _, hkv_e, _ = head_layout(cfg.attention, 16)
        n_attn = sum(1 for k in cfg.block_pattern
                     if k.split("+")[0].startswith("attn")) * cfg.n_periods
        kv = (cell.global_batch * cell.seq_len * hkv_e
              * cfg.attention.head_dim * 2 * 2) * n_attn
    if cell.kind == "prefill":
        act = cell.global_batch * cell.seq_len * d * 2 * cfg.n_layers
        return pbytes + kv + act
    return pbytes + kv  # decode reads the cache once


def analyze_report(rep: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rep.get("status") != "ok" or "flops_per_partition" not in rep:
        return None
    chips = rep["chips"]
    g_flops = rep["flops_per_partition"] * chips
    g_bytes_upper = rep["bytes_accessed_per_partition"] * chips
    g_coll = rep["collective_bytes_per_partition"]["total"] * chips
    g_bytes_floor = memory_floor_bytes(rep["arch"], rep["cell"])

    compute_s = g_flops / (chips * PEAK_FLOPS)
    memory_up_s = g_bytes_upper / (chips * HBM_BW)
    memory_s = g_bytes_floor / (chips * HBM_BW)
    coll_s = g_coll / (chips * LINK_BW)
    # dominance uses the *fused-program* memory floor; the unfused upper
    # bound is reported alongside (see EXPERIMENTS.md conventions)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rep["arch"], rep["cell"])
    bound_s = max(terms.values())
    ideal_s = mf / (chips * PEAK_FLOPS)
    return {
        "arch": rep["arch"], "cell": rep["cell"], "mesh": rep["mesh"],
        "chips": chips,
        "global_flops": g_flops,
        "global_bytes_floor": g_bytes_floor,
        "global_bytes_unfused_upper": g_bytes_upper,
        "global_collective_bytes": g_coll,
        **{k: round(v, 6) for k, v in terms.items()},
        "memory_unfused_upper_s": round(memory_up_s, 6),
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_flops_ratio": round(mf / g_flops, 4) if g_flops else None,
        "roofline_fraction": round(ideal_s / bound_s, 4) if bound_s else None,
        "collective_breakdown": {
            k: v * chips for k, v in
            rep["collective_bytes_per_partition"].items() if k != "total"},
        "hbm_per_chip_gib": round(
            (rep["memory_analysis"]["argument_size_bytes"] or 0) / 2**30
            + (rep["memory_analysis"]["temp_size_bytes"] or 0) / 2**30, 2),
    }


def load_all(report_dir: str = REPORT_DIR) -> List[Dict[str, Any]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        row = analyze_report(rep)
        if row:
            rows.append(row)
    return rows


def what_would_help(row: Dict[str, Any]) -> str:
    d = row["dominant"]
    if d == "collective":
        top = max(row["collective_breakdown"],
                  key=row["collective_breakdown"].get)
        return (f"dominant collective is {top}: restructure sharding/schedule"
                " (gather weights once per step, bf16 gathers, one-hot CE)")
    if d == "compute":
        ratio = row["useful_flops_ratio"] or 0
        if ratio < 0.6:
            return ("compute-bound with low useful-FLOP ratio: cut remat "
                    "recompute + masked-attention waste (flash/ring)")
        return "compute-bound near roofline: increase arithmetic intensity"
    return "memory-bound: fuse elementwise chains, widen tiles, bf16/int8"


def table(rows: List[Dict[str, Any]]) -> str:
    hdr = (f"{'arch':24s} {'cell':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':>10s} {'useful':>7s} {'roofline':>8s} "
           f"{'HBM/chip':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['cell']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} "
            f"{(r['useful_flops_ratio'] or 0):7.3f} "
            f"{(r['roofline_fraction'] or 0):8.3f} "
            f"{r['hbm_per_chip_gib']:8.2f}G")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = load_all()
    print(table(rows))
    print()
    for r in rows:
        print(f"{r['arch']:24s} {r['cell']:12s} -> {what_would_help(r)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
