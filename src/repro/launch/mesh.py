"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: (16, 16) = ("data", "model"); multi-pod:
(2, 16, 16) = ("pod", "data", "model") — the pod axis maps to the DCN
(inter-pod) network, data/model to ICI.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over available devices (tests / examples)."""
    n = data * model
    devs = np.asarray(jax.devices()[:n]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
