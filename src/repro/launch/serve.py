"""Serving launcher: continuous-batching engine demo for any registry arch.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import LM, materialize
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=256)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    lm = LM(cfg, tp=1)
    params = materialize(lm.spec(), jax.random.PRNGKey(0), jnp.float32)
    engine = ServingEngine(cfg, params, max_slots=args.slots,
                           s_max=args.s_max, eos_id=-1)
    rs = np.random.RandomState(0)
    reqs = [Request(uid=i,
                    prompt=list(rs.randint(2, cfg.vocab_size,
                                           rs.randint(4, 24))),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s); stats={engine.stats}")
    for r in done[:4]:
        print(f"  req{r.uid}: prompt[:6]={r.prompt[:6]} out={r.output}")


if __name__ == "__main__":
    main()
