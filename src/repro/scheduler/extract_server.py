"""Shared MLLM extract server: one model, many feeds — pipelined.

Every ``MLLMExtractOp`` used to own a private jitted program, so K feeds
(and, before multi-query sharing, N queries) each paid their own forward
and their own compilation.  The server inverts the ownership: it holds one
jitted union-task extract program per *physical backbone variant*
(big / small / pruned — the same resolution ``MLLMExtractOp.open`` does,
with "adaptive" resolved by the op's density tracker before submission),
and coalesces extract requests from different streams into batched
forwards.

Coalescing is shape-bucketed and padded: requests whose frames agree on
(C, H, W) — same preprocessing stage — concatenate into one batch, padded
to a power-of-two bucket (the ``serving.engine`` ``_bucket`` idiom) so the
number of distinct compiled shapes stays logarithmic in batch size.
Requests with different frame shapes (a cropped tollbooth feed next to a
full-frame volleyball feed) land in different buckets but still share the
compiled program cache across feeds.

Because ``make_extract_fn`` normalizes per frame and every head is
computed in one forward, each row of a coalesced batch is bitwise
identical to what the op's solo path would have produced — the server
changes *how many* forwards run, never *what* any query observes.

Pipelined serving protocol (dispatch / poll / resume)
-----------------------------------------------------
``submit()`` queues a request.  ``dispatch(budget)`` assembles
shape-bucketed chunks into *reused pre-allocated staging buffers* (no
per-chunk allocation + zero-fill), launches the jitted forwards, and
returns immediately: JAX async dispatch runs the device work in the
background while the caller keeps doing host-side stream work — source
batching, Skip/window ops, tail fan-out.  Predictions stay device-side
behind each ``ExtractRequest`` until ``poll()`` (non-blocking) or
``wait()``/``drain()`` (blocking) observes the forward's completion; the
request then reports ``done``, and materializes its per-task numpy slices
lazily on first ``result`` access — one device→host transfer per chunk,
shared by every request coalesced into it.

``max_inflight`` bounds the number of launched-but-unretired forwards
(default 2 = double buffering), which also bounds staging memory: a
staging buffer returns to the reuse pool as soon as its forward retires.
``drain()`` keeps its original synchronous contract (run everything,
block, return the forward count) and survives as the end-of-run /
checkpoint barrier.

Semantic gating (the cache-consult stage)
-----------------------------------------
With a ``repro.semantic.SemanticGate`` attached (``gate=`` or
``ctx.gate``), ``submit()`` consults the per-feed keyframe cache before
anything is queued: near-duplicate rows are answered from cached extract
outputs and only the admission's *novel* rows (plus its revalidation
hits) enter the dispatch queue — a batch whose every row hits
short-circuits dispatch entirely.  The returned ``GatedExtractRequest``
keeps the ``n``/``done``/``result`` surface, so the runtimes' suspension
protocol is unchanged; a gate with ``threshold=0`` is inert and the
ungated path stays bitwise identical.

Stats: ``forwards`` (jitted invocations), ``dispatches`` (dispatch calls
that launched work), ``max_inflight_seen`` (peak concurrent forwards),
``staging_allocated`` / ``staging_reused`` (buffer-pool misses / hits),
``staging_skipped`` (exact-fit single requests passed straight to the
jitted fn, no copy), the cache tier's ``cache_hits`` / ``cache_misses`` /
``revalidations`` / ``cache_mismatches``, plus the original ``frames`` /
``padded_frames`` / ``requests`` / ``coalesced_batches``.  ``stats`` is a
*cached view*: one dict object for the server's lifetime, updated in
place (never rebuilt per read).  Two entries are *gauges*, not counters:
``queue_depth`` (requests queued, undispatched) and ``inflight``
(forwards launched, unretired) — the view recomputes them from live
state on every read, so they stay truthful across ``reset_stats()``
instead of freezing at whatever the last in-place update wrote.

Observability (``repro.obs``): with an enabled ``Observability``
(``obs=`` or ``ctx.obs``) the server records the device half of every
frame's lifecycle — per-request ``queue_wait`` spans (submit → launch)
and a ``queue_wait_ms/<feed>`` histogram, ``staging`` / ``dispatch``
spans on the ``server`` track, a ``forward[variant]`` span per chunk on
the ``device`` track (launch → observed completion) feeding a
``forward_ms`` histogram, and ``inflight`` / ``queue_depth`` counter
samples — the occupancy timeline that shows whether double buffering
actually overlaps.  Un-observed servers pay only no-op calls.

The observed ``forward`` span is an upper bound on device time — it
includes however long the runtime took to poll the completion — so every
``device_probe_every``-th forward is additionally *probed*: the launch
thread blocks on a one-element sentinel sliced from the output and
records the launch → device-completion interval as a
``forward_device[variant]`` span and ``forward_device_ms`` /
``forward_device_ms/<variant>`` histograms (frames counted in
``forward_device_frames/<variant>``).  Probed device time is what the
cost-model reconciliation (``repro.obs.audit``) trusts; sampling keeps
the probe off the steady-state path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults import (
    ExtractFaultError,
    ExtractStallError,
    RetryPolicy,
    resolve_faults,
)
from repro.obs import resolve_obs
from repro.streaming.mllm import make_extract_fn, variant_models
from repro.streaming.operators import OpContext, _bucket_pad


def _is_ready(x) -> bool:
    """Non-blocking completion probe; a backend without ``is_ready``
    reports ready (materialization then simply blocks)."""
    ready = getattr(x, "is_ready", None)
    return bool(ready()) if ready is not None else True


class _InFlightChunk:
    """One launched forward: device-side predictions for a coalesced chunk
    plus the bookkeeping to fulfil its requests and recycle its staging
    buffer once the device retires it."""

    __slots__ = ("preds", "reqs", "buf_key", "buf", "completed", "_np",
                 "t_launch", "variant", "total", "delay_polls")

    def __init__(self, preds, reqs: List["ExtractRequest"],
                 buf_key=None, buf=None):
        self.preds = preds                # device arrays until materialized
        self.reqs = reqs
        self.buf_key = buf_key
        self.buf = buf                    # staging buffer, held until retire
        self.completed = False
        self._np: Optional[Dict[str, np.ndarray]] = None
        self.t_launch = 0                 # obs stamp: forward launch (ns)
        self.variant = ""
        self.total = 0
        #: injected artificial device latency: the chunk's completion is
        #: observed this many ``poll()``s late (clock-free by design)
        self.delay_polls = 0

    def ready(self) -> bool:
        return all(_is_ready(v) for v in self.preds.values())

    def block(self) -> None:
        jax.block_until_ready(self.preds)

    def materialize(self) -> Dict[str, np.ndarray]:
        """One device→host transfer for the whole chunk (blocks only if the
        forward is still running); requests slice views out of it."""
        if self._np is None:
            self._np = {k: np.asarray(v) for k, v in self.preds.items()}
            self.preds = {}               # release device references
        return self._np


class GatedExtractRequest:
    """A submitted extract answered (partly or fully) by the semantic
    cache: only the admission's *model rows* entered the server queue
    (``inner``), the rest resolve from cached keyframe outputs.  Presents
    the same ``n``/``done``/``result`` surface as ``ExtractRequest``, so
    continuations and ``settle_fifo`` never distinguish the two."""

    __slots__ = ("variant", "frames", "feed", "adm", "inner")

    def __init__(self, variant: str, frames: np.ndarray, feed: str,
                 adm, inner: Optional["ExtractRequest"]):
        self.variant = variant
        self.frames = frames
        self.feed = feed
        self.adm = adm
        self.inner = inner

    @property
    def n(self) -> int:
        return int(self.frames.shape[0])

    @property
    def dispatched(self) -> bool:
        return self.inner is None or self.inner.dispatched

    @property
    def failed(self) -> bool:
        """The model rows' request exhausted its retry budget."""
        return self.inner is not None and self.inner.failed

    @property
    def done(self) -> bool:
        """The model rows' forward and every cached-row donor completed —
        ``result`` will not block."""
        return self.adm.ready

    @property
    def result(self) -> Optional[Dict[str, np.ndarray]]:
        if not self.done:
            return None
        return self.adm.assemble()


class ExtractRequest:
    """One pending union extract: ``frames`` in, per-task predictions out.

    Lifecycle: queued → dispatched (forward in flight) → ``done`` (forward
    observed complete by ``poll``/``wait``/``drain``) → ``result`` (lazy
    numpy materialization, shared per coalesced chunk, on first access)."""

    __slots__ = ("variant", "frames", "feed", "_chunk", "_offset",
                 "t_submit", "attempts", "isolate", "failed", "not_before",
                 "fault_event")

    def __init__(self, variant: str, frames: np.ndarray, feed: str = ""):
        self.variant = variant            # big | small | pruned
        self.frames = frames              # (n, C, H, W)
        self.feed = feed
        self._chunk: Optional[_InFlightChunk] = None
        self._offset = 0
        self.t_submit = 0                 # obs stamp: enqueue time (ns)
        #: retry accounting: launches attempted / earliest dispatch round
        #: the next attempt is eligible (exponential backoff) / whether a
        #: failed chunk's members must relaunch one-per-chunk so a
        #: poisoned feed's frames never exhaust chunk-mates' budgets
        self.attempts = 0
        self.not_before = 0
        self.isolate = False
        #: terminally failed (retry budget exhausted) — ``result`` raises
        self.failed = False
        #: fault-schedule event index, assigned once at enqueue so every
        #: retry of this request replays the same scheduled fault
        self.fault_event = 0

    @property
    def n(self) -> int:
        return int(self.frames.shape[0])

    @property
    def dispatched(self) -> bool:
        return self._chunk is not None

    @property
    def done(self) -> bool:
        """The forward completed — ``result`` will not block."""
        return self._chunk is not None and self._chunk.completed

    @property
    def result(self) -> Optional[Dict[str, np.ndarray]]:
        if self.failed:
            raise ExtractFaultError(
                f"extract request feed={self.feed!r} "
                f"variant={self.variant} n={self.n} failed after "
                f"{self.attempts} attempts")
        if not self.done:
            return None
        preds = self._chunk.materialize()
        return {k: v[self._offset:self._offset + self.n]
                for k, v in preds.items()}


# ---------------------------------------------------------------------------
# suspension-queue settling (shared by MultiStreamRuntime's feed queues and
# MultiQueryRuntime's pipelined path — one implementation of the resume-
# order invariant, so the two executors cannot drift)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PendingResume:
    """A suspended micro-batch: resumes past ``op_index`` once ``req``'s
    forward completes."""

    op_index: int
    batch: Any
    req: Union["ExtractRequest", "GatedExtractRequest"]
    n: int


def settle_fifo(pendings: List[Tuple[Any, PendingResume]],
                resume: Callable[[Any, PendingResume], Optional[PendingResume]],
                ) -> Tuple[List[Tuple[Any, PendingResume]], int]:
    """Resume, in FIFO order, every fulfilled continuation whose *lane* has
    no earlier outstanding one.

    Stateful post-extract ops must observe batches in stream order per
    lane (a lane = one sharing-group executor; lanes are independent), so
    a completed continuation stays parked while an older one of the same
    lane is still in flight.  ``resume(lane, pending)`` returns a
    re-suspension or None; re-suspensions keep their queue position.
    Returns ``(new queue, number resumed)``."""
    out: List[Tuple[Any, PendingResume]] = []
    blocked: set = set()
    resumed = 0
    for lane, p in pendings:
        if id(lane) not in blocked and p.req.done:
            nxt = resume(lane, p)
            resumed += 1
            if nxt is not None:
                out.append((lane, nxt))
                blocked.add(id(lane))
        else:
            out.append((lane, p))
            blocked.add(id(lane))
    return out, resumed


class SharedExtractServer:
    """Coalesces union-task extract requests across feeds into batched
    forwards per (variant, frame-shape) bucket, pipelined.

    ``max_batch`` bounds a single coalesced forward (memory / latency
    ceiling); ``max_inflight`` bounds dispatched-but-unretired forwards
    (double buffering by default)."""

    VARIANTS = ("big", "small", "pruned")

    #: consecutive dispatch calls a padded partial chunk may be deferred
    #: before it launches anyway — bounds the latency of a feed whose
    #: chunks never fill their bucket while other feeds keep the device
    #: busy (continuous-traffic starvation guard)
    MAX_PARTIAL_DEFERS = 2

    def __init__(self, ctx: OpContext, max_batch: int = 64,
                 max_inflight: int = 2, gate=None, obs=None,
                 faults=None, retry: Optional[RetryPolicy] = None,
                 drain_timeout_s: float = 120.0,
                 device_probe_every: int = 8):
        assert max_batch >= 1 and max_inflight >= 1
        self.ctx = ctx
        self.max_batch = max_batch
        self.max_inflight = max_inflight
        #: optional ``repro.semantic.SemanticGate``: the cache-consult
        #: stage in front of dispatch.  Defaults to the context's gate so
        #: one configuration point covers the solo and the served path.
        self.gate = gate if gate is not None else ctx.gate
        #: observability handle (explicit arg > ctx.obs > inert NULL_OBS)
        self.obs = resolve_obs(obs, getattr(ctx, "obs", None))
        if self.gate is not None:
            self.gate.obs = self.obs
        #: fault injection (explicit arg > ctx.faults > inert NULL_FAULTS)
        self.faults = resolve_faults(faults, getattr(ctx, "faults", None))
        #: bounded-retry policy for failed forwards (see repro.faults)
        self.retry = retry if retry is not None else RetryPolicy()
        #: watchdog deadline: ``wait()``/``drain()`` raise a descriptive
        #: ``ExtractStallError`` naming the stuck chunk/bucket after this
        #: many seconds without progress (a launch or a retirement resets
        #: it; a long first compile blocks *inside* the forward and so
        #: never trips it)
        self.drain_timeout_s = drain_timeout_s
        #: device-accurate forward timing: every Nth launched forward is
        #: *probed* — a ``block_until_ready`` on a one-element sentinel
        #: sliced from the forward output, timed launch → device
        #: completion, so the measurement excludes the poll interval the
        #: observed ``forward`` span necessarily includes.  Sampling keeps
        #: steady-state serving free (a probe serializes the host for that
        #: one forward); 0 disables probing entirely.  Active only with an
        #: enabled ``Observability`` — the un-observed path never probes.
        self.device_probe_every = device_probe_every
        self._probe_seq = 0                   # forwards since last probe
        self._dispatch_seq = 0                # retry backoff clock (rounds)
        self._defers: Dict[Tuple, int] = {}   # bucket key -> deferred calls
        self._fns: Dict[str, Any] = {}
        self._queue: List[ExtractRequest] = []
        self._inflight: List[_InFlightChunk] = []
        #: staging-buffer pool: (bucket, shape, dtype) -> free buffers
        self._staging: Dict[Tuple, List[np.ndarray]] = {}
        # running pending counters — submit/dispatch keep them exact, so
        # the per-feed backpressure checks each scheduling round are O(1)
        # instead of O(queue)
        self._pending_reqs: Dict[str, int] = {}
        self._pending_frames: Dict[str, int] = {}
        self._pending_reqs_total = 0
        self._pending_frames_total = 0
        self._stats = self._fresh_stats()

    @staticmethod
    def _fresh_stats() -> Dict[str, int]:
        return {"forwards": 0, "frames": 0, "padded_frames": 0,
                "requests": 0, "coalesced_batches": 0,
                "dispatches": 0, "max_inflight_seen": 0,
                "staging_allocated": 0, "staging_reused": 0,
                "staging_skipped": 0,
                # fault-tolerance tier: injected/observed forward faults,
                # relaunch decisions, terminal failures, latency injections
                "forward_faults": 0, "retries": 0, "retry_exhausted": 0,
                "latency_faults": 0,
                # live gauges (recomputed on read, see ``stats``)
                "queue_depth": 0, "inflight": 0,
                # cache tier (mirrors the gate's counters; stays 0 ungated)
                "cache_hits": 0, "cache_misses": 0,
                "revalidations": 0, "cache_mismatches": 0}

    @property
    def stats(self) -> Dict[str, int]:
        """The server's counters as a *cached view*: one dict object for
        the server's lifetime, updated in place (it used to be rebound on
        every reset, so holders diffed against a dead dict).  Reading the
        view syncs the semantic-cache tier's counters
        (hits/misses/revalidations/mismatches) into it and recomputes the
        ``queue_depth`` / ``inflight`` gauges from live state — they stay
        truthful across ``reset_stats()``."""
        if self.gate is not None:
            self._stats.update(self.gate.counters)
        self._stats["queue_depth"] = self._pending_reqs_total
        self._stats["inflight"] = len(self._inflight)
        return self._stats

    def reset_stats(self) -> None:
        """Drop accounting (e.g. after warmup) without dropping the
        compiled program cache, the staging pool or the semantic cache's
        keyframes — reusing those across the measured run is the whole
        point of warmup.  Warmup-polluted latency histograms (queue-wait,
        forward: compile time would swamp the measured p99) drop with it;
        gauges recompute on the next ``stats`` read."""
        self._stats.update(self._fresh_stats())
        if self.gate is not None:
            self.gate.reset_counters()
        if self.obs.enabled:
            self.obs.metrics.drop("queue_wait_ms")
            self.obs.metrics.drop("forward_ms")
            self.obs.metrics.drop("forward_device_ms")
            self.obs.metrics.drop("forward_device_frames")
            # realign probe sampling so the first *measured* forward is
            # probed — a short post-warmup run must not land between
            # sample points and finish with zero device measurements
            self._probe_seq = 0

    # ------------------------------------------------------------------
    def _fn(self, variant: str):
        if variant not in self._fns:
            mllm, params = variant_models(self.ctx)[variant]
            assert mllm is not None, f"ctx has no model for {variant!r}"
            self._fns[variant] = make_extract_fn(mllm, params)
        return self._fns[variant]

    # ------------------------------------------------------------------
    def submit(self, variant: str, frames: np.ndarray,
               feed: str = "", sig=None) -> Union[ExtractRequest,
                                                  GatedExtractRequest]:
        """Queue an extract; the returned request reports ``done`` once a
        ``dispatch``ed forward completes (observed by ``poll``/``wait``)
        or a blocking ``drain()`` runs it.  "adaptive" must be resolved by
        the caller (``MLLMExtractOp.begin_extract``) — the density EMA is
        per-op state the server has no business owning.

        With an active semantic gate, submission first consults the
        per-feed keyframe cache: near-duplicate rows are answered from
        cached extract outputs and only the admission's model rows enter
        the dispatch queue — a batch whose every row hits short-circuits
        dispatch entirely (``done`` immediately, zero queued frames).

        ``sig`` forwards a fused-prefix-computed ``(feats, emb)`` pair
        for these frames to the gate (see ``SemanticGate.admit``)."""
        assert variant in self.VARIANTS, variant
        assert frames.ndim == 4 and frames.shape[0] > 0, frames.shape
        self.stats["requests"] += 1
        if self.gate is not None and self.gate.active:
            adm = self.gate.admit(feed, variant, frames, sig=sig)
            inner = None
            if adm.n_model:
                inner = self._enqueue(variant, adm.model_frames(frames),
                                      feed)
            adm.bind(inner)
            return GatedExtractRequest(variant, frames, feed, adm, inner)
        return self._enqueue(variant, frames, feed)

    def _enqueue(self, variant: str, frames: np.ndarray,
                 feed: str) -> ExtractRequest:
        req = ExtractRequest(variant=variant, frames=frames, feed=feed)
        if self.obs.enabled:
            req.t_submit = self.obs.now()
        if self.faults.enabled:
            req.fault_event = self.faults.next_event("forward", feed)
        self._queue.append(req)
        self._pending_reqs[feed] = self._pending_reqs.get(feed, 0) + 1
        self._pending_frames[feed] = \
            self._pending_frames.get(feed, 0) + req.n
        self._pending_reqs_total += 1
        self._pending_frames_total += req.n
        return req

    def probe(self, variant: str, frames: np.ndarray,
              feed: str = "") -> ExtractRequest:
        """Enqueue an *isolated* canary extract (circuit-breaker
        half-open probe): it never coalesces with other feeds' requests,
        so a probe that faults cannot burn chunk-mates' retry budgets."""
        req = self._enqueue(variant, frames, feed)
        req.isolate = True
        return req

    def cancel(self, req: ExtractRequest) -> bool:
        """Remove a still-queued request (quarantine path: a tripped
        feed's parked submissions must not launch pointless forwards).
        Returns False when the request already dispatched or left the
        queue — its forward, if any, retires normally and is ignored."""
        if req.dispatched or req.failed:
            return False
        try:
            self._queue.remove(req)
        except ValueError:
            return False
        self._pending_reqs[req.feed] -= 1
        self._pending_frames[req.feed] -= req.n
        self._pending_reqs_total -= 1
        self._pending_frames_total -= req.n
        return True

    def pending_frames(self, feed: Optional[str] = None) -> int:
        """Frames queued and not yet dispatched (running counter)."""
        if feed is None:
            return self._pending_frames_total
        return self._pending_frames.get(feed, 0)

    def pending_requests(self, feed: Optional[str] = None) -> int:
        """Requests queued and not yet dispatched (running counter)."""
        if feed is None:
            return self._pending_reqs_total
        return self._pending_reqs.get(feed, 0)

    @property
    def inflight(self) -> int:
        """Forwards dispatched and not yet retired."""
        return len(self._inflight)

    # ------------------------------------------------------------------
    def _acquire_staging(self, key: Tuple, bucket: int, shape: Tuple,
                         dtype) -> np.ndarray:
        pool = self._staging.get(key)
        if pool:
            self.stats["staging_reused"] += 1
            return pool.pop()
        self.stats["staging_allocated"] += 1
        return np.empty((bucket,) + shape, dtype)

    def _chunk_failed(self, variant: str,
                      chunk: List[ExtractRequest]) -> None:
        """A chunk's forward faulted (injected or real): every member
        request stays queued for an *isolated* relaunch after its
        exponential backoff, or — past ``retry.max_attempts`` — turns
        terminally ``failed`` and leaves the queue (the runtime's
        circuit breaker takes over from there)."""
        obs = self.obs
        self.stats["forward_faults"] += 1
        seq = self._dispatch_seq
        for r in chunk:
            r.attempts += 1
            r.isolate = True
            if r.attempts >= self.retry.max_attempts:
                r.failed = True
                self.stats["retry_exhausted"] += 1
                # terminal: dispatch removes it from the queue below
                self._pending_reqs[r.feed] -= 1
                self._pending_frames[r.feed] -= r.n
                self._pending_reqs_total -= 1
                self._pending_frames_total -= r.n
            else:
                r.not_before = seq + self.retry.backoff_rounds(r.attempts)
                self.stats["retries"] += 1
            if obs.enabled:
                track = f"feed:{r.feed}"
                obs.tracer.instant(
                    f"fault:forward[{variant}]", "fault", track=track,
                    n=r.n)
                if r.failed:
                    obs.metrics.inc(f"faults/exhausted/{r.feed}", 1)
                else:
                    obs.tracer.instant("retry", "retry", track=track,
                                       n=r.n)
                    obs.metrics.inc(f"faults/retries/{r.feed}", 1)

    def _launch(self, variant: str, chunk: List[ExtractRequest]) -> bool:
        """Pack one chunk and launch its forward asynchronously; returns
        False when the forward faulted (members re-staged or failed)."""
        obs = self.obs
        faults = self.faults
        delay = 0
        if faults.enabled:
            for r in chunk:
                f = faults.fire("forward", r.feed, variant,
                                r.fault_event, r.attempts)
                if f is None:
                    continue
                if f[0] == "error":
                    self._chunk_failed(variant, chunk)
                    return False
                delay = max(delay, f[1])        # latency
        t_stage = obs.now() if obs.enabled else 0
        total = sum(r.n for r in chunk)
        bucket = _bucket_pad(total)
        shape = chunk[0].frames.shape[1:]
        dtype = chunk[0].frames.dtype
        if len(chunk) == 1 and chunk[0].n == bucket:
            # an exactly-full single request needs no staging copy
            dev = jnp.asarray(chunk[0].frames)
            buf_key = buf = None
            self.stats["staging_skipped"] += 1
        else:
            buf_key = (bucket,) + tuple(shape) + (dtype.str,)
            buf = self._acquire_staging(buf_key, bucket, shape, dtype)
            off = 0
            for r in chunk:
                buf[off:off + r.n] = r.frames
                off += r.n
            if bucket > total:
                # padding rows must classify as "normalized" in the jitted
                # program — a reused buffer otherwise carries stale frames
                buf[total:bucket] = 0
            dev = jnp.asarray(buf)
        t_disp = obs.now() if obs.enabled else 0
        if faults.enabled:
            # with the injector live, a real forward exception follows
            # the same retry path as an injected one; without it, errors
            # propagate exactly as before (no behavior change)
            try:
                preds = self._fn(variant)(dev)
            except AssertionError:
                raise
            except Exception:
                if buf is not None:
                    self._staging.setdefault(buf_key, []).append(buf)
                self._chunk_failed(variant, chunk)
                return False
        else:
            preds = self._fn(variant)(dev)  # async dispatch: returns now
        fl = _InFlightChunk(preds, list(chunk), buf_key, buf)
        fl.variant = variant
        fl.total = total
        if delay:
            fl.delay_polls = delay
            self.stats["latency_faults"] += 1
            if obs.enabled:
                obs.tracer.instant(f"fault:latency[{variant}]", "fault",
                                   track="device", n=total)
        if obs.enabled:
            fl.t_launch = obs.now()
            tr = obs.tracer
            tr.span("staging", "staging", t_stage, t_disp,
                    track="server", n=total)
            tr.span(f"dispatch[{variant}]", "dispatch", t_disp,
                    fl.t_launch, track="server", n=bucket)
            for r in chunk:
                if r.t_submit:
                    tr.span("queue_wait", "queue", r.t_submit, fl.t_launch,
                            track=f"feed:{r.feed}", n=r.n)
                    obs.metrics.observe(
                        f"queue_wait_ms/{r.feed}",
                        (fl.t_launch - r.t_submit) / 1e6, r.n)
            if self.device_probe_every and not delay:
                # device-accurate forward timing: every Nth forward is
                # probed — block on a one-element sentinel sliced from
                # the output, so the launch→completion interval excludes
                # the poll quantization the observed ``forward`` span
                # carries.  The probe serializes the host for this one
                # forward only; un-probed forwards are untouched.
                if self._probe_seq % self.device_probe_every == 0:
                    sentinel = next(iter(fl.preds.values()))[:1]
                    jax.block_until_ready(sentinel)
                    t_done = obs.now()
                    tr.span(f"forward_device[{variant}]", "forward",
                            fl.t_launch, t_done, track="device", n=total)
                    dev_ms = (t_done - fl.t_launch) / 1e6
                    obs.metrics.observe("forward_device_ms", dev_ms)
                    obs.metrics.observe(
                        f"forward_device_ms/{variant}", dev_ms)
                    obs.metrics.inc(
                        f"forward_device_frames/{variant}", total)
                self._probe_seq += 1
        off = 0
        for r in chunk:
            r._chunk = fl
            r._offset = off
            off += r.n
            self._pending_reqs[r.feed] -= 1
            self._pending_frames[r.feed] -= r.n
        self._pending_reqs_total -= len(chunk)
        self._pending_frames_total -= total
        self._inflight.append(fl)
        if obs.enabled:
            # occupancy timeline: sampled at every launch and retire
            obs.tracer.counter("inflight", len(self._inflight))
            obs.tracer.counter("queue_depth", self._pending_reqs_total)
        self.stats["forwards"] += 1
        self.stats["frames"] += total
        self.stats["padded_frames"] += bucket - total
        if len(chunk) > 1:
            self.stats["coalesced_batches"] += 1
        self.stats["max_inflight_seen"] = max(
            self.stats["max_inflight_seen"], len(self._inflight))
        return True

    def dispatch(self, budget: Optional[int] = None) -> int:
        """Launch queued requests as asynchronous forwards and return
        immediately; returns the number of forwards launched.

        Requests group by (variant, frame shape, dtype) and chunk greedily
        under ``max_batch`` frames per forward, exactly like the
        synchronous drain; at most ``budget`` chunks launch (None: as many
        as ``max_inflight`` allows).  Unlaunched requests stay queued in
        order, so per-feed FIFO resume order is preserved.

        Dispatch-ahead coalesces *fuller* forwards than the barrier drain:
        a chunk that exactly fills its power-of-two bucket launches
        eagerly, while a padded partial chunk is deferred — backpressured
        feeds keep filling the queue, so the partial usually grows into a
        full bucket by the next call — unless the device would otherwise
        idle (nothing in flight) or the chunk's bucket has already been
        deferred ``MAX_PARTIAL_DEFERS`` times (a feed whose chunks never
        fill a bucket must not starve behind feeds that keep the device
        busy).  ``drain()`` flushes deferred partials at the barrier,
        exactly like the synchronous path always did.

        With a live fault injector three more queue states exist:
        terminally *failed* requests leave the queue here (their owner
        sees ``failed``/``result`` raise), requests inside their backoff
        window (``not_before`` > the dispatch round counter) stay queued
        untouched, and *isolated* retry requests launch one-per-chunk
        ahead of everything else so a poisoned request can never spend a
        healthy chunk-mate's retry budget."""
        seq = self._dispatch_seq = self._dispatch_seq + 1
        room = self.max_inflight - len(self._inflight)
        if budget is not None:
            room = min(room, budget)
        if room <= 0 or not self._queue:
            return 0
        launched = 0
        taken: set = set()
        iso: List[ExtractRequest] = []
        groups: Dict[Tuple, List[ExtractRequest]] = {}
        for r in self._queue:
            if r.failed:
                taken.add(id(r))      # terminal: drop from the queue
                continue
            if r.not_before > seq:
                continue              # backing off: not eligible yet
            if r.isolate:
                iso.append(r)
                continue
            key = (r.variant, r.frames.shape[1:], r.frames.dtype.str)
            groups.setdefault(key, []).append(r)
        full: List[Tuple[Tuple, List[ExtractRequest]]] = []
        partial: List[Tuple[Tuple, List[ExtractRequest]]] = []
        for key, reqs in groups.items():
            chunk: List[ExtractRequest] = []
            size = 0
            for r in reqs:
                if chunk and size + r.n > self.max_batch:
                    (full if size == _bucket_pad(size) else partial).append(
                        (key, chunk))
                    chunk, size = [], 0
                chunk.append(r)
                size += r.n
            if chunk:
                (full if size == _bucket_pad(size) else partial).append(
                    (key, chunk))

        def launch(key: Tuple, chunk: List[ExtractRequest],
                   served: bool) -> None:
            nonlocal launched
            ok = self._launch(key[0], chunk)
            if served:
                # only a *partial* launch services the waiting bucket — a
                # full chunk of the same key must not reset the clock of
                # partial requests still parked behind it
                self._defers.pop(key, None)
            if ok:
                taken.update(id(r) for r in chunk)
                launched += 1
            else:
                # the forward faulted: members stay queued for isolated
                # retry, except those that just exhausted their budget
                taken.update(id(r) for r in chunk if r.failed)

        # isolated retries outrank everything: they are the oldest work
        # in the queue and each occupies a whole chunk by design
        for r in iso:
            if launched >= room:
                break
            launch((r.variant,), [r], served=False)
        overdue = [c for c in partial
                   if self._defers.get(c[0], 0) >= self.MAX_PARTIAL_DEFERS]
        fresh = [c for c in partial
                 if self._defers.get(c[0], 0) < self.MAX_PARTIAL_DEFERS]
        # overdue partials outrank full chunks: they have already waited
        # their bound, and full buckets can afford one call's patience
        for key, chunk in overdue:
            if launched >= room:
                break
            launch(key, chunk, served=True)
        for key, chunk in full:
            if launched >= room:
                break
            launch(key, chunk, served=False)
        for key, chunk in fresh:
            if launched >= room or self._inflight:
                break              # defer padding while the device is fed
            launch(key, chunk, served=True)
        # age every partial bucket that stayed queued — whatever the
        # reason (device fed, room exhausted by fulls) — so the deferral
        # bound holds even for a feed whose chunks never fill a bucket;
        # buckets with nothing left waiting drop their count (a partial
        # that grew into a launched full chunk must not leave a stale
        # count that would prematurely pad the bucket's next partial)
        waiting = {key for key, chunk in partial
                   if id(chunk[0]) not in taken}
        for key in waiting:
            self._defers[key] = self._defers.get(key, 0) + 1
        for key in list(self._defers):
            if key not in waiting:
                del self._defers[key]
        if not taken:
            return 0
        self._queue = [r for r in self._queue if id(r) not in taken]
        self.stats["dispatches"] += 1
        return launched

    # ------------------------------------------------------------------
    def _retire(self, fl: _InFlightChunk) -> None:
        fl.completed = True
        if fl.buf is not None:
            # the device consumed the staging input; recycle it
            self._staging.setdefault(fl.buf_key, []).append(fl.buf)
            fl.buf = None
        if fl.t_launch:
            # launch → observed completion: an upper bound on device time
            # (includes the poll interval), which is the honest quantity
            # for occupancy reasoning — the host couldn't have used the
            # result any earlier
            obs = self.obs
            t1 = obs.now()
            obs.tracer.span(f"forward[{fl.variant}]", "forward",
                            fl.t_launch, t1, track="device", n=fl.total)
            obs.metrics.observe(
                "forward_ms", (t1 - fl.t_launch) / 1e6)
            fl.t_launch = 0

    def poll(self) -> int:
        """Non-blocking: retire every in-flight forward whose device work
        completed — its requests report ``done`` and their continuations
        become resumable — and recycle its staging buffer.  Returns the
        number of forwards retired."""
        still: List[_InFlightChunk] = []
        retired = 0
        for fl in self._inflight:
            if fl.delay_polls > 0:
                # injected device latency: completion observed late,
                # one poll at a time (clock-free)
                fl.delay_polls -= 1
                still.append(fl)
            elif fl.ready():
                self._retire(fl)
                retired += 1
            else:
                still.append(fl)
        self._inflight = still
        return retired

    def pump(self, progressed: bool, coalesce_frames: int,
             settle: Callable[[], int]) -> None:
        """One pipelined scheduling step — THE shared driver of the
        dispatch/poll/resume protocol, so the serving runtimes
        (``MultiStreamRuntime.run``, ``MultiQueryRuntime``'s server path)
        cannot drift: dispatch once the coalescing window holds
        ``coalesce_frames`` queued frames (or nothing progressed this
        round), poll completions, ``settle()`` fulfilled continuations
        (returns how many resumed), and block for the oldest forward only
        when genuinely stalled — nothing pulled, nothing resumed.
        Polling comes first so an inflight slot freed by a completed
        forward refills in the *same* step — the device stays
        double-buffered instead of draining toward depth 1."""
        self.poll()
        if self.pending_frames() >= coalesce_frames or not progressed:
            self.dispatch()
        resumed = settle()
        if not progressed and not resumed:
            self.wait()

    def _stuck_desc(self) -> str:
        """Name the work the watchdog is stuck on — the error message a
        timed-out ``wait()``/``drain()`` raises."""
        if self._inflight:
            fl = self._inflight[0]
            total = sum(r.n for r in fl.reqs)
            feeds = sorted({r.feed for r in fl.reqs})
            return (f"in-flight chunk variant={fl.variant!r} "
                    f"bucket={_bucket_pad(total)} ({len(fl.reqs)} reqs, "
                    f"{total} frames, feeds={feeds})")
        if self._queue:
            r = self._queue[0]
            return (f"queued request feed={r.feed!r} "
                    f"variant={r.variant!r} n={r.n} "
                    f"attempts={r.attempts} "
                    f"not_before={r.not_before} (round {self._dispatch_seq})")
        return "no queued or in-flight work"

    def wait(self) -> int:
        """Block until at least one in-flight forward completes
        (dispatching queued work first when nothing is in flight); returns
        the number of forwards retired.  The runtime's stall path: called
        only when no feed can progress and nothing polled ready.

        Deadline-bounded: if ``drain_timeout_s`` passes without a single
        retirement or launch, raises ``ExtractStallError`` naming the
        stuck chunk instead of spinning forever (injected latency burns
        one poll per iteration, so it always terminates well before)."""
        if not self._inflight:
            self.dispatch()
        deadline = time.monotonic() + self.drain_timeout_s
        while self._inflight:
            self._inflight[0].block()
            retired = self.poll()
            if retired:
                return retired
            if not self.dispatch() and time.monotonic() > deadline:
                raise ExtractStallError(
                    f"wait(): no extract progress for "
                    f"{self.drain_timeout_s:g}s; stuck on "
                    f"{self._stuck_desc()}")
        return 0

    def drain(self) -> int:
        """Synchronous barrier: run every queued and in-flight request to
        completion; returns the number of forwards.  Survives as the
        end-of-run / warmup / checkpoint flush — the steady-state path is
        ``dispatch``/``poll``.

        Deadline-bounded (was an unbounded busy-wait): every round that
        launches or retires nothing eats into ``drain_timeout_s``; when
        the budget is gone an ``ExtractStallError`` names the stuck
        bucket/variant.  Rounds that *do* progress reset the deadline, so
        a long healthy drain never trips it."""
        forwards0 = self.stats["forwards"]
        deadline = time.monotonic() + self.drain_timeout_s
        while self._queue or self._inflight:
            launched = self.dispatch()
            retired = 0
            if self._inflight:
                self._inflight[0].block()
                retired = self.poll()
            if launched or retired:
                deadline = time.monotonic() + self.drain_timeout_s
            elif time.monotonic() > deadline:
                raise ExtractStallError(
                    f"drain(): no extract progress for "
                    f"{self.drain_timeout_s:g}s with "
                    f"{len(self._queue)} queued / "
                    f"{len(self._inflight)} in-flight forwards; stuck on "
                    f"{self._stuck_desc()}")
        return self.stats["forwards"] - forwards0
