"""Shared MLLM extract server: one model, many feeds.

Every ``MLLMExtractOp`` used to own a private jitted program, so K feeds
(and, before multi-query sharing, N queries) each paid their own forward
and their own compilation.  The server inverts the ownership: it holds one
jitted union-task extract program per *physical backbone variant*
(big / small / pruned — the same resolution ``MLLMExtractOp.open`` does,
with "adaptive" resolved by the op's density tracker before submission),
and coalesces extract requests from different streams into batched
forwards.

Coalescing is shape-bucketed and padded: requests whose frames agree on
(C, H, W) — same preprocessing stage — concatenate into one batch, padded
to a power-of-two bucket (the ``serving.engine`` ``_bucket`` idiom) so the
number of distinct compiled shapes stays logarithmic in batch size.
Requests with different frame shapes (a cropped tollbooth feed next to a
full-frame volleyball feed) land in different buckets but still share the
compiled program cache across feeds.

Because ``make_extract_fn`` normalizes per frame and every head is
computed in one forward, each row of a coalesced batch is bitwise
identical to what the op's solo path would have produced — the server
changes *how many* forwards run, never *what* any query observes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.streaming.mllm import make_extract_fn, variant_models
from repro.streaming.operators import OpContext, _bucket_pad


@dataclasses.dataclass
class ExtractRequest:
    """One pending union extract: ``frames`` in, per-task predictions out
    (filled by ``SharedExtractServer.drain``)."""

    variant: str                      # big | small | pruned
    frames: np.ndarray                # (n, C, H, W)
    feed: str = ""
    result: Optional[Dict[str, np.ndarray]] = None

    @property
    def n(self) -> int:
        return int(self.frames.shape[0])

    @property
    def done(self) -> bool:
        return self.result is not None


class SharedExtractServer:
    """Coalesces union-task extract requests across feeds into one batched
    forward per (variant, frame-shape) bucket.

    ``max_batch`` bounds a single coalesced forward (memory / latency
    ceiling); a drain splits larger groups into several forwards."""

    VARIANTS = ("big", "small", "pruned")

    def __init__(self, ctx: OpContext, max_batch: int = 64):
        assert max_batch >= 1
        self.ctx = ctx
        self.max_batch = max_batch
        self._fns: Dict[str, Any] = {}
        self._queue: List[ExtractRequest] = []
        self.stats = self._fresh_stats()

    @staticmethod
    def _fresh_stats() -> Dict[str, int]:
        return {"forwards": 0, "frames": 0, "padded_frames": 0,
                "requests": 0, "coalesced_batches": 0}

    def reset_stats(self) -> None:
        """Drop accounting (e.g. after warmup) without dropping the
        compiled program cache — that is the whole point of warmup."""
        self.stats = self._fresh_stats()

    # ------------------------------------------------------------------
    def _fn(self, variant: str):
        if variant not in self._fns:
            mllm, params = variant_models(self.ctx)[variant]
            assert mllm is not None, f"ctx has no model for {variant!r}"
            self._fns[variant] = make_extract_fn(mllm, params)
        return self._fns[variant]

    # ------------------------------------------------------------------
    def submit(self, variant: str, frames: np.ndarray,
               feed: str = "") -> ExtractRequest:
        """Queue an extract; returns the request whose ``result`` is filled
        at the next ``drain()``.  "adaptive" must be resolved by the caller
        (``MLLMExtractOp.begin_extract``) — the density EMA is per-op state
        the server has no business owning."""
        assert variant in self.VARIANTS, variant
        assert frames.ndim == 4 and frames.shape[0] > 0, frames.shape
        req = ExtractRequest(variant=variant, frames=frames, feed=feed)
        self._queue.append(req)
        self.stats["requests"] += 1
        return req

    def pending_frames(self, feed: Optional[str] = None) -> int:
        return sum(r.n for r in self._queue
                   if feed is None or r.feed == feed)

    def pending_requests(self, feed: Optional[str] = None) -> int:
        return sum(1 for r in self._queue
                   if feed is None or r.feed == feed)

    # ------------------------------------------------------------------
    def _run_chunk(self, variant: str, chunk: List[ExtractRequest]) -> None:
        total = sum(r.n for r in chunk)
        bucket = _bucket_pad(total)
        shape = chunk[0].frames.shape[1:]
        dtype = chunk[0].frames.dtype
        batch = np.zeros((bucket,) + shape, dtype)
        off = 0
        for r in chunk:
            batch[off:off + r.n] = r.frames
            off += r.n
        preds = self._fn(variant)(jnp.asarray(batch))
        preds = {k: np.asarray(v) for k, v in preds.items()}
        off = 0
        for r in chunk:
            r.result = {k: v[off:off + r.n] for k, v in preds.items()}
            off += r.n
        self.stats["forwards"] += 1
        self.stats["frames"] += total
        self.stats["padded_frames"] += bucket - total
        if len(chunk) > 1:
            self.stats["coalesced_batches"] += 1

    def drain(self) -> int:
        """Run every queued request; returns the number of forwards.

        Requests group by (variant, frame shape, dtype); each group is
        chunked greedily under ``max_batch`` frames per forward (a request
        larger than ``max_batch`` still runs whole — the op's own micro-
        batch is the upstream bound)."""
        queue, self._queue = self._queue, []
        groups: Dict[Tuple, List[ExtractRequest]] = {}
        for r in queue:
            key = (r.variant, r.frames.shape[1:], r.frames.dtype.str)
            groups.setdefault(key, []).append(r)
        forwards0 = self.stats["forwards"]
        for (variant, _, _), reqs in groups.items():
            chunk: List[ExtractRequest] = []
            size = 0
            for r in reqs:
                if chunk and size + r.n > self.max_batch:
                    self._run_chunk(variant, chunk)
                    chunk, size = [], 0
                chunk.append(r)
                size += r.n
            if chunk:
                self._run_chunk(variant, chunk)
        return self.stats["forwards"] - forwards0
