"""Multi-feed serving runtime: K streams, one shared MLLM serving tier.

``MultiStreamRuntime`` generalizes ``MultiQueryRuntime`` (N queries, one
stream) to N queries over K heterogeneous feeds (e.g. three tollbooth
cameras with different traffic plus a volleyball court).  Per feed, the
``SharingTreePlanner`` factors that feed's plans into sharing groups
(shared signature prefix + merged union-task extract + per-query tails);
across feeds, every group's extract requests route through one
``SharedExtractServer`` that coalesces them into shape-bucketed batched
forwards — K feeds cost one forward per coalesced batch instead of K.

Scheduling is round-robin over feeds at micro-batch granularity (the
starting feed rotates every round so no feed systematically front-runs the
coalescing window), with per-stream backpressure: a feed whose un-fulfilled
extract continuations reach its budget of ``max_pending × n_groups`` is
skipped until the server drains, so one stalled/bursty feed cannot grow
the request queue unboundedly while the others starve.

Execution is suspension-based: a group advances each micro-batch through
its prefix until an ``MLLMExtractOp``, parks the batch as a continuation
keyed by the server request, and resumes — in submission order, so every
stateful op still observes batches in stream order — once the server
fulfils it.  Because the server runs the *same* jitted extract program the
op's solo path uses (per-frame normalization, union heads), every query's
outputs are bitwise identical to independent execution.

Serving is *pipelined* by default (``pipelined=True``): instead of the
lock-step barrier drain at round boundaries, the run loop launches
coalesced forwards asynchronously (``SharedExtractServer.dispatch``),
``poll``s for completions, and resumes exactly the continuations whose
forwards finished — so round *k*'s source batching, prefix ops and tail
fan-out overlap round *k−1*'s device forwards, double-buffered under the
server's ``max_inflight`` cap.  The loop blocks (``server.wait``) only
when no feed can progress and nothing polled ready; the synchronous
``_drain_all`` barrier survives for warmup, end-of-run and flush.
``pipelined=False`` restores the lock-step drain (the baseline the
``fig_pipeline`` benchmark measures against).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Union

import numpy as np

from repro.core.costs import op_cost_key
from repro.faults import OPEN, CircuitBreaker, resolve_faults
from repro.scheduler.extract_server import (
    PendingResume,
    SharedExtractServer,
    settle_fifo,
)
from repro.scheduler.sharing_tree import SharingForest, SharingTreePlanner
from repro.streaming.fused import FusedPrefixOp
from repro.streaming.multiquery import (broadcast_windows, fan_out_tails,
                                        flush_shared)
from repro.streaming.operators import (
    Batch,
    MLLMExtractOp,
    Op,
    OpContext,
    SinkOp,
    SourceOp,
)
from repro.streaming.plan import Plan
from repro.streaming.runtime import (
    RunResult,
    mllm_frames_of,
    warmup_ops,
)


@dataclasses.dataclass
class Feed:
    """One physical stream plus the queries standing on it."""

    name: str
    stream: Any                       # TollBoothStream / VolleyballStream
    plans: List[Plan]


@dataclasses.dataclass
class FeedResult:
    name: str
    n_frames: int
    mllm_frames: int
    per_query: Dict[str, RunResult]
    plan: str
    #: fault-tolerance accounting — ``served + degraded + dropped`` exactly
    #: partitions the feed's ingested frames.  ``served`` frames are
    #: bitwise identical to a fault-free run; ``degraded`` frames were
    #: answered from the semantic gate's last keyframe (marked ``stale``
    #: in ``degraded_records``); ``dropped`` frames had no stale answer
    #: available and are counted, never silently invented.
    served: int = 0
    degraded: int = 0
    dropped: int = 0
    degraded_records: List[Dict[str, Any]] = \
        dataclasses.field(default_factory=list)
    #: per-feed circuit-breaker counters (trips/probes/recoveries)
    breaker: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class MultiStreamResult:
    #: aggregate throughput in query-frames/s across every feed
    fps: float
    wall_s: float
    n_feeds: int
    n_queries: int
    #: frames *reaching* MLLM extracts (each shared prefix counted once);
    #: under semantic gating the cache answers part of them — frames that
    #: actually paid a forward are ``server_stats["frames"]``
    mllm_frames: int
    #: server accounting for the sharing claim: ``forwards`` is the number
    #: of jitted extract invocations serving *all* feeds
    server_stats: Dict[str, int]
    feeds: Dict[str, FeedResult]


#: suspended micro-batch continuation (shared with MultiQueryRuntime's
#: pipelined path — one definition of the resume contract)
_Pending = PendingResume


class _GroupExec:
    """Executor for one sharing group: shared prefix with extract
    suspension points + per-query fan-out tails.  Used per feed by
    ``MultiStreamRuntime`` and (single-instance) by ``MultiQueryRuntime``'s
    server-backed pipelined path."""

    def __init__(self, execution, ctx: OpContext,
                 server: SharedExtractServer, feed: str,
                 parallel_tails: bool, open_ops: bool = True,
                 arrival: Optional[list] = None):
        self.exe = execution
        self.server = server
        self.feed = feed
        self.parallel_tails = parallel_tails
        #: observability rides the server — one handle for every group
        #: coalescing into it, so spans from all feeds land in one trace
        self.obs = server.obs
        self._track = f"feed:{feed}"
        #: shared one-slot newest-arrival stamp (ns): the pull loop writes
        #: it at ingest, ``_fan_out`` reads it at emit — their difference
        #: is the feed's staleness (how far the freshest served answer
        #: lags the stream head)
        self.arrival = arrival if arrival is not None else [0]
        if open_ops:
            for op in self.all_ops():
                op.open(ctx)
        for tail in self.exe.tails:
            assert isinstance(tail[-1], SinkOp), "tails must end in a Sink"
        self.reset_accumulators()

    def all_ops(self) -> List[Op]:
        ops = list(self.exe.prefix)
        for tail in self.exe.tails:
            ops.extend(tail)
        return ops

    def reset_accumulators(self) -> None:
        self.pcounts: Dict[str, int] = {op.name: 0
                                        for op in self.exe.prefix}
        self.counts: List[Dict[str, int]] = [
            {op.name: 0 for op in tail} for tail in self.exe.tails]
        self.windows: List[List[Dict[str, Any]]] = [
            [] for _ in self.exe.tails]

    def begin_run(self) -> None:
        """Per-run reset: drop collected sink records and accumulators
        (operator *state* — windows, skip carries — persists, so a
        warmup=0 run continues the stream exactly like StreamRuntime)."""
        for tail in self.exe.tails:
            tail[-1].collected = []
        self.reset_accumulators()

    # ------------------------------------------------------------------
    def start(self, batch: Batch) -> Optional[_Pending]:
        """Advance a fresh micro-batch; returns a continuation if the
        prefix suspended at an extract, else None (fan-out done)."""
        return self._advance(dict(batch), 0)

    def resume(self, p: _Pending) -> Optional[_Pending]:
        op = self.exe.prefix[p.op_index]
        obs = self.obs
        if obs.enabled:
            t0 = obs.now()
            batch = op.apply_preds(p.batch, p.req.result, p.n)
            obs.tracer.span("resume", "resume", t0, obs.now(),
                            track=self._track, n=p.n)
        else:
            batch = op.apply_preds(p.batch, p.req.result, p.n)
        return self._advance(batch, p.op_index + 1)

    def _advance(self, batch: Batch, i: int) -> Optional[_Pending]:
        obs = self.obs
        while i < len(self.exe.prefix):
            op = self.exe.prefix[i]
            self.pcounts[op.name] += len(batch["idx"])
            n = int(batch["frames"].shape[0])
            if isinstance(op, MLLMExtractOp) and n > 0:
                variant = op.begin_extract(n)
                # a fused prefix immediately upstream computed the gate
                # signature in its single pass — hand it to the server
                # (and strip it: it must not ride into apply_preds)
                sig = batch.pop("_sig", None)
                req = self.server.submit(variant, batch["frames"],
                                         feed=self.feed, sig=sig)
                return _Pending(op_index=i, batch=batch, req=req, n=n)
            if obs.enabled:
                t0 = obs.now()
                batch = broadcast_windows(op.process(batch), self.windows)
                t1 = obs.now()
                fused = isinstance(op, FusedPrefixOp)
                obs.tracer.span("prefix:fused" if fused
                                else f"prefix:{op.name}", "prefix", t0,
                                t1, track=self._track, n=n)
                if n > 0:
                    # measured per-op accounting keyed the way the cost
                    # catalog keys predictions — what PlanAudit joins
                    # against (wall µs per invocation; frames in; rows
                    # surviving) to reconcile marginal cost + pass rate
                    key = op_cost_key(op)
                    obs.metrics.observe(f"op_wall_us/{key}",
                                        (t1 - t0) / 1e3)
                    obs.metrics.inc(f"op_frames/{key}", n)
                    obs.metrics.inc(f"op_rows_out/{key}",
                                    int(batch["frames"].shape[0]))
                if fused:
                    # per-stage attribution: the chain collapsed to one
                    # dispatch, so surviving-row counts per fused stage
                    # are the remaining stage-level signal
                    for sname, rows_in, rows_out in op.last_stage_counts:
                        obs.metrics.set_gauge(
                            f"prefix_fused/{self.feed}/{sname}/in",
                            rows_in)
                        obs.metrics.set_gauge(
                            f"prefix_fused/{self.feed}/{sname}/out",
                            rows_out)
            else:
                batch = broadcast_windows(op.process(batch), self.windows)
            i += 1
        self._fan_out(batch)
        return None

    def _fan_out(self, batch: Batch) -> None:
        obs = self.obs
        if not obs.enabled:
            fan_out_tails(self.exe.tails, batch, self.counts, self.windows,
                          parallel=self.parallel_tails)
            return
        t0 = obs.now()
        fan_out_tails(self.exe.tails, batch, self.counts, self.windows,
                      parallel=self.parallel_tails)
        t1 = obs.now()
        obs.tracer.span("tail", "tail", t0, t1, track=self._track,
                        n=len(batch["idx"]))
        tb = batch.get("_obs_t0")
        if tb:
            # frame latency: ingest stamp → emit; staleness: emit − the
            # feed's newest arrival (exceeds latency whenever fresher
            # frames arrived while this batch was in flight)
            stale = (t1 - self.arrival[0]) / 1e6 if self.arrival[0] \
                else None
            obs.slo.record(self.feed, (t1 - tb) / 1e6, stale,
                           n=int(batch.get("_obs_n", len(batch["idx"]))))

    def flush(self) -> None:
        """End of stream.  Flush batches carry no frames (only buffered
        window results), so pushing them through a downstream extract op is
        a no-op and never needs the server."""
        flush_shared(self.exe.prefix, self.exe.tails, self.windows,
                     self._fan_out)


class _FeedState:
    def __init__(self, feed: Feed, groups: List[_GroupExec],
                 arrival: Optional[list] = None):
        self.feed = feed
        self.groups = groups
        self.source_index = 0
        self.labels: List[Dict[str, Any]] = []
        self.pendings: List[tuple] = []      # (group, _Pending) FIFO
        self.arrival = arrival if arrival is not None else [0]
        # ---- fault-tolerance state (inert without a live injector) ----
        #: circuit breaker quarantining this feed after retry exhaustion
        self.breaker: Optional[CircuitBreaker] = None
        #: outstanding frame-range tickets: start idx -> groups still
        #: working on that micro-batch.  FIFO serving makes the
        #: outstanding set a contiguous suffix, so ``served_upto`` (the
        #: exactly-once frontier) is just the minimum outstanding start.
        self.tickets: Dict[int, int] = {}
        #: last per-feed recovery snapshot (ops + gate + sink/window
        #: lengths + the stream offset of the next pull)
        self.snap: Optional[Dict[str, Any]] = None
        #: captured at trip: the gate's newest concrete keyframe answer,
        #: served as the ``stale`` degraded-mode result (None -> drop)
        self.stale_answer: Optional[Dict[str, Any]] = None
        #: trip set this: on recovery, replay frames [snap.next_pull,
        #: replay_to) with sinks suppressed to rebuild operator state
        self.replay_to: Optional[int] = None
        self.degraded_records: List[Dict[str, Any]] = []
        self.n_degraded = 0
        self.n_dropped = 0

    @property
    def served_upto(self) -> int:
        """Every frame below this index has fully fanned out through
        every sharing group (the exactly-once frontier)."""
        return min(self.tickets) if self.tickets else self.source_index

    @property
    def name(self) -> str:
        return self.feed.name

    def all_ops(self) -> List[Op]:
        return [op for g in self.groups for op in g.all_ops()]


class MultiStreamRuntime:
    def __init__(self, feeds: List[Feed], ctx: OpContext,
                 micro_batch: int = 16,
                 server: Optional[SharedExtractServer] = None,
                 planner: Optional[SharingTreePlanner] = None,
                 max_pending: int = 2,
                 coalesce_frames: Optional[int] = None,
                 parallel_tails: bool = True,
                 pipelined: bool = True,
                 max_inflight: int = 2,
                 gate=None,
                 faults=None,
                 breaker_cooldown: int = 4,
                 snapshot_every: int = 8,
                 ingest_retries: int = 2):
        assert feeds, "need at least one feed"
        names = [f.name for f in feeds]
        assert len(set(names)) == len(names), f"duplicate feed names {names}"
        assert server is None or gate is None, \
            "pass the gate to the SharedExtractServer, not both"
        self.ctx = dataclasses.replace(ctx, micro_batch=micro_batch)
        self.micro_batch = micro_batch
        self.pipelined = pipelined
        #: fault injection (explicit arg > ctx.faults > the server's own >
        #: inert NULL_FAULTS); the resolved injector is pushed into the
        #: server so ingest and forward faults draw from one schedule
        self.faults = resolve_faults(
            faults, getattr(ctx, "faults", None),
            server.faults if server is not None
            and server.faults.enabled else None)
        self.server = server if server is not None \
            else SharedExtractServer(self.ctx, max_inflight=max_inflight,
                                     gate=gate, faults=self.faults)
        if self.faults.enabled and not self.server.faults.enabled:
            self.server.faults = self.faults
        self._chaos = self.faults.enabled
        self.breaker_cooldown = breaker_cooldown
        #: take a per-feed recovery snapshot every this many scheduling
        #: rounds (when the feed has no outstanding work) — bounds both
        #: snapshot overhead and the replay a recovery pays
        self.snapshot_every = max(snapshot_every, 1)
        #: bounded redelivery attempts for a corrupt ingest transport
        self.ingest_retries = ingest_retries
        #: observability rides the server (one trace across every feed);
        #: attach via ``ctx.obs`` or the server's ``obs=``
        self.obs = self.server.obs
        self._restored = False
        self.planner = planner if planner is not None else SharingTreePlanner()
        self.max_pending = max_pending
        #: drain the server once this many frames are queued (default: one
        #: full coalesced forward) — or when no feed can progress
        self.coalesce_frames = coalesce_frames if coalesce_frames is not None \
            else self.server.max_batch
        self.forests: Dict[str, SharingForest] = {}
        self._feeds: List[_FeedState] = []
        for feed in feeds:
            streams = {p.ops[0].stream_name for p in feed.plans
                       if isinstance(p.ops[0], SourceOp)}
            assert len(streams) == 1, \
                f"feed {feed.name!r} mixes source streams {streams}"
            forest = self.planner.plan(feed.plans)
            self.forests[feed.name] = forest
            arrival = [0]                 # shared newest-arrival slot
            groups = [_GroupExec(g.execution, self.ctx, self.server,
                                 feed.name, parallel_tails,
                                 arrival=arrival)
                      for g in forest.groups()]
            self._feeds.append(_FeedState(feed, groups, arrival=arrival))

    @classmethod
    def from_fleet(cls, fleet, streams: Dict[str, Any], ctx: OpContext,
                   **kw) -> "MultiStreamRuntime":
        """Serve a whole ``repro.core.fleet.FleetResult``: one feed per
        fleet feed (``streams`` maps feed name -> stream object), with the
        fleet's calibrated cost catalog backing the sharing-tree planner
        unless the caller supplies one explicitly."""
        assert set(streams) == set(fleet.plans_by_feed), \
            f"streams {sorted(streams)} != fleet feeds " \
            f"{sorted(fleet.plans_by_feed)}"
        feeds = [Feed(name, streams[name],
                      [p.clone() for p in plans])
                 for name, plans in fleet.plans_by_feed.items()]
        kw.setdefault("planner", SharingTreePlanner(
            catalog=fleet.catalog, micro_batch=kw.get("micro_batch", 16)))
        return cls(feeds, ctx, **kw)

    # ------------------------------------------------------------------
    def describe(self) -> str:
        return "\n".join(f"[{fs.name}]\n{self.forests[fs.name].describe()}"
                         for fs in self._feeds)

    # ------------------------------------------------------------------
    def audit(self, tolerance: float = 0.5):
        """A ``PlanAudit`` over this runtime's sharing forests, priced
        with the planner's own catalog / micro-batch / gate-hit-rate —
        call after ``run`` and join with ``self.obs.metrics`` for the
        predicted-vs-measured decision table."""
        from repro.obs.audit import PlanAudit
        return PlanAudit.from_runtime(self, tolerance=tolerance)

    #: drift tolerance for end-of-run cost reconciliation (relative)
    reconcile_tolerance = 0.5
    #: drift-flagged catalog keys from the most recent reconcile
    drift_flags: List[str] = []

    def _reconcile_costs(self) -> None:
        """Close the audit loop: EMA-feed the run's measured op costs
        (device-probed forwards, prefix-op walls) back into the
        planner's catalog — the cost-model twin of the gate-hit-rate
        feedback in ``_collect`` — and keep the drift flags for the
        flight report.  No catalog, no measurements: no-op."""
        catalog = getattr(self.planner, "catalog", None)
        if catalog is None or not hasattr(catalog, "reconcile"):
            return
        audit = self.audit(tolerance=self.reconcile_tolerance)
        self.drift_flags = audit.reconcile(self.obs.metrics, catalog)

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Aligned multi-feed checkpoint: per-feed source offsets + every
        group operator's state + the semantic gate's per-feed keyframes
        and tuned thresholds.  ``SharedExtractServer.drain()`` is the
        alignment barrier — in-flight extract continuations are run to
        completion and resumed first, so no channel holds data."""
        self._drain_all()
        assert not (self.server._queue or self.server._inflight)
        if self.obs.enabled:
            # the checkpoint is a natural audit boundary: everything
            # launched has retired, so the measured surfaces are complete
            # up to this instant — fold them into the catalog before the
            # state is frozen
            self._reconcile_costs()
        st: Dict[str, Any] = {"feeds": {}}
        for fs in self._feeds:
            st["feeds"][fs.name] = {
                "source_index": fs.source_index,
                "groups": [[op.snapshot() for op in g.all_ops()]
                           for g in fs.groups],
            }
        if self.server.gate is not None:
            st["gate"] = self.server.gate.snapshot()
        return st

    def restore(self, st: Dict[str, Any]) -> None:
        """Resume from a snapshot: replay each feed's stream to its
        recorded offset (the caller positions the streams, exactly like
        ``StreamRuntime``), restore operator + gating state, and suppress
        the next ``run``'s warmup reset."""
        assert set(st["feeds"]) == {fs.name for fs in self._feeds}
        for fs in self._feeds:
            fst = st["feeds"][fs.name]
            fs.source_index = fst["source_index"]
            assert len(fst["groups"]) == len(fs.groups)
            for g, states in zip(fs.groups, fst["groups"]):
                ops = g.all_ops()
                assert len(ops) == len(states)
                for op, s in zip(ops, states):
                    op.restore(s)
        if st.get("gate") is not None and self.server.gate is not None:
            self.server.gate.restore(st["gate"])
        self._restored = True

    # ------------------------------------------------------------------
    def _settle(self, fs: _FeedState) -> int:
        """Resume fulfilled continuations of one feed in FIFO order per
        group lane (so stateful post-extract ops observe stream order);
        re-suspensions keep their position in the queue.  Returns the
        number of continuations resumed."""
        if not self._chaos:
            fs.pendings, resumed = settle_fifo(
                fs.pendings, lambda group, p: group.resume(p))
            return resumed

        def resume(group, p):
            nxt = group.resume(p)
            if nxt is None:
                # this group finished the micro-batch: retire its share
                # of the frame-range ticket (advances ``served_upto``)
                self._ticket_done(fs, p.batch)
            return nxt

        fs.pendings, resumed = settle_fifo(fs.pendings, resume)
        return resumed

    def _ticket_done(self, fs: _FeedState, batch: Batch) -> None:
        i0 = batch.get("_ticket")
        if i0 is None:
            return                 # replay / flush batches carry no ticket
        left = fs.tickets.get(i0)
        if left is not None:
            if left <= 1:
                del fs.tickets[i0]
            else:
                fs.tickets[i0] = left - 1

    def _drain_all(self) -> None:
        """Blocking barrier: run every queued and in-flight forward and
        resume until no continuation is left (warmup, end of run, flush —
        the steady-state path is dispatch/poll in ``run``)."""
        while any(fs.pendings for fs in self._feeds):
            self.server.drain()
            for fs in self._feeds:
                self._settle(fs)

    def _warmup(self) -> None:
        """One untimed batch per feed through its full group set (and the
        server — compiling the shared extract programs is the point), then
        rewind streams, reset ops, drop accumulators and server stats.
        The fault injector sleeps through warmup: warmup traffic must not
        consume schedule events (or fail unobserved)."""
        was_enabled = self.faults.enabled
        self.faults.enabled = False
        try:
            self._warmup_inner()
        finally:
            self.faults.enabled = was_enabled

    def _warmup_inner(self) -> None:
        for fs in self._feeds:
            def advance(batch):
                for g in fs.groups:
                    p = g.start(batch)
                    if p is not None:
                        fs.pendings.append((g, p))
                self._drain_all()

            warmup_ops(fs.feed.stream, self.micro_batch, advance,
                       fs.all_ops())
            assert not fs.pendings
            fs.source_index = 0
            for g in fs.groups:
                g.reset_accumulators()
        if self.server.gate is not None:
            # keyframes learned from warmup frames must not leak into the
            # measured stream — the gate resets exactly like the ops do
            self.server.gate.reset()
        self.server.reset_stats()

    # ------------------------------------------------------------------
    # fault-tolerant serving (active only with a live injector; every
    # entry point below is behind ``self._chaos``)
    # ------------------------------------------------------------------
    def _snap_feed(self, fs: _FeedState) -> None:
        """Per-feed recovery snapshot — taken only when the feed has no
        outstanding work, so every captured structure is quiescent and
        the semantic cache holds no pending entries."""
        assert not fs.pendings and not fs.tickets
        gate = self.server.gate
        fs.snap = {
            "next_pull": fs.source_index,
            "groups": [[op.snapshot() for op in g.all_ops()]
                       for g in fs.groups],
            "window_lens": [[len(w) for w in g.windows]
                            for g in fs.groups],
            "pcounts": [dict(g.pcounts) for g in fs.groups],
            "counts": [[dict(c) for c in g.counts] for g in fs.groups],
            "gate": gate.snapshot_feed(fs.name)
            if gate is not None and gate.active else None,
        }

    def _rollback(self, fs: _FeedState, keep_upto: int) -> None:
        """Restore ops/gate/accumulators to the feed's last snapshot.
        Sink records below ``keep_upto`` (the exactly-once frontier) are
        final — *served* — and are kept; the recovery replay re-drives
        those frames with sink collection suppressed, so operator state
        catches back up without serving any frame twice."""
        snap = fs.snap
        gate = self.server.gate
        for g, states, lens, pc, cc in zip(
                fs.groups, snap["groups"], snap["window_lens"],
                snap["pcounts"], snap["counts"]):
            for op, s in zip(g.all_ops(), states):
                if isinstance(op, SinkOp):
                    continue     # sinks truncate content-based below
                op.restore(s)
            for tail in g.exe.tails:
                sink = tail[-1]
                sink.collected = [r for r in sink.collected
                                  if r.get("idx", -1) < keep_upto]
            for wl, L in zip(g.windows, lens):
                del wl[L:]       # replay re-emits deterministically
            g.pcounts = dict(pc)
            g.counts = [dict(c) for c in cc]
        if gate is not None and snap.get("gate") is not None:
            gate.restore_feed(fs.name, snap["gate"])

    def _degrade_range(self, fs: _FeedState, a: int, b: int) -> None:
        """Account frames [a, b) as degraded (stale keyframe answer) or
        dropped (no answer available) — exact loss accounting, never a
        silently wrong result."""
        n = b - a
        if n <= 0:
            return
        obs = self.obs
        if fs.stale_answer is not None:
            for i in range(a, b):
                fs.degraded_records.append(
                    {"idx": i, "stale": True, "answer": fs.stale_answer})
            fs.n_degraded += n
            if obs.enabled:
                obs.tracer.instant("degraded", "degraded",
                                   track=f"feed:{fs.name}", n=n)
                obs.metrics.inc(f"faults/degraded/{fs.name}", n)
                obs.slo.record_degraded(fs.name, n)
        else:
            fs.n_dropped += n
            if obs.enabled:
                obs.tracer.instant("dropped", "degraded",
                                   track=f"feed:{fs.name}", n=n)
                obs.metrics.inc(f"faults/dropped/{fs.name}", n)
                obs.slo.record_dropped(fs.name, n)

    def _trip(self, fs: _FeedState, reason: str) -> None:
        """Open the feed's circuit: capture the stale-answer fallback,
        cancel parked submissions, account the un-served suffix and roll
        the feed back to its last snapshot so a later recovery can replay
        forward.  The rest of the fleet is untouched — its requests keep
        flowing through the shared server."""
        obs = self.obs
        gate = self.server.gate
        # let healthy in-flight work finish first: an *ingest* trip
        # leaves the extract path intact, so frames already accepted can
        # still be served exactly once — only an extract trip (a failed
        # request among the pendings) skips straight to cancellation
        while fs.pendings and \
                not any(p.req.failed for _, p in fs.pendings):
            self.server.drain()
            self._settle(fs)
        keep_upto = fs.served_upto
        pulled_upto = fs.source_index
        if gate is not None and gate.active:
            fs.stale_answer = gate.stale_answer(fs.name)
        for _, p in fs.pendings:
            inner = getattr(p.req, "inner", p.req)
            if inner is not None:
                self.server.cancel(inner)
        fs.pendings = []
        fs.tickets.clear()
        self._degrade_range(fs, keep_upto, pulled_upto)
        self._rollback(fs, keep_upto)
        fs.replay_to = keep_upto
        fs.breaker.trip(reason)
        if obs.enabled:
            obs.tracer.instant(f"quarantine[{fs.name}]", "quarantine",
                               track=f"feed:{fs.name}")
            obs.metrics.inc(f"faults/trips/{fs.name}", 1)

    def _outage_turn(self, fs: _FeedState,
                     remaining: Dict[str, int]) -> None:
        """One quarantined scheduling round: the frames the feed would
        have pulled are accounted (stale-served or dropped) without
        touching the stream — recovery repositions it.  The skipped pull
        still consumes its source schedule event: quarantine does not
        freeze the fault timeline, so a count-limited outage ages out
        and the probe's peek can eventually see daylight."""
        if remaining[fs.name] <= 0:
            return
        take = min(self.micro_batch, remaining[fs.name])
        self.faults.next_event("source", fs.name)
        self._degrade_range(fs, fs.source_index, fs.source_index + take)
        fs.source_index += take
        remaining[fs.name] -= take

    def _canary_ok(self, fs: _FeedState) -> bool:
        """Drive one isolated canary extract for the feed through the
        real server.  It consumes a forward schedule event — an honest
        probe pays the same schedule the feed's next request would."""
        variant = None
        for g in fs.groups:
            for op in g.exe.prefix:
                if isinstance(op, MLLMExtractOp):
                    v = getattr(op, "model", "small")
                    variant = v if v in SharedExtractServer.VARIANTS \
                        else "small"
                    break
            if variant is not None:
                break
        if variant is None:
            return True      # no extract path: the transport peek decides
        frames = np.zeros((1,) + tuple(self.ctx.frame_shape),
                          dtype=np.float32)
        req = self.server.probe(variant, frames, feed=fs.name)
        while not req.done and not req.failed:
            self.server.dispatch()
            if self.server._inflight:
                self.server._inflight[0].block()
            self.server.poll()
        return not req.failed

    def _replay(self, fs: _FeedState) -> bool:
        """Recovery: reposition the stream and re-drive frames
        [snap.next_pull, replay_to) with sink collection suppressed —
        operator/gate/window state catches back up to the exactly-once
        frontier without serving any frame twice — then skip the stream
        past the degraded gap.  A terminal extract failure mid-replay
        rolls back again and reports False (the breaker re-opens with a
        doubled cooldown)."""
        snap = fs.snap
        start = snap["next_pull"]
        target = fs.replay_to
        stream = fs.feed.stream
        stream.reset()
        if start:
            stream.batch(start)
        pos = start
        ok = True
        while pos < target and ok:
            take = min(self.micro_batch, target - pos)
            frames, _ = stream.batch(take)
            batch = {"frames": frames,
                     "idx": np.arange(pos, pos + take),
                     "_suppress_sink": True}
            for g in fs.groups:
                p = g.start(batch)
                if p is not None:
                    fs.pendings.append((g, p))
            pos += take
            while fs.pendings:
                if any(p.req.failed for _, p in fs.pendings):
                    ok = False
                    break
                self.server.drain()
                self._settle(fs)
        if not ok:
            for _, p in fs.pendings:
                inner = getattr(p.req, "inner", p.req)
                if inner is not None:
                    self.server.cancel(inner)
            fs.pendings = []
            self._rollback(fs, fs.replay_to)
            return False
        if fs.source_index > target:
            stream.batch(fs.source_index - target)  # skip the degraded gap
        return True

    def _probe(self, fs: _FeedState) -> None:
        """Half-open: one probe decides.  The transport is *peeked*
        (would the next delivery fail past the retry budget?) without
        consuming a schedule event; the device path pays a real isolated
        canary forward.  Success replays from the last snapshot and
        closes the breaker; failure re-opens it with a doubled cooldown."""
        obs = self.obs
        br = fs.breaker
        if obs.enabled:
            obs.tracer.instant(f"probe[{fs.name}]", "quarantine",
                               track=f"feed:{fs.name}")
            obs.metrics.inc(f"faults/probes/{fs.name}", 1)
        fi = self.faults
        f = fi.fault_at("source", fs.name, "",
                        fi.peek_event("source", fs.name))
        src_dead = f is not None and f[0] == "corrupt" \
            and f[1] > self.ingest_retries
        if src_dead or not self._canary_ok(fs) or not self._replay(fs):
            br.probe_failed()
            return
        br.close()
        fs.stale_answer = None
        fs.replay_to = None
        self._snap_feed(fs)
        if obs.enabled:
            obs.tracer.instant(f"recovered[{fs.name}]", "quarantine",
                               track=f"feed:{fs.name}")
            obs.metrics.inc(f"faults/recoveries/{fs.name}", 1)

    def _ingest(self, fs: _FeedState, take: int) -> tuple:
        """One guarded pull: returns ``("ok", frames, labels)``,
        ``("stall",)`` — the feed produced nothing this round — or
        ``("lost",)`` when corrupt-delivery retries are exhausted (the
        caller accounts the frames and trips the breaker)."""
        fi = self.faults
        ev = fi.next_event("source", fs.name)
        f = fi.fault_at("source", fs.name, "", ev)
        if f is not None and f[0] == "stall":
            fi.fire("source", fs.name, "", ev)           # log the stall
            if self.obs.enabled:
                self.obs.tracer.instant("fault:stall", "fault",
                                        track=f"feed:{fs.name}", n=take)
            return ("stall",)
        frames, labels = fs.feed.stream.batch(take)
        if f is None:
            return ("ok", frames, labels)
        # corrupt transport: bounded redelivery against the same event —
        # a cleared attempt returns the pristine frames (bitwise)
        for attempt in range(self.ingest_retries + 1):
            got = fi.transport(fs.name, frames, ev, attempt)
            if fi.delivered_ok(got):
                return ("ok", got, labels)
        return ("lost",)

    def _chaos_turn(self, fs: _FeedState,
                    remaining: Dict[str, int]) -> Optional[bool]:
        """Breaker gate in front of a feed's scheduling turn: None lets
        the normal serve path run; otherwise the turn was consumed here
        and the value is whether it made progress (a quarantined feed
        with nothing left to account is *idle* — claiming progress would
        starve the other feeds' force-dispatch/wait path forever)."""
        br = fs.breaker
        if br.closed:
            if any(p.req.failed for _, p in fs.pendings):
                self._trip(fs, "extract retry budget exhausted")
                return True
            return None
        if br.state == OPEN:
            if remaining[fs.name] <= 0:
                br.tick()
                return False
            self._outage_turn(fs, remaining)
            br.tick()
            return True
        self._probe(fs)
        return True

    # ------------------------------------------------------------------
    def run(self, n_frames: Union[int, Dict[str, int]],
            warmup: int = 1) -> MultiStreamResult:
        """Drive every feed ``n_frames`` frames (int, or per-feed dict).

        ``warmup=1`` (default) makes this a *fresh* measurement — streams
        rewound, every op reset — exactly like ``StreamRuntime.run``; pass
        ``warmup=0`` to continue previous segments (the first run after
        ``restore()`` continues automatically).  Either way, sinks and
        per-run accumulators start empty."""
        if isinstance(n_frames, int):
            frames_by_feed = {fs.name: n_frames for fs in self._feeds}
        else:
            frames_by_feed = dict(n_frames)
            assert set(frames_by_feed) == {fs.name for fs in self._feeds}

        for fs in self._feeds:
            assert not fs.pendings
            fs.labels = []
            for g in fs.groups:
                g.begin_run()
            if self._chaos:
                fs.breaker = CircuitBreaker(self.breaker_cooldown)
                fs.tickets = {}
                fs.snap = None
                fs.stale_answer = None
                fs.replay_to = None
                fs.degraded_records = []
                fs.n_degraded = fs.n_dropped = 0
        if warmup and not self._restored:
            self._warmup()
        self._restored = False
        if self._chaos:
            # run-start snapshot: rollback always has a floor to land on
            for fs in self._feeds:
                self._snap_feed(fs)
        # per-run (not lifetime) model load, per prefix/tail component —
        # the same convention as the single-stream executors
        mllm_start = {
            fs.name: [(mllm_frames_of(g.exe.prefix),
                       [mllm_frames_of(t) for t in g.exe.tails])
                      for g in fs.groups]
            for fs in self._feeds}

        remaining = dict(frames_by_feed)
        t0 = time.perf_counter()
        rnd = 0
        while any(remaining.values()) or \
                any(fs.pendings for fs in self._feeds):
            order = self._feeds[rnd % len(self._feeds):] + \
                self._feeds[:rnd % len(self._feeds)]
            progressed = False
            for fs in order:
                if self._chaos:
                    ct = self._chaos_turn(fs, remaining)
                    if ct is not None:      # trip / quarantine / probe
                        progressed = progressed or ct
                        continue
                if remaining[fs.name] <= 0:
                    continue
                if len(fs.pendings) >= self.max_pending * len(fs.groups):
                    continue                      # per-stream backpressure
                if self._chaos and not fs.tickets and not fs.pendings \
                        and rnd % self.snapshot_every == 0:
                    self._snap_feed(fs)           # opportunistic, quiescent
                take = min(self.micro_batch, remaining[fs.name])
                obs = self.obs
                t_pull = obs.now() if obs.enabled else 0
                if self._chaos:
                    got = self._ingest(fs, take)
                    if got[0] == "stall":
                        continue   # the feed produced nothing this round
                    if got[0] == "lost":
                        # delivery retries exhausted: quarantine first
                        # (healthy in-flight frames settle and serve),
                        # then account the lost batch itself
                        self._trip(fs,
                                   "ingest delivery retries exhausted")
                        self._degrade_range(fs, fs.source_index,
                                            fs.source_index + take)
                        fs.source_index += take
                        remaining[fs.name] -= take
                        progressed = True
                        continue
                    frames, labels = got[1], got[2]
                else:
                    frames, labels = fs.feed.stream.batch(take)
                fs.labels.extend(labels)
                batch = {"frames": frames,
                         "idx": np.arange(fs.source_index,
                                          fs.source_index + take)}
                if self._chaos:
                    # frame-range ticket: retired once every group's
                    # fan-out for this micro-batch completes — the
                    # outstanding set defines ``served_upto``
                    fs.tickets[fs.source_index] = len(fs.groups)
                    batch["_ticket"] = fs.source_index
                if obs.enabled:
                    # lifecycle stamps ride the batch dict (every op
                    # copies it, so they survive to fan-out); the shared
                    # arrival slot feeds the staleness measure
                    t_arr = obs.now()
                    obs.tracer.span("ingest", "ingest", t_pull, t_arr,
                                    track=f"feed:{fs.name}", n=take)
                    batch["_obs_t0"] = t_arr
                    batch["_obs_n"] = take
                    fs.arrival[0] = t_arr
                fs.source_index += take
                remaining[fs.name] -= take
                for g in fs.groups:
                    p = g.start(batch)
                    if p is not None:
                        fs.pendings.append((g, p))
                    elif self._chaos:
                        self._ticket_done(fs, batch)
                progressed = True
            if self.pipelined:
                # overlap: ship the queue when the coalescing window fills
                # (or every feed is parked), harvest whatever the device
                # finished while this round did host-side work, resume
                # those continuations, and block only when truly stalled
                self.server.pump(
                    progressed, self.coalesce_frames,
                    lambda: sum(self._settle(fs) for fs in self._feeds))
            elif self.server.pending_frames() >= self.coalesce_frames \
                    or not progressed:
                self._drain_all()                 # lock-step baseline
            rnd += 1
        self._drain_all()
        for fs in self._feeds:
            if self._chaos and fs.breaker is not None \
                    and not fs.breaker.closed:
                # still quarantined at end of run: window aggregates over
                # the outage would cover frames the feed never served —
                # withhold them (never wrong) instead of emitting
                # partial answers
                continue
            for g in fs.groups:
                g.flush()
        wall = time.perf_counter() - t0

        return self._collect(frames_by_feed, mllm_start, wall)

    # ------------------------------------------------------------------
    def _collect(self, frames_by_feed: Dict[str, int],
                 mllm_start: Dict[str, List[tuple]],
                 wall: float) -> MultiStreamResult:
        total_q = sum(len(g.exe.queries) for fs in self._feeds
                      for g in fs.groups)
        #: query-frames served this run — feeds may have different budgets
        total_qframes = sum(
            frames_by_feed[fs.name] * sum(len(g.exe.queries)
                                          for g in fs.groups)
            for fs in self._feeds)
        feeds: Dict[str, FeedResult] = {}
        total_mllm = 0
        for fs in self._feeds:
            n = frames_by_feed[fs.name]
            per_query: Dict[str, RunResult] = {}
            used: set = set()
            feed_mllm = 0
            for gi, g in enumerate(fs.groups):
                prefix_start, tail_starts = mllm_start[fs.name][gi]
                prefix_mllm = mllm_frames_of(g.exe.prefix) - prefix_start
                tail_mllms = [mllm_frames_of(t) - s
                              for t, s in zip(g.exe.tails, tail_starts)]
                feed_mllm += prefix_mllm + sum(tail_mllms)
                for qi, qid in enumerate(g.exe.queries):
                    tail = g.exe.tails[qi]
                    key = qid
                    k = 1
                    while key in used:           # same qid in two groups
                        key = f"{qid}#{k}"
                        k += 1
                    used.add(key)
                    q_counts = dict(g.pcounts)
                    q_counts.update(g.counts[qi])
                    # amortized sharing convention (as MultiQueryRuntime):
                    # per-query fps is the aggregate query-frames/s every
                    # query experiences, and per-query walls — weighted by
                    # each query's frame budget — sum to the shared wall
                    per_query[key] = RunResult(
                        fps=total_qframes / wall,
                        wall_s=wall * n / max(total_qframes, 1),
                        n_frames=n,
                        outputs=tail[-1].collected,
                        window_results=g.windows[qi],
                        op_input_counts=q_counts,
                        mllm_frames=prefix_mllm + tail_mllms[qi],
                        labels=fs.labels,
                    )
            total_mllm += feed_mllm
            feeds[fs.name] = FeedResult(
                name=fs.name, n_frames=n, mllm_frames=feed_mllm,
                per_query=per_query,
                plan=self.forests[fs.name].describe(),
                # served + degraded + dropped == n: the exact partition
                # of the feed's ingested frames the chaos tests assert
                served=n - fs.n_degraded - fs.n_dropped,
                degraded=fs.n_degraded,
                dropped=fs.n_dropped,
                degraded_records=list(fs.degraded_records),
                breaker=dict(fs.breaker.counters)
                if fs.breaker is not None else {},
            )
        gate = self.server.gate
        if gate is not None and gate.active and \
                getattr(self.planner, "catalog", None) is not None:
            # close the cost-model loop: the measured per-feed hit rates
            # land in the planner's catalog, so the next planning pass
            # (SharingTreePlanner / FleetOptimizer) prices gated extracts
            # at their observed, not assumed, model load
            for fs in self._feeds:
                if gate.served(fs.name):
                    self.planner.catalog.record_gate_hit_rate(
                        fs.name, gate.hit_rate(fs.name))
        if self.obs.enabled:
            # unify the ad-hoc surfaces: server stats + gate counters land
            # in the registry next to the latency/staleness histograms
            m = self.obs.metrics
            m.ingest("server", self.server.stats)
            m.set_gauge("run/wall_s", wall)
            m.set_gauge("run/fps", total_qframes / wall)
            # a truncated trace looks complete in Perfetto — surface the
            # tracer's overwrite count where dashboards actually look
            m.counter("tracer/dropped_events").set(
                getattr(self.obs.tracer, "dropped", 0))
            for name, fr in feeds.items():
                m.counter(f"mllm_frames/{name}").set(fr.mllm_frames)
            if self._chaos:
                for fs in self._feeds:
                    if fs.breaker is not None:
                        m.ingest(f"breaker/{fs.name}",
                                 fs.breaker.counters)
            self._reconcile_costs()
        return MultiStreamResult(
            fps=total_qframes / wall,
            wall_s=wall,
            n_feeds=len(self._feeds),
            n_queries=total_q,
            mllm_frames=total_mllm,
            server_stats=dict(self.server.stats),
            feeds=feeds,
        )
